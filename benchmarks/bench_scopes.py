"""Multi-tenant scope benchmark: concurrent tenants vs solo runs, and
weighted-fair admission.

Simulated (virtual-time) comparison: each paper app graph is run solo
and then as TWO concurrent scopes (``RuntimeSimulator.run_scopes``) on
the same core count — the headline number is the concurrency ratio
``T_concurrent / (T_solo_a + T_solo_b)``: 1.0 means tenants time-share
perfectly, < 1.0 means idle-time overlap wins, and anything above
``1 / 0.9`` means the scope layers (keying shim, per-scope replay
slots, fair admission) cost real throughput. A fairness section floods
two scopes with independent tasks at 2:1 weights and measures the
grant ratio over the contended prefix (``sync`` mode: inline
dependence analysis, so readiness tracks submission and admission is
the contended stage — under the managed modes the DDAST MIN_READY
discipline deliberately keeps the ready pool small, which is upstream
of admission). A real-threaded section runs two client threads with
per-scope replay and reports the RuntimeStats rollups.

Standalone:

    PYTHONPATH=src python benchmarks/bench_scopes.py            # full sweep
    PYTHONPATH=src python benchmarks/bench_scopes.py --smoke    # ~10 s, CI
    ... [--out BENCH_scopes.json]

or as a suite inside ``python -m benchmarks.run --only scopes``.

Exit status doubles as the CI gate: non-zero when (a) 2-scope
concurrent throughput drops below 0.9x the sum-of-solo throughput on
the matmul graph (ddast AND sharded), or (b) weight-2:1 scopes stop
getting admission grants within 2:1 +- 25% over the contended prefix.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (DDASTParams, RuntimeSimulator,  # noqa: E402
                        SimTaskSpec, TaskRuntime)
from repro.core.taskgraph_apps import sim_app_specs  # noqa: E402
from repro.core.wd import DepMode  # noqa: E402

#: gate (a): concurrent makespan may exceed the sum of solos by at most
#: 1/0.9 (i.e. throughput >= 0.9x sum of solo runs)
MAX_CONC_RATIO = 1.0 / 0.9
#: gate (b): 2:1 weights must grant within +-25%
FAIR_LO, FAIR_HI = 2.0 * 0.75, 2.0 * 1.25

FULL = {
    "apps": {"matmul": 8, "nbody": 6, "sparselu": 10},
    "modes": ("sync", "ddast", "sharded"),
    "workers": 8,
    "flood": 120,
    "real_tasks": 200,
    "real_iters": 3,
}
SMOKE = {
    "apps": {"matmul": 8, "sparselu": 8},
    "modes": ("ddast", "sharded"),
    "workers": 8,
    "flood": 90,
    "real_tasks": 100,
    "real_iters": 3,
}


def _flood(n: int, tag: str):
    return [SimTaskSpec(dur=100.0, deps=[((tag, i), DepMode.INOUT)],
                        label=f"{tag}.{i}") for i in range(n)]


def sim_concurrency(cfg: dict) -> list:
    records = []
    for app, scale in cfg["apps"].items():
        for mode in cfg["modes"]:
            specs = sim_app_specs(app, scale)
            solo = RuntimeSimulator(cfg["workers"], mode).run(specs)
            conc = RuntimeSimulator(cfg["workers"], mode).run_scopes(
                [specs, specs], names=["a", "b"])
            ratio = conc.makespan_us / (2 * solo.makespan_us)
            records.append({
                "app": app, "mode": mode, "workers": cfg["workers"],
                "tasks_per_scope": solo.tasks,
                "solo_makespan_us": round(solo.makespan_us, 1),
                "concurrent_makespan_us": round(conc.makespan_us, 1),
                "concurrency_ratio": round(ratio, 4),
                "scope_finish_us": {
                    k: round(v["finish_us"], 1)
                    for k, v in conc.scopes.items()},
            })
    return records


def sim_fairness(cfg: dict) -> dict:
    n = cfg["flood"]
    r = RuntimeSimulator(4, "sync").run_scopes(
        [_flood(n, "a"), _flood(n, "b")], weights=[2.0, 1.0],
        names=["a", "b"])
    pre = r.exec_order[:n]              # both scopes still backlogged
    na = sum(1 for lbl in pre if lbl.startswith("a."))
    nb = len(pre) - na
    return {
        "flood_tasks_per_scope": n,
        "weights": [2.0, 1.0],
        "prefix_a": na, "prefix_b": nb,
        "grant_ratio": round(na / max(nb, 1), 3),
        "admission_waits": {k: v["admission_waits"]
                            for k, v in r.scopes.items()},
    }


def sim_fairness_flood(cfg: dict) -> dict:
    """Fairness under flood through the MANAGED modes (ddast AND
    sharded): a weight-2 victim with n tasks against a weight-1 tenant
    flooding 3n, measured on ``contended_grants`` — admission grants
    taken while both rings were backlogged, the only window where the
    2:1 weight is defined. ``min_ready_tasks`` is raised so dependence
    analysis runs eagerly and the rings actually backlog: with the
    default MIN_READY discipline readiness production is the
    bottleneck and admission never contends (the sync-mode prefix gate
    above covers that regime)."""
    n = cfg["flood"]
    params = DDASTParams(min_ready_tasks=100_000)
    out = {"victim_tasks": n, "flood_tasks": 3 * n,
           "weights": [2.0, 1.0], "modes": {}}
    for mode in ("ddast", "sharded"):
        r = RuntimeSimulator(4, mode, params=params).run_scopes(
            [_flood(n, "v"), _flood(3 * n, "f")], weights=[2.0, 1.0],
            names=["victim", "flood"])
        cg_v = r.scopes["victim"]["contended_grants"]
        cg_f = r.scopes["flood"]["contended_grants"]
        out["modes"][mode] = {
            "contended_grants": {"victim": cg_v, "flood": cg_f},
            "grant_ratio": round(cg_v / max(cg_f, 1), 3),
            "victim_finish_us": round(
                r.scopes["victim"]["finish_us"], 1),
            "flood_finish_us": round(r.scopes["flood"]["finish_us"], 1),
        }
    return out


def real_threads(cfg: dict) -> dict:
    """Two client threads, each iterating its own scope's graph with
    per-scope replay, on real threads (informational: wall time; the
    replay counters are deterministic)."""
    def spin():
        x = 0.0
        for i in range(150):
            x += i * i
        return x

    tasks, iters = cfg["real_tasks"], cfg["real_iters"]
    t0 = time.perf_counter()
    with TaskRuntime(num_workers=4, mode="sharded", num_shards=8,
                     num_clients=2, replay=True) as rt:
        def client(name, weight):
            sc = rt.open_scope(name, weight=weight)
            for _ in range(iters):
                for i in range(tasks):
                    sc.task(spin, deps=[((i % 31,), DepMode.INOUT)])
                sc.taskwait()
            sc.close()

        ts = [threading.Thread(target=client, args=("a", 2.0)),
              threading.Thread(target=client, args=("b", 1.0))]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    wall = time.perf_counter() - t0
    return {
        "mode": "sharded", "tasks_per_iter": tasks, "iters": iters,
        "wall_s": round(wall, 3),
        "scopes": {k: {kk: (round(vv, 4) if isinstance(vv, float) else vv)
                       for kk, vv in v.items()}
                   for k, v in rt.stats.scopes.items()},
    }


def acceptance(concurrency: list, fairness: dict, flood: dict) -> dict:
    gates = {}
    for rec in concurrency:
        if rec["app"] == "matmul" and rec["mode"] in ("ddast", "sharded"):
            gates[f"throughput_{rec['mode']}"] = (
                rec["concurrency_ratio"] <= MAX_CONC_RATIO)
    gates["fairness_2to1"] = FAIR_LO <= fairness["grant_ratio"] <= FAIR_HI
    for mode, rec in flood["modes"].items():
        gates[f"fairness_flood_{mode}"] = (
            FAIR_LO <= rec["grant_ratio"] <= FAIR_HI)
    gates["ok"] = all(gates.values())
    return gates


def run(rows: list, smoke: bool = True, out: str = None) -> bool:
    """``benchmarks.run`` suite entry point (smoke config there, like
    the sibling suites; the standalone CLI picks via ``--smoke``)."""
    cfg = SMOKE if smoke else FULL
    concurrency = sim_concurrency(cfg)
    fairness = sim_fairness(cfg)
    flood = sim_fairness_flood(cfg)
    real = real_threads(cfg)
    gates = acceptance(concurrency, fairness, flood)
    for rec in concurrency:
        rows.append((f"scopes.{rec['app']}.{rec['mode']}.conc_ratio",
                     rec["concurrency_ratio"],
                     f"solo={rec['solo_makespan_us']}us"))
    rows.append(("scopes.fairness.grant_ratio", fairness["grant_ratio"],
                 "weights 2:1"))
    for mode, rec in flood["modes"].items():
        cg = rec["contended_grants"]
        rows.append((f"scopes.fairness.flood.{mode}.grant_ratio",
                     rec["grant_ratio"],
                     f"contended {cg['victim']}:{cg['flood']} "
                     f"weights 2:1"))
    rows.append(("scopes.real.wall_s", real["wall_s"],
                 f"{real['tasks_per_iter']}x{real['iters']} x 2 scopes"))
    for k, v in real["scopes"].items():
        rows.append((f"scopes.real.{k}.replay_iters",
                     v["replay_iterations"], ""))
    rows.append(("scopes.gates.ok", int(gates["ok"]), str(gates)))
    if out:
        with open(out, "w") as f:
            json.dump({"concurrency": concurrency, "fairness": fairness,
                       "fairness_flood": flood,
                       "real_threads": real, "gates": gates,
                       "config": {k: v for k, v in cfg.items()
                                  if not isinstance(v, dict)}},
                      f, indent=2, default=str)
    return gates["ok"]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows: list = []
    ok = run(rows, smoke=args.smoke, out=args.out)
    print("name,value,derived")
    for n, v, d in rows:
        print(f"{n},{v},{d}")
    print(f"# gates {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
