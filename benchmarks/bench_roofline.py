"""Roofline table: reads the dry-run artifacts (experiments/dryrun/*.json)
and emits per-(arch x shape x mesh) terms. Falls back to a small live
lowering if no artifacts exist."""
from __future__ import annotations

import glob
import json
import os


def load_records(path: str = "experiments/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def run(csv_rows: list) -> None:
    recs = load_records()
    if not recs:
        csv_rows.append(("roofline.no_dryrun_artifacts", 0,
                         "run: python -m repro.launch.dryrun --all"))
        return
    ok = [r for r in recs if "terms_s" in r]
    skipped = [r for r in recs if "skip" in r]
    failed = [r for r in recs if "error" in r]
    csv_rows.append(("roofline.cells_ok", len(ok),
                     f"skipped={len(skipped)} failed={len(failed)}"))
    for r in ok:
        if r["mesh"] != "pod":
            continue                       # roofline table is single-pod
        t = r["terms_s"]
        total = t["compute"] + t["memory"] + t["collective"]
        frac = t["compute"] / total if total else 0.0
        csv_rows.append((
            f"roofline.{r['arch']}.{r['shape']}",
            round(frac, 4),
            f"comp={t['compute']:.3g}s mem={t['memory']:.3g}s "
            f"coll={t['collective']:.3g}s dom={r['dominant']} "
            f"useful={r.get('useful_ratio', 0):.2f}"))
