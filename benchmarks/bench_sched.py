"""Scheduling-subsystem benchmark: placement policies over replayed
task graphs.

Simulated (virtual-time) sweep of the placement table — round_robin /
shard_affine / critical_path, live vs ``replay=True`` — over the paper
app graphs plus an *imbalanced* sparse-LU (heavy diagonal factorization
and triangular solves, light updates: the shape where chain-blind ready
orders leave the critical path waiting behind breadth work). Under
``critical_path`` the frozen replay graph's bottom levels put the
longest remaining chain into the priority lane of every two-lane ready
deque (``core/sched``), so steady-state replay iterations finish no
later than round-robin replay while still touching zero locks and zero
mailboxes. A real-threaded section runs the same loop on this host and
reports the deterministic RuntimeStats deltas.

Standalone:

    PYTHONPATH=src python benchmarks/bench_sched.py            # full sweep
    PYTHONPATH=src python benchmarks/bench_sched.py --smoke    # ~10 s, CI
    ... [--out BENCH_sched.json]

or as a suite inside ``python -m benchmarks.run --only sched``.

Exit status doubles as the CI gate, on replayed imbalanced sparse-LU
(nb=10, 8 workers, 4 iterations, sharded): non-zero when (a) the
critical_path steady-state replay makespan exceeds the round_robin one,
or (b) critical_path steady-state iterations perform ANY lock
acquisition or process ANY mailbox message (simulated or real-threaded
— the priority lane must not reintroduce a lock).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import RuntimeSimulator, TaskRuntime  # noqa: E402
from repro.core.taskgraph_apps import (sim_app_specs,  # noqa: E402
                                       sim_sparselu_specs)
from repro.core.wd import DepMode  # noqa: E402

PLACEMENTS = ("round_robin", "shard_affine", "critical_path")

# The gate workload: imbalanced sparse-LU — heavy lu0 diagonal chain.
GATE = {"nb": 10, "workers": 8, "iters": 4, "mode": "sharded"}
GATE_DURS = dict(dur_lu0=600.0, dur_fwd=150.0, dur_bdiv=150.0,
                 dur_bmod=60.0)

FULL = {
    "apps": {"matmul": 8, "sparselu": 10},
    "workers": (8, 32),
    "iters": 4,
    "real_tasks": 200,
    "real_iters": 4,
}
SMOKE = {
    "apps": {"sparselu": 8},
    "workers": (8,),
    "iters": 4,
    "real_tasks": 120,
    "real_iters": 3,
}


def _gate_specs():
    return sim_sparselu_specs(GATE["nb"], **GATE_DURS)


def _steady(result) -> float:
    tail = result.iter_makespans_us[1:]
    return sum(tail) / len(tail) if tail else result.makespan_us


def _sim_record(specs, app: str, workers: int, placement: str,
                iters: int) -> dict:
    live = RuntimeSimulator(workers, GATE["mode"],
                            placement=placement).run(specs,
                                                     iterations=iters)
    rep = RuntimeSimulator(workers, GATE["mode"], replay=True,
                           placement=placement).run(specs,
                                                    iterations=iters)
    return {
        "app": app, "workers": workers, "placement": placement,
        "iters": iters, "tasks": rep.tasks,
        "live_makespan_us": round(live.makespan_us, 1),
        "replay_makespan_us": round(rep.makespan_us, 1),
        "live_steady_iter_us": round(_steady(live), 1),
        "replay_steady_iter_us": round(_steady(rep), 1),
        "replay_steady_lock_acq": sum(rep.iter_lock_acq[1:]),
        "replay_steady_messages": sum(rep.iter_messages[1:]),
    }


def sim_sweep(cfg: dict) -> list:
    records = []
    for app, scale in cfg["apps"].items():
        specs = sim_app_specs(app, scale)
        for p in cfg["workers"]:
            for placement in PLACEMENTS:
                records.append(_sim_record(specs, app, p, placement,
                                           cfg["iters"]))
    # the gate workload always runs, at every placement
    for placement in PLACEMENTS:
        records.append(_sim_record(_gate_specs(), "sparselu-imbalanced",
                                   GATE["workers"], placement,
                                   GATE["iters"]))
    return records


def real_sweep(cfg: dict) -> list:
    """Real threads: chained spin tasks under each placement with
    replay; steady-state lock/message deltas are deterministic."""
    records = []

    def spin():
        x = 0.0
        for i in range(200):
            x += i * i
        return x

    tasks, iters = cfg["real_tasks"], cfg["real_iters"]
    for placement in PLACEMENTS:
        iter_wall, iter_locks, iter_msgs = [], [], []
        with TaskRuntime(num_workers=4, mode=GATE["mode"], num_shards=8,
                         replay=True, placement=placement) as rt:
            prev_l = prev_m = 0
            for _ in range(iters):
                t0 = time.perf_counter()
                for i in range(tasks):
                    rt.task(spin, deps=[((i % 31,), DepMode.INOUT)])
                rt.taskwait()
                iter_wall.append(round(time.perf_counter() - t0, 4))
                st = rt.policy.stats()
                iter_locks.append(st["lock_acquisitions"] - prev_l)
                iter_msgs.append(st["messages_processed"] - prev_m)
                prev_l = st["lock_acquisitions"]
                prev_m = st["messages_processed"]
        records.append({
            "placement": placement, "tasks": tasks, "iters": iters,
            "iter_wall_s": iter_wall,
            "steady_lock_acq": sum(iter_locks[1:]),
            "steady_messages": sum(iter_msgs[1:]),
            "replay_iterations": rt.stats.replay_iterations,
            "priority_pushes": getattr(rt.placement, "priority_pushes",
                                       0),
        })
    return records


def acceptance(sim_records: list, real_records: list) -> dict:
    """The CI gates on replayed imbalanced sparse-LU: (a) critical_path
    steady-state makespan <= round_robin's, (b) critical_path steady
    state costs 0 locks and 0 messages (simulated and real-threaded)."""
    g = {r["placement"]: r for r in sim_records
         if r["app"] == "sparselu-imbalanced"}
    out = {"checked": "critical_path" in g and "round_robin" in g}
    if out["checked"]:
        cp, rr = g["critical_path"], g["round_robin"]
        out.update({
            "critical_path_steady_iter_us": cp["replay_steady_iter_us"],
            "round_robin_steady_iter_us": rr["replay_steady_iter_us"],
            "critical_path_not_slower":
                cp["replay_steady_iter_us"] <= rr["replay_steady_iter_us"],
            "replay_steady_lock_acq": cp["replay_steady_lock_acq"],
            "replay_steady_messages": cp["replay_steady_messages"],
            "replay_steady_zero_cost":
                cp["replay_steady_lock_acq"] == 0
                and cp["replay_steady_messages"] == 0,
        })
    cp_real = [r for r in real_records
               if r["placement"] == "critical_path"]
    out["real_checked"] = bool(cp_real)
    if cp_real:
        out["real_steady_lock_acq"] = max(r["steady_lock_acq"]
                                          for r in cp_real)
        out["real_steady_messages"] = max(r["steady_messages"]
                                          for r in cp_real)
        out["real_steady_zero_cost"] = (
            out["real_steady_lock_acq"] == 0
            and out["real_steady_messages"] == 0)
    return out


def collect(smoke: bool, with_real: bool = True) -> dict:
    cfg = SMOKE if smoke else FULL
    t0 = time.time()
    sim = sim_sweep(cfg)
    real = real_sweep(cfg) if with_real else []
    return {
        "bench": "sched",
        "smoke": smoke,
        "sim": sim,
        "real": real,
        "acceptance": acceptance(sim, real),
        "bench_wall_s": round(time.time() - t0, 2),
    }


def run(csv_rows: list) -> None:
    """benchmarks.run suite entry point."""
    out = collect(smoke=True)
    for r in out["sim"]:
        tag = f"sched.sim.{r['app']}.p{r['workers']}.{r['placement']}"
        csv_rows.append((f"{tag}.replay_steady_iter_us",
                         r["replay_steady_iter_us"],
                         f"live={r['live_steady_iter_us']} "
                         f"locks={r['replay_steady_lock_acq']} "
                         f"msgs={r['replay_steady_messages']}"))
    acc = out["acceptance"]
    csv_rows.append(("sched.acceptance.critical_path_not_slower",
                     int(acc.get("critical_path_not_slower", False)), ""))
    csv_rows.append(("sched.acceptance.steady_zero_cost",
                     int(acc.get("replay_steady_zero_cost", False)), ""))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep, same gate workload (~10 s, CI)")
    ap.add_argument("--no-real", action="store_true",
                    help="skip the real-threaded section")
    ap.add_argument("--out", default="BENCH_sched.json",
                    help="JSON output path")
    args = ap.parse_args()
    out = collect(smoke=args.smoke, with_real=not args.no_real)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    acc = out["acceptance"]
    print(f"wrote {args.out} ({len(out['sim'])} sim + "
          f"{len(out['real'])} real records, {out['bench_wall_s']}s)")
    failed = False
    if acc.get("checked"):
        print(f"imbalanced sparse-LU nb={GATE['nb']} @ {GATE['workers']} "
              f"workers x {GATE['iters']} iters, replay steady iter: "
              f"critical_path {acc['critical_path_steady_iter_us']}us vs "
              f"round_robin {acc['round_robin_steady_iter_us']}us -> "
              f"{'OK' if acc['critical_path_not_slower'] else 'REGRESSION'}")
        failed |= not acc["critical_path_not_slower"]
        print(f"critical_path steady locks="
              f"{acc['replay_steady_lock_acq']} "
              f"msgs={acc['replay_steady_messages']} -> "
              f"{'OK' if acc['replay_steady_zero_cost'] else 'REGRESSION'}")
        failed |= not acc["replay_steady_zero_cost"]
    if acc.get("real_checked"):
        print(f"real threads (critical_path): steady locks="
              f"{acc['real_steady_lock_acq']} "
              f"msgs={acc['real_steady_messages']} -> "
              f"{'OK' if acc['real_steady_zero_cost'] else 'REGRESSION'}")
        failed |= not acc["real_steady_zero_cost"]
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
