"""Figs 5-8 / Table 5 reproduction: sweep each DDAST parameter (doubling
1..128, as in the paper) with the others at their tuned defaults, on
Matmul + Sparse LU at the two largest thread counts (the paper's most
interesting configurations).

Also exercises the online ``num_shards`` hill-climb of ``DynamicTuner``
over the sharded policy: a phased real-threaded workload where the tuner
doubles/halves the shard count at taskwait quiescence until the
lock-wait-per-message metric brackets its optimum and settles — the
convergence trajectory is the benchmark output.
"""
from __future__ import annotations

from repro.core import (DDASTParams, DynamicTuner, RuntimeSimulator,
                        TaskRuntime, TunerConfig)
from repro.core.taskgraph_apps import sim_matmul_specs, sim_sparselu_specs
from repro.core.wd import DepMode

SWEEP = (1, 2, 4, 8, 16, 32, 64, 128)
THREADS = (32, 64)


def _apps():
    return {"matmul_fg": lambda: sim_matmul_specs(16, dur_us=100.0),
            "sparselu_fg": lambda: sim_sparselu_specs(
                20, dur_lu0=120, dur_fwd=95, dur_bdiv=95, dur_bmod=105)}


def sweep_param(param: str) -> dict:
    out = {}
    for app, factory in _apps().items():
        for p in THREADS:
            base = RuntimeSimulator(
                num_cores=p, mode="ddast", params=DDASTParams()).run(
                factory())
            for val in SWEEP:
                params = DDASTParams(**{param: val})
                r = RuntimeSimulator(num_cores=p, mode="ddast",
                                     params=params).run(factory())
                # speedup over the tuned default (y-axis of figs 5-8)
                out[(app, p, val)] = base.makespan_us / r.makespan_us
    return out


def shard_convergence(phases: int = 12, tasks: int = 400,
                      workers: int = 4) -> list:
    """Phased chained workload on the real threaded runtime with the
    shard hill-climb active; returns the num_shards trajectory (one entry
    per phase, observed after the phase's taskwait quiescence)."""

    def spin():
        x = 0.0
        for i in range(150):
            x += i * i
        return x

    traj = []
    with TaskRuntime(num_workers=workers, mode="sharded",
                     num_shards=2) as rt:
        tuner = DynamicTuner(rt, TunerConfig(interval_s=0.0,
                                             shard_min_messages=64))
        for _ in range(phases):
            for i in range(tasks):
                rt.task(spin, deps=[((i % 97,), DepMode.INOUT)])
            rt.taskwait()
            traj.append(rt.policy.num_shards)
        traj.append(1 if tuner.shards_settled else 0)  # settled flag last
    return traj


def run(csv_rows: list) -> None:
    for param, tuned in (("max_ddast_threads", "num_threads/8"),
                         ("max_spins", 1),
                         ("max_ops_thread", 8),
                         ("min_ready_tasks", 4)):
        table = sweep_param(param)
        for app in _apps():
            for p in THREADS:
                curve = [f"{table[(app, p, v)]:.3f}" for v in SWEEP]
                best_val = max(SWEEP, key=lambda v: table[(app, p, v)])
                csv_rows.append((
                    f"tuning.{param}.{app}.{p}t", best_val,
                    f"tuned_default={tuned} rel_speedup@1..128 "
                    + "/".join(curve)))
    traj = shard_convergence()
    settled = traj.pop()
    csv_rows.append(("tuning.num_shards.final", traj[-1],
                     "traj=" + "/".join(map(str, traj))))
    csv_rows.append(("tuning.num_shards.settled", settled,
                     "hill-climb bracketed its optimum"))


if __name__ == "__main__":
    traj = shard_convergence()
    settled = traj.pop()
    print("num_shards trajectory:", " -> ".join(map(str, traj)),
          "(settled)" if settled else "(still moving)")
