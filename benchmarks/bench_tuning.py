"""Figs 5-8 / Table 5 reproduction: sweep each DDAST parameter (doubling
1..128, as in the paper) with the others at their tuned defaults, on
Matmul + Sparse LU at the two largest thread counts (the paper's most
interesting configurations)."""
from __future__ import annotations

from repro.core import DDASTParams, RuntimeSimulator
from repro.core.taskgraph_apps import sim_matmul_specs, sim_sparselu_specs

SWEEP = (1, 2, 4, 8, 16, 32, 64, 128)
THREADS = (32, 64)


def _apps():
    return {"matmul_fg": lambda: sim_matmul_specs(16, dur_us=100.0),
            "sparselu_fg": lambda: sim_sparselu_specs(
                20, dur_lu0=120, dur_fwd=95, dur_bdiv=95, dur_bmod=105)}


def sweep_param(param: str) -> dict:
    out = {}
    for app, factory in _apps().items():
        for p in THREADS:
            base = RuntimeSimulator(
                num_cores=p, mode="ddast", params=DDASTParams()).run(
                factory())
            for val in SWEEP:
                params = DDASTParams(**{param: val})
                r = RuntimeSimulator(num_cores=p, mode="ddast",
                                     params=params).run(factory())
                # speedup over the tuned default (y-axis of figs 5-8)
                out[(app, p, val)] = base.makespan_us / r.makespan_us
    return out


def run(csv_rows: list) -> None:
    for param, tuned in (("max_ddast_threads", "num_threads/8"),
                         ("max_spins", 1),
                         ("max_ops_thread", 8),
                         ("min_ready_tasks", 4)):
        table = sweep_param(param)
        for app in _apps():
            for p in THREADS:
                curve = [f"{table[(app, p, v)]:.3f}" for v in SWEEP]
                best_val = max(SWEEP, key=lambda v: table[(app, p, v)])
                csv_rows.append((
                    f"tuning.{param}.{app}.{p}t", best_val,
                    f"tuned_default={tuned} rel_speedup@1..128 "
                    + "/".join(curve)))
