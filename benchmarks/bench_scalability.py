"""Figs 9-11 reproduction: speedup vs worker threads for Matmul, Sparse LU
and N-Body, coarse + fine grain, under sync (Nanos++ analogue), dast
(centralized manager [7]) and ddast (this paper) — in the deterministic
virtual-time simulator (this container has ONE physical core).

Task durations are the paper's workloads scaled so that the ratio
(task duration / runtime-op cost) matches the paper's regimes:
coarse grain ~ no contention; fine grain ~ the contention regime.
"""
from __future__ import annotations

from repro.core import DDASTParams, RuntimeSimulator
from repro.core.taskgraph_apps import (sim_matmul_specs, sim_nbody_specs,
                                       sim_sparselu_specs)

THREADS = (1, 2, 4, 8, 16, 32, 64)
MODES = ("sync", "dast", "ddast")


def _workloads():
    return {
        # (name, spec factory): CG = long tasks, FG = 8x shorter & 8x more
        "matmul_cg": lambda: sim_matmul_specs(8, dur_us=800.0),
        "matmul_fg": lambda: sim_matmul_specs(16, dur_us=100.0),
        "sparselu_cg": lambda: sim_sparselu_specs(
            12, dur_lu0=900, dur_fwd=750, dur_bdiv=750, dur_bmod=800),
        "sparselu_fg": lambda: sim_sparselu_specs(
            20, dur_lu0=120, dur_fwd=95, dur_bdiv=95, dur_bmod=105),
        "nbody_cg": lambda: sim_nbody_specs(8, 4, dur_force=700,
                                            dur_update=120),
        "nbody_fg": lambda: sim_nbody_specs(16, 4, dur_force=90,
                                            dur_update=20),
    }


def speedup_table() -> dict:
    out = {}
    for name, factory in _workloads().items():
        for mode in MODES:
            for p in THREADS:
                r = RuntimeSimulator(num_cores=p, mode=mode).run(factory())
                out[(name, mode, p)] = r
    return out


def run(csv_rows: list) -> None:
    table = speedup_table()
    for name in _workloads():
        for mode in MODES:
            curve = [f"{table[(name, mode, p)].speedup:.2f}"
                     for p in THREADS]
            best = table[(name, mode, THREADS[-1])]
            csv_rows.append((
                f"scalability.{name}.{mode}",
                best.speedup,
                "speedup@threads " + "/".join(curve)
                + f" lockwait64={best.lock_wait_us:.0f}us"))
        # the paper's headline: DDAST >= Nanos++ at max threads
        s = table[(name, "sync", 64)].speedup
        d = table[(name, "ddast", 64)].speedup
        csv_rows.append((f"scalability.{name}.ddast_vs_sync_64t",
                         d / s, "paper: >=1 at high thread counts"))
