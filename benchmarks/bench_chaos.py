"""Fault-tolerance benchmark: the price of surviving worker kills.

Two sections, both driven by the deterministic
:class:`~repro.core.procs.chaos.FaultPlan` harness:

  correctness   an idempotent ping-pong stencil (assign-only bodies,
                physical-cell region keys) under a seeded k=2 kill
                plan — the surviving run must equal the serial oracle
                bit-for-bit with zero leaked shm segments.
  recovery      the CPU-bound 8-worker spin graph (independent inout
                chains) run fault-free and again under a seeded k=2
                kill plan with retries: every task must still execute,
                and the faulty makespan must stay within the recovery
                budget of the clean one.

CI gates (--smoke, exit status):
  (a) kill-plan run serial-exact + no leaked shm — always enforced;
  (b) faulty wall <= 2.0x fault-free wall on the spin graph with every
      task executed — always enforced (both runs share the host, so
      load noise cancels in the ratio).

Standalone:

    PYTHONPATH=src python benchmarks/bench_chaos.py            # full
    PYTHONPATH=src python benchmarks/bench_chaos.py --smoke    # CI
    ... [--out BENCH_chaos.json]

or inside ``python -m benchmarks.run --only chaos``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import FaultPlan, ProcessRuntime  # noqa: E402
from repro.core.procs import apps  # noqa: E402

GATE = {"workers": 8, "chains": 8, "kills": 2, "recovery_ratio": 2.0}

FULL = {"chain_len": 24, "spin_us": 15000.0, "repeats": 3,
        "pp_cells": 8, "pp_stages": 8, "pp_spin_us": 1500.0,
        "seeds": (1, 2, 3)}
SMOKE = {"chain_len": 30, "spin_us": 5000.0, "repeats": 2,
         "pp_cells": 8, "pp_stages": 6, "pp_spin_us": 1000.0,
         "seeds": (1,)}


# ------------------------------------------------------------ oracle app
# Idempotent ping-pong stencil (same contract as tests/test_chaos.py):
# generation g ASSIGNS its cell of buffer (g+1)%2 from buffer g%2, and
# regions key physical cells, so a retried body recomputes the same
# value — the at-least-once contract that makes kill-plan runs
# comparable bit-for-bit against a serial oracle.

def pp_step(n0, n1, n, g, i, spin_us=0.0):
    bufs = (apps._attach(n0), apps._attach(n1))
    if spin_us:
        apps.spin(spin_us)
    src, dst = bufs[g % 2], bufs[(g + 1) % 2]
    dst[i] = (src[(i - 1) % n] + src[i] + src[(i + 1) % n]) * 0.5 + 1.0


def _submit_pingpong(rt, n0, n1, n, stages, retries, spin_us):
    for g in range(stages):
        for i in range(n):
            deps = [(("cell", (g + 1) % 2, i), "inout"),
                    (("cell", g % 2, (i - 1) % n), "in"),
                    (("cell", g % 2, i), "in"),
                    (("cell", g % 2, (i + 1) % n), "in")]
            rt.task(pp_step, n0, n1, n, g, i, spin_us, deps=deps,
                    label=f"pp[{g},{i}]", retries=retries)


def _serial_pingpong(init, n, stages):
    bufs = [list(init), [0.0] * n]
    for g in range(stages):
        src, dst = bufs[g % 2], bufs[(g + 1) % 2]
        for i in range(n):
            dst[i] = (src[(i - 1) % n] + src[i] + src[(i + 1) % n]) \
                * 0.5 + 1.0
    return bufs[stages % 2]


def correctness_section(cfg: dict) -> dict:
    """Seeded k=2 kill plans over the ping-pong stencil: serial-exact
    completion, respawn/retry counts, shm leak scan."""
    n, stages = cfg["pp_cells"], cfg["pp_stages"]
    runs = []
    for seed in cfg["seeds"]:
        b0, b1 = apps.ShmArray(n), apps.ShmArray(n)
        apps.fill_deterministic(b0, seed)
        init = b0.tolist()
        try:
            plan = FaultPlan.seeded_kills(seed, num_workers=2,
                                          total_tasks=n * stages,
                                          kills=GATE["kills"])
            t0 = time.perf_counter()
            with ProcessRuntime(num_workers=2, mode="sharded",
                                ipc_batch=1, fault_plan=plan) as rt:
                _submit_pingpong(rt, b0.name, b1.name, n, stages,
                                 retries=3, spin_us=cfg["pp_spin_us"])
                rt.taskwait()
            wall = time.perf_counter() - t0
            final = b0.tolist() if stages % 2 == 0 else b1.tolist()
            runs.append({
                "seed": seed,
                "tasks": n * stages,
                "wall_s": round(wall, 4),
                "serial_exact": final == _serial_pingpong(init, n,
                                                          stages),
                "worker_respawns": rt.stats.worker_respawns,
                "task_retries": rt.stats.task_retries,
                "leaked_shm": rt.stats.leaked_shm,
            })
        finally:
            b0.close_unlink()
            b1.close_unlink()
    return {"kills": GATE["kills"], "runs": runs}


def _spin_graph(rt, chains: int, chain_len: int, spin_us: float,
                retries: int) -> int:
    for c in range(chains):
        for i in range(chain_len):
            rt.task(apps.spin, spin_us, deps=[(("chain", c), "inout")],
                    label=f"spin[{c},{i}]", retries=retries)
    return chains * chain_len


def recovery_section(cfg: dict) -> dict:
    """Fault-free vs kill-plan makespan on the 8-worker spin graph.
    ``apps.spin`` is pure arithmetic (idempotent for free), so the only
    cost of a kill is the respawn plus the lost in-flight bodies."""
    total = GATE["chains"] * cfg["chain_len"]

    def once(plan):
        with ProcessRuntime(num_workers=GATE["workers"], mode="sharded",
                            ipc_batch=1, fault_plan=plan) as rt:
            t0 = time.perf_counter()
            _spin_graph(rt, GATE["chains"], cfg["chain_len"],
                        cfg["spin_us"], retries=3)
            rt.taskwait()
            wall = time.perf_counter() - t0
        return wall, rt.stats

    clean_walls, faulty_walls = [], []
    faulty_stats = None
    for r in range(cfg["repeats"]):
        wall, _ = once(None)
        clean_walls.append(round(wall, 4))
        plan = FaultPlan.seeded_kills(41 + r, GATE["workers"], total,
                                      kills=GATE["kills"])
        wall, st = once(plan)
        faulty_walls.append(round(wall, 4))
        faulty_stats = st
    clean, faulty = min(clean_walls), min(faulty_walls)
    return {
        "workers": GATE["workers"], "tasks": total,
        "spin_us": cfg["spin_us"],
        "clean_wall_s": clean_walls,
        "faulty_wall_s": faulty_walls,
        "recovery_ratio": round(faulty / clean, 3) if clean else 0.0,
        "tasks_executed": faulty_stats.tasks_executed,
        "worker_respawns": faulty_stats.worker_respawns,
        "task_retries": faulty_stats.task_retries,
        "leaked_shm": faulty_stats.leaked_shm,
    }


def acceptance(correct: dict, recov: dict) -> dict:
    runs = correct["runs"]
    return {
        "kills": GATE["kills"],
        "serial_exact_all": all(r["serial_exact"] for r in runs),
        "no_leaked_shm": all(not r["leaked_shm"] for r in runs)
        and not recov["leaked_shm"],
        "all_tasks_executed": recov["tasks_executed"] == recov["tasks"],
        "recovery_ratio": recov["recovery_ratio"],
        "recovery_target": GATE["recovery_ratio"],
        "recovery_ok": recov["recovery_ratio"]
        <= GATE["recovery_ratio"],
    }


def collect(smoke: bool) -> dict:
    cfg = SMOKE if smoke else FULL
    t0 = time.time()
    correct = correctness_section(cfg)
    recov = recovery_section(cfg)
    return {
        "bench": "chaos",
        "smoke": smoke,
        "correctness": correct,
        "recovery": recov,
        "acceptance": acceptance(correct, recov),
        "bench_wall_s": round(time.time() - t0, 2),
    }


def run(csv_rows: list) -> None:
    """benchmarks.run suite entry point."""
    out = collect(smoke=True)
    acc = out["acceptance"]
    for r in out["correctness"]["runs"]:
        csv_rows.append((f"chaos.correct.seed{r['seed']}.serial_exact",
                         int(r["serial_exact"]),
                         f"respawns={r['worker_respawns']} "
                         f"retries={r['task_retries']}"))
    rec = out["recovery"]
    csv_rows.append(("chaos.recovery.ratio", rec["recovery_ratio"],
                     f"target={acc['recovery_target']} "
                     f"respawns={rec['worker_respawns']} "
                     f"retries={rec['task_retries']}"))
    csv_rows.append(("chaos.recovery.tasks_executed",
                     rec["tasks_executed"], f"of {rec['tasks']}"))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep, same gates (~10 s, CI)")
    ap.add_argument("--out", default="BENCH_chaos.json",
                    help="JSON output path")
    args = ap.parse_args()
    out = collect(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    acc = out["acceptance"]
    print(f"wrote {args.out} ({out['bench_wall_s']}s)")
    print(f"correctness under k={acc['kills']} kills: serial_exact="
          f"{acc['serial_exact_all']} no_leaked_shm="
          f"{acc['no_leaked_shm']} -> "
          + ("OK" if acc["serial_exact_all"] and acc["no_leaked_shm"]
             else "REGRESSION"))
    print(f"recovery: faulty/clean wall ratio={acc['recovery_ratio']} "
          f"(target <= {acc['recovery_target']}), all_tasks_executed="
          f"{acc['all_tasks_executed']} -> "
          + ("OK" if acc["recovery_ok"] and acc["all_tasks_executed"]
             else "REGRESSION"))
    if not (acc["serial_exact_all"] and acc["no_leaked_shm"]
            and acc["recovery_ok"] and acc["all_tasks_executed"]):
        sys.exit(1)


if __name__ == "__main__":
    main()
