"""Shard-count × worker × batch-size sweep for the sharded manager.

Simulated (virtual-time) sweep over the paper's three app graphs
(matmul / N-Body / sparse LU from ``taskgraph_apps``) comparing the four
runtime organizations, with the shard-count and Submit-batch axes for
``sharded``. The headline numbers are total graph-lock wait (``sync``
reports the global lock's wait, ``sharded`` the per-shard waits summed —
directly comparable contention metrics) and mailbox message counts: at
64 shards a cross-shard task pays one ``msg_overhead`` per shard
portion, the cliff that Submit batching (one ``SubmitBatchMessage``
carrying up to ``batch_size`` portions per mailbox entry) flattens. A
small real-threaded section measures the same quantities on this host's
actual cores.

Standalone:

    PYTHONPATH=src python benchmarks/bench_shards.py            # full sweep
    PYTHONPATH=src python benchmarks/bench_shards.py --smoke    # ~10 s, CI
    ... [--out BENCH_shards.json]

or as a suite inside ``python -m benchmarks.run --only shards``.

Exit status doubles as the CI gate: non-zero when the sharded
organization's summed lock wait stops undercutting sync at 8 workers on
matmul, or when batching stops reducing the 16-shard message count.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import RuntimeSimulator, TaskRuntime  # noqa: E402
from repro.core.taskgraph_apps import sim_app_specs  # noqa: E402
from repro.core.wd import DepMode  # noqa: E402

FULL = {
    "apps": {"matmul": 8, "nbody": 8, "sparselu": 10},
    "workers": (2, 8, 16, 32),
    "shards": (1, 4, 16, 64),
    "batches": (None, 4, 16),
    "real_tasks": 600,
}
SMOKE = {
    "apps": {"matmul": 6, "nbody": 4, "sparselu": 8},
    "workers": (8,),
    "shards": (4, 16),
    "batches": (None, 8),
    "real_tasks": 200,
}


def sim_sweep(cfg: dict) -> list:
    """Virtual-time sweep; one record per
    (app, workers, mode[, shards[, batch]])."""
    records = []
    for app, scale in cfg["apps"].items():
        for p in cfg["workers"]:
            for mode in ("sync", "dast", "ddast"):
                r = RuntimeSimulator(p, mode).run(sim_app_specs(app, scale))
                records.append({
                    "app": app, "workers": p, "mode": mode, "shards": None,
                    "batch": None,
                    "tasks": r.tasks, "speedup": round(r.speedup, 3),
                    "makespan_us": round(r.makespan_us, 1),
                    "lock_wait_us": round(r.lock_wait_us, 2),
                    "lock_acq": r.lock_acquisitions,
                    "messages": r.messages,
                })
            for nshards in cfg["shards"]:
                for batch in cfg["batches"]:
                    r = RuntimeSimulator(p, "sharded", num_shards=nshards,
                                         batch_size=batch).run(
                        sim_app_specs(app, scale))
                    records.append({
                        "app": app, "workers": p, "mode": "sharded",
                        "shards": nshards, "batch": batch,
                        "tasks": r.tasks, "speedup": round(r.speedup, 3),
                        "makespan_us": round(r.makespan_us, 1),
                        "lock_wait_us": round(r.lock_wait_us, 2),
                        "lock_acq": r.lock_acquisitions,
                        "messages": r.messages,
                    })
    return records


def real_sweep(cfg: dict) -> list:
    """Real threads on this host: independent-chain workload, graph-lock
    wait under sync vs sharded (per-shard waits summed), batched and
    not."""
    records = []

    def spin():
        x = 0.0
        for i in range(200):
            x += i * i
        return x

    tasks = cfg["real_tasks"]
    for mode, nshards, batch in (("sync", None, None),
                                 ("ddast", None, None),
                                 ("sharded", 4, None),
                                 ("sharded", 16, None),
                                 ("sharded", 16, 8)):
        kw = {}
        if nshards:
            kw["num_shards"] = nshards
        if batch:
            kw["batch_size"] = batch
        with TaskRuntime(num_workers=4, mode=mode, **kw) as rt:
            for i in range(tasks):
                rt.task(spin, deps=[((i % 97,), DepMode.INOUT)])
            rt.taskwait()
        records.append({
            "mode": mode, "shards": nshards, "batch": batch, "tasks": tasks,
            "wall_s": round(rt.stats.wall_s, 4),
            "lock_wait_ms": round(rt.stats.lock_wait_s * 1e3, 4),
            "lock_acq": rt.stats.lock_acquisitions,
            "messages": rt.stats.messages_processed,
        })
    return records


def acceptance(sim_records: list) -> dict:
    """The checks CI gates on: (1) at 8 workers on the matmul graph the
    sharded organization's summed per-shard lock wait must undercut the
    sync global lock's wait; (2) batched sharded runs must not process
    more mailbox entries than unbatched at 16 shards."""
    sync8 = [r for r in sim_records
             if r["app"] == "matmul" and r["workers"] == 8
             and r["mode"] == "sync"]
    shard8 = [r for r in sim_records
              if r["app"] == "matmul" and r["workers"] == 8
              and r["mode"] == "sharded" and not r["batch"]]
    out = {"checked": bool(sync8 and shard8)}
    if sync8 and shard8:
        best = min(shard8, key=lambda r: r["lock_wait_us"])
        out.update({
            "sync_lock_wait_us": sync8[0]["lock_wait_us"],
            "sharded_best_lock_wait_us": best["lock_wait_us"],
            "sharded_best_shards": best["shards"],
            "sharded_lock_wait_lt_sync":
                best["lock_wait_us"] < sync8[0]["lock_wait_us"],
        })
    s16 = [r for r in sim_records
           if r["mode"] == "sharded" and r["shards"] == 16
           and r["app"] == "matmul" and r["workers"] == 8]
    unb = [r for r in s16 if not r["batch"]]
    bat = [r for r in s16 if r["batch"]]
    out["batch_checked"] = bool(unb and bat)
    if unb and bat:
        best_b = min(bat, key=lambda r: r["messages"])
        out.update({
            "unbatched_messages_16": unb[0]["messages"],
            "batched_messages_16": best_b["messages"],
            "batched_batch_size": best_b["batch"],
            "batched_le_unbatched":
                best_b["messages"] <= unb[0]["messages"],
        })
    return out


def collect(smoke: bool, with_real: bool = True) -> dict:
    cfg = SMOKE if smoke else FULL
    t0 = time.time()
    sim = sim_sweep(cfg)
    real = real_sweep(cfg) if with_real else []
    return {
        "bench": "shards",
        "smoke": smoke,
        "sim": sim,
        "real": real,
        "acceptance": acceptance(sim),
        "bench_wall_s": round(time.time() - t0, 2),
    }


def run(csv_rows: list) -> None:
    """benchmarks.run suite entry point."""
    out = collect(smoke=True)
    for r in out["sim"]:
        tag = (f"shards.sim.{r['app']}.p{r['workers']}.{r['mode']}"
               + (f".s{r['shards']}" if r["shards"] else "")
               + (f".b{r['batch']}" if r["batch"] else ""))
        csv_rows.append((f"{tag}.lock_wait_us", r["lock_wait_us"],
                         f"speedup={r['speedup']} msgs={r['messages']}"))
    for r in out["real"]:
        tag = (f"shards.real.{r['mode']}"
               + (f".s{r['shards']}" if r["shards"] else "")
               + (f".b{r['batch']}" if r["batch"] else ""))
        csv_rows.append((f"{tag}.lock_wait_ms", r["lock_wait_ms"],
                         f"msgs={r['messages']}"))
    acc = out["acceptance"]
    csv_rows.append(("shards.acceptance.sharded_lock_wait_lt_sync",
                     int(acc.get("sharded_lock_wait_lt_sync", False)), ""))
    csv_rows.append(("shards.acceptance.batched_le_unbatched",
                     int(acc.get("batched_le_unbatched", False)), ""))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small graphs, one worker count (~10 s, for CI)")
    ap.add_argument("--no-real", action="store_true",
                    help="skip the real-threaded section")
    ap.add_argument("--out", default="BENCH_shards.json",
                    help="JSON output path")
    args = ap.parse_args()
    out = collect(smoke=args.smoke, with_real=not args.no_real)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    acc = out["acceptance"]
    print(f"wrote {args.out} ({len(out['sim'])} sim + "
          f"{len(out['real'])} real records, {out['bench_wall_s']}s)")
    failed = False
    if acc.get("checked"):
        print(f"matmul @ 8 workers: sync lock wait "
              f"{acc['sync_lock_wait_us']}us vs sharded "
              f"{acc['sharded_best_lock_wait_us']}us "
              f"(S={acc['sharded_best_shards']}) -> "
              f"{'OK' if acc['sharded_lock_wait_lt_sync'] else 'REGRESSION'}")
        failed |= not acc["sharded_lock_wait_lt_sync"]
    if acc.get("batch_checked"):
        print(f"matmul @ 8 workers, 16 shards: unbatched "
              f"{acc['unbatched_messages_16']} msgs vs batched "
              f"{acc['batched_messages_16']} "
              f"(batch={acc['batched_batch_size']}) -> "
              f"{'OK' if acc['batched_le_unbatched'] else 'REGRESSION'}")
        failed |= not acc["batched_le_unbatched"]
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
