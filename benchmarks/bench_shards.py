"""Shard-count × worker sweep for the sharded dependence manager.

Simulated (virtual-time) sweep over the paper's three app graphs
(matmul / N-Body / sparse LU from ``taskgraph_apps``) comparing the four
runtime organizations, with the shard-count axis for ``sharded``. The
headline number is total graph-lock wait: ``sync`` reports the global
lock's wait, ``sharded`` the per-shard waits summed — directly
comparable contention metrics. A small real-threaded section measures
the same quantities on this host's actual cores.

Standalone:

    PYTHONPATH=src python benchmarks/bench_shards.py            # full sweep
    PYTHONPATH=src python benchmarks/bench_shards.py --smoke    # ~10 s, CI
    ... [--out BENCH_shards.json]

or as a suite inside ``python -m benchmarks.run --only shards``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import RuntimeSimulator, TaskRuntime  # noqa: E402
from repro.core.taskgraph_apps import sim_app_specs  # noqa: E402
from repro.core.wd import DepMode  # noqa: E402

FULL = {
    "apps": {"matmul": 8, "nbody": 8, "sparselu": 10},
    "workers": (2, 8, 16, 32),
    "shards": (1, 4, 16, 64),
    "real_tasks": 600,
}
SMOKE = {
    "apps": {"matmul": 6, "nbody": 4, "sparselu": 8},
    "workers": (8,),
    "shards": (4, 16),
    "real_tasks": 200,
}


def sim_sweep(cfg: dict) -> list:
    """Virtual-time sweep; one record per (app, workers, mode[, shards])."""
    records = []
    for app, scale in cfg["apps"].items():
        for p in cfg["workers"]:
            for mode in ("sync", "dast", "ddast"):
                r = RuntimeSimulator(p, mode).run(sim_app_specs(app, scale))
                records.append({
                    "app": app, "workers": p, "mode": mode, "shards": None,
                    "tasks": r.tasks, "speedup": round(r.speedup, 3),
                    "makespan_us": round(r.makespan_us, 1),
                    "lock_wait_us": round(r.lock_wait_us, 2),
                    "lock_acq": r.lock_acquisitions,
                    "messages": r.messages,
                })
            for nshards in cfg["shards"]:
                r = RuntimeSimulator(p, "sharded", num_shards=nshards).run(
                    sim_app_specs(app, scale))
                records.append({
                    "app": app, "workers": p, "mode": "sharded",
                    "shards": nshards,
                    "tasks": r.tasks, "speedup": round(r.speedup, 3),
                    "makespan_us": round(r.makespan_us, 1),
                    "lock_wait_us": round(r.lock_wait_us, 2),
                    "lock_acq": r.lock_acquisitions,
                    "messages": r.messages,
                })
    return records


def real_sweep(cfg: dict) -> list:
    """Real threads on this host: independent-chain workload, graph-lock
    wait under sync vs sharded (per-shard waits summed)."""
    records = []

    def spin():
        x = 0.0
        for i in range(200):
            x += i * i
        return x

    tasks = cfg["real_tasks"]
    for mode, nshards in (("sync", None), ("ddast", None),
                          ("sharded", 4), ("sharded", 16)):
        kw = {"num_shards": nshards} if nshards else {}
        with TaskRuntime(num_workers=4, mode=mode, **kw) as rt:
            for i in range(tasks):
                rt.task(spin, deps=[((i % 97,), DepMode.INOUT)])
            rt.taskwait()
        records.append({
            "mode": mode, "shards": nshards, "tasks": tasks,
            "wall_s": round(rt.stats.wall_s, 4),
            "lock_wait_ms": round(rt.stats.lock_wait_s * 1e3, 4),
            "lock_acq": rt.stats.lock_acquisitions,
            "messages": rt.stats.messages_processed,
        })
    return records


def acceptance(sim_records: list) -> dict:
    """The check ISSUE.md gates on: at 8 workers on the matmul graph the
    sharded organization's summed per-shard lock wait must undercut the
    sync global lock's wait."""
    sync8 = [r for r in sim_records
             if r["app"] == "matmul" and r["workers"] == 8
             and r["mode"] == "sync"]
    shard8 = [r for r in sim_records
              if r["app"] == "matmul" and r["workers"] == 8
              and r["mode"] == "sharded"]
    if not sync8 or not shard8:
        return {"checked": False}
    best = min(shard8, key=lambda r: r["lock_wait_us"])
    return {
        "checked": True,
        "sync_lock_wait_us": sync8[0]["lock_wait_us"],
        "sharded_best_lock_wait_us": best["lock_wait_us"],
        "sharded_best_shards": best["shards"],
        "sharded_lock_wait_lt_sync":
            best["lock_wait_us"] < sync8[0]["lock_wait_us"],
    }


def collect(smoke: bool, with_real: bool = True) -> dict:
    cfg = SMOKE if smoke else FULL
    t0 = time.time()
    sim = sim_sweep(cfg)
    real = real_sweep(cfg) if with_real else []
    return {
        "bench": "shards",
        "smoke": smoke,
        "sim": sim,
        "real": real,
        "acceptance": acceptance(sim),
        "bench_wall_s": round(time.time() - t0, 2),
    }


def run(csv_rows: list) -> None:
    """benchmarks.run suite entry point."""
    out = collect(smoke=True)
    for r in out["sim"]:
        tag = (f"shards.sim.{r['app']}.p{r['workers']}.{r['mode']}"
               + (f".s{r['shards']}" if r["shards"] else ""))
        csv_rows.append((f"{tag}.lock_wait_us", r["lock_wait_us"],
                         f"speedup={r['speedup']}"))
    for r in out["real"]:
        tag = (f"shards.real.{r['mode']}"
               + (f".s{r['shards']}" if r["shards"] else ""))
        csv_rows.append((f"{tag}.lock_wait_ms", r["lock_wait_ms"],
                         f"msgs={r['messages']}"))
    acc = out["acceptance"]
    csv_rows.append(("shards.acceptance.sharded_lock_wait_lt_sync",
                     int(acc.get("sharded_lock_wait_lt_sync", False)), ""))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small graphs, one worker count (~10 s, for CI)")
    ap.add_argument("--no-real", action="store_true",
                    help="skip the real-threaded section")
    ap.add_argument("--out", default="BENCH_shards.json",
                    help="JSON output path")
    args = ap.parse_args()
    out = collect(smoke=args.smoke, with_real=not args.no_real)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    acc = out["acceptance"]
    print(f"wrote {args.out} ({len(out['sim'])} sim + "
          f"{len(out['real'])} real records, {out['bench_wall_s']}s)")
    if acc.get("checked"):
        print(f"matmul @ 8 workers: sync lock wait "
              f"{acc['sync_lock_wait_us']}us vs sharded "
              f"{acc['sharded_best_lock_wait_us']}us "
              f"(S={acc['sharded_best_shards']}) -> "
              f"{'OK' if acc['sharded_lock_wait_lt_sync'] else 'REGRESSION'}")
        if not acc["sharded_lock_wait_lt_sync"]:
            sys.exit(1)


if __name__ == "__main__":
    main()
