"""Process-backend benchmark: escaping the GIL, measured.

The paper's argument needs CPU-bound task bodies running in *parallel* —
exactly what CPython threads cannot give it. This bench builds a wide
CPU-bound task graph (independent inout chains of pure-arithmetic spin
tasks, ~no syscalls, GIL never released) and compares makespan
throughput across:

    threads   + sync      the GIL-bound baseline
    threads   + sharded   lock-wait win only: still GIL-flatlined
    processes + sharded   the tentpole: real parallel bodies

plus a replay section: the same iterated graph under
``backend="processes"`` + ``replay=True``, checking the steady-state
invariant that replayed iterations cross the process boundary with
**zero** Submit/Done mailbox messages (one control frame per worker is
all that ships).

CI gates (--smoke, exit status):
  (a) processes+sharded throughput >= 1.5x threads+sync on the
      CPU-bound graph — SKIPPED (reported, not enforced) on hosts with
      < 2 usable cores, where no process backend can beat anything;
  (b) replay steady-state cross-process mailbox messages == 0 — always
      enforced (deterministic, core-count independent).

Standalone:

    PYTHONPATH=src python benchmarks/bench_procs.py            # full
    PYTHONPATH=src python benchmarks/bench_procs.py --smoke    # CI
    ... [--out BENCH_procs.json]

or inside ``python -m benchmarks.run --only procs``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import TaskRuntime  # noqa: E402
from repro.core.procs import apps  # noqa: E402

# The acceptance workload: 8 workers over 8 independent inout chains of
# CPU-bound tasks — wide enough to occupy every core, dependence-heavy
# enough that the managers do real work.
GATE = {"workers": 8, "chains": 8, "ratio": 1.5}

FULL = {"chain_len": 24, "spin_us": 2000.0, "repeats": 3,
        "replay_iters": 6, "replay_tasks": 32}
SMOKE = {"chain_len": 10, "spin_us": 1500.0, "repeats": 1,
         "replay_iters": 5, "replay_tasks": 24}


def _cpu_graph(rt, chains: int, chain_len: int, spin_us: float) -> int:
    for c in range(chains):
        for i in range(chain_len):
            rt.task(apps.spin, spin_us, deps=[(("chain", c), "inout")],
                    label=f"spin[{c},{i}]")
    return chains * chain_len


def throughput_sweep(cfg: dict) -> list:
    """tasks/s makespan throughput for the three driver configurations
    on the identical CPU-bound graph."""
    records = []
    combos = (("threads", "sync"), ("threads", "sharded"),
              ("processes", "sharded"))
    for backend, mode in combos:
        best = 0.0
        walls = []
        for _ in range(cfg["repeats"]):
            with TaskRuntime(num_workers=GATE["workers"], mode=mode,
                             backend=backend) as rt:
                t0 = time.perf_counter()
                n = _cpu_graph(rt, GATE["chains"], cfg["chain_len"],
                               cfg["spin_us"])
                rt.taskwait()
                wall = time.perf_counter() - t0
            walls.append(round(wall, 4))
            best = max(best, n / wall)
        records.append({
            "backend": backend, "mode": mode,
            "workers": GATE["workers"], "tasks": n,
            "spin_us": cfg["spin_us"],
            "wall_s": walls,
            "tasks_per_s": round(best, 1),
        })
    return records


def replay_section(cfg: dict) -> dict:
    """Iterated dependence chains under backend="processes" +
    replay=True: per-iteration cross-process (submit, done) frame
    counts. Steady state must be (0, 0)."""
    A = apps.ShmArray(8)
    apps.fill_deterministic(A, 13)
    iters = cfg["replay_iters"]
    try:
        with TaskRuntime(num_workers=2, mode="sharded", replay=True,
                         backend="processes") as rt:
            iter_wall = []
            for _ in range(iters):
                t0 = time.perf_counter()
                for i in range(cfg["replay_tasks"]):
                    rt.task(apps.nbody_update, A.name, A.name, A.name,
                            i % 4, deps=[(("X", i % 4), "inout")],
                            label=f"t{i}")
                rt.taskwait()
                iter_wall.append(round(time.perf_counter() - t0, 4))
        # the final (0, 0) entry is the shutdown boundary, not an
        # iteration — slice to the submitted iterations
        ipc = rt.iter_ipc[:iters]
        return {
            "iters": iters, "tasks_per_iter": cfg["replay_tasks"],
            "iter_ipc_msgs": ipc,
            "iter_wall_s": iter_wall,
            "steady_ipc_msgs": sum(s + d for s, d in ipc[1:]),
            "ctrl_msgs": rt.stats.ipc_ctrl_msgs,
            "replay_iterations": rt.stats.replay_iterations,
        }
    finally:
        A.close_unlink()


def acceptance(tput: list, replay: dict) -> dict:
    cores = os.cpu_count() or 1
    by = {(r["backend"], r["mode"]): r for r in tput}
    procs = by[("processes", "sharded")]["tasks_per_s"]
    sync = by[("threads", "sync")]["tasks_per_s"]
    ratio = round(procs / sync, 3) if sync else 0.0
    out = {
        "cores": cores,
        "procs_tasks_per_s": procs,
        "threads_sync_tasks_per_s": sync,
        "throughput_ratio": ratio,
        "throughput_target": GATE["ratio"],
        # one core cannot demonstrate parallelism: report, don't gate
        "throughput_gate_enforced": cores >= 2,
        "throughput_ok": ratio >= GATE["ratio"] or cores < 2,
        "replay_steady_ipc_msgs": replay["steady_ipc_msgs"],
        "replay_zero_ipc": replay["steady_ipc_msgs"] == 0,
    }
    return out


def collect(smoke: bool) -> dict:
    cfg = SMOKE if smoke else FULL
    t0 = time.time()
    tput = throughput_sweep(cfg)
    rep = replay_section(cfg)
    return {
        "bench": "procs",
        "smoke": smoke,
        "throughput": tput,
        "replay": rep,
        "acceptance": acceptance(tput, rep),
        "bench_wall_s": round(time.time() - t0, 2),
    }


def run(csv_rows: list) -> None:
    """benchmarks.run suite entry point."""
    out = collect(smoke=True)
    for r in out["throughput"]:
        csv_rows.append((f"procs.{r['backend']}.{r['mode']}.tasks_per_s",
                         r["tasks_per_s"],
                         f"workers={r['workers']} tasks={r['tasks']}"))
    acc = out["acceptance"]
    csv_rows.append(("procs.acceptance.throughput_ratio",
                     acc["throughput_ratio"],
                     f"target={acc['throughput_target']} "
                     f"cores={acc['cores']} "
                     f"enforced={int(acc['throughput_gate_enforced'])}"))
    csv_rows.append(("procs.acceptance.replay_steady_ipc_msgs",
                     acc["replay_steady_ipc_msgs"], ""))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep, same gates (~20 s, CI)")
    ap.add_argument("--out", default="BENCH_procs.json",
                    help="JSON output path")
    args = ap.parse_args()
    out = collect(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    acc = out["acceptance"]
    print(f"wrote {args.out} ({acc['cores']} cores, "
          f"{out['bench_wall_s']}s)")
    print(f"throughput: processes+sharded {acc['procs_tasks_per_s']} "
          f"tasks/s vs threads+sync {acc['threads_sync_tasks_per_s']} "
          f"tasks/s -> ratio {acc['throughput_ratio']} "
          f"(target {acc['throughput_target']})")
    failed = False
    if acc["throughput_gate_enforced"]:
        print("throughput gate: "
              + ("OK" if acc["throughput_ok"] else "REGRESSION"))
        failed |= not acc["throughput_ok"]
    else:
        print(f"throughput gate: SKIPPED ({acc['cores']} core(s) — "
              f"parallel speedup impossible here; enforced in CI)")
    print(f"replay steady-state cross-process msgs="
          f"{acc['replay_steady_ipc_msgs']} -> "
          + ("OK" if acc["replay_zero_ipc"] else "REGRESSION"))
    failed |= not acc["replay_zero_ipc"]
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
