"""Figs 12-14 reproduction + tracing-overhead gate.

Two sections:

  * **pyramid vs roof** — the evolution of in-graph / ready task counts
    across all four dependence policies on the paper's matmul and
    sparse-LU graphs. Nanos++/sync shows a 'pyramid' (every created
    task sits in the graph); the managed policies a flat 'roof' (tasks
    wait in the manager queues; the graph holds only what is needed to
    discover parallelism).
  * **tracing overhead** — the same graph simulated with ``trace=False``
    and ``trace=True``; every per-task event stamp is priced in virtual
    time (``SimCosts.trace_event``), so the makespan delta is the
    honest cost of the observability layer, not zero by construction.

Standalone:

    PYTHONPATH=src python benchmarks/bench_traces.py            # full sweep
    PYTHONPATH=src python benchmarks/bench_traces.py --smoke    # CI
    ... [--out BENCH_traces.json]

or as a suite inside ``python -m benchmarks.run --only traces``.

Exit status doubles as the CI gate, on the 16-core nb=16 matmul
(the acceptance workload): non-zero when (a) the sync pyramid stops
towering over the ddast roof (peak in-graph ratio <= 2), or (b) traced
makespan exceeds untraced by more than ``GATE['overhead_pct_max']`` %.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import RuntimeSimulator  # noqa: E402
from repro.core.taskgraph_apps import (sim_matmul_specs,  # noqa: E402
                                       sim_sparselu_specs)
from repro.core.trace import detect_all  # noqa: E402

# The gate workload is fixed by the acceptance criterion: nb=16 matmul
# (400 us bodies) on 16 simulated cores — identical in smoke and full.
GATE = {"app": "matmul_fg", "nb": 16, "dur_us": 400.0, "cores": 16,
        "mode": "ddast", "overhead_pct_max": 5.0,
        # sync keeps the whole graph live; ddast's sustained (mean)
        # in-graph level must sit well below it — the paper's roof
        "pyramid_ratio_min": 1.5}

MODES = ("sync", "dast", "ddast", "sharded")

FULL = {"matmul_nb": 16, "sparselu_nb": 14, "modes": MODES}
SMOKE = {"matmul_nb": 10, "sparselu_nb": 8, "modes": MODES}


def _apps(cfg: dict):
    return (
        ("matmul_fg", lambda: sim_matmul_specs(cfg["matmul_nb"],
                                               dur_us=400.0)),
        ("sparselu", lambda: sim_sparselu_specs(
            cfg["sparselu_nb"], dur_lu0=400, dur_fwd=320, dur_bdiv=320,
            dur_bmod=350)),
    )


def trace_stats(trace, makespan):
    if not trace:
        return {}
    ts = np.array([t for t, _, _ in trace])
    ig = np.array([g for _, g, _ in trace])
    rd = np.array([r for _, _, r in trace])
    # time-weighted mean in-graph level
    mid = ig[ts < makespan * 0.9]
    return {"peak_in_graph": int(ig.max()),
            "mean_in_graph": float(mid.mean()) if len(mid) else 0.0,
            "peak_ready": int(rd.max())}


def _pyramid_record(name: str, specs, mode: str, nb: int) -> dict:
    r = RuntimeSimulator(num_cores=16, mode=mode, trace=True).run(specs)
    st = trace_stats(r.trace, r.makespan_us)
    findings = detect_all(r.events)
    return {
        "app": name, "mode": mode, "nb": nb, "tasks": r.tasks,
        "makespan_us": round(r.makespan_us, 1),
        "events": len(r.events),
        "trace_dropped": r.trace_dropped,
        "steals": int(sum(r.worker_steals)),
        "findings": [f.kind for f in findings],
        **st,
    }


def pyramid_sweep(cfg: dict) -> list:
    """All four policies on both apps: legacy (t, in_graph, ready)
    samples plus the per-task event timeline's bulk counters."""
    records = []
    for name, factory in _apps(cfg):
        nb = cfg["matmul_nb" if name == "matmul_fg" else "sparselu_nb"]
        for mode in cfg["modes"]:
            records.append(_pyramid_record(name, factory(), mode, nb))
    # the pyramid gate compares sync vs ddast at the acceptance scale
    # regardless of the sweep config (smoke sweeps a smaller nb)
    if cfg["matmul_nb"] != GATE["nb"]:
        for mode in ("sync", "ddast"):
            records.append(_pyramid_record(
                "matmul_fg",
                sim_matmul_specs(GATE["nb"], dur_us=GATE["dur_us"]),
                mode, GATE["nb"]))
    return records


def overhead_case(cores: int, nb: int, dur_us: float, mode: str) -> dict:
    """Same graph, traced vs untraced; the pct delta is the gate."""
    specs = sim_matmul_specs(nb, dur_us=dur_us)
    base = RuntimeSimulator(cores, mode).run(specs)
    traced = RuntimeSimulator(cores, mode, trace=True).run(specs)
    pct = (traced.makespan_us / base.makespan_us - 1.0) * 100.0
    return {
        "app": "matmul_fg", "nb": nb, "cores": cores, "mode": mode,
        "untraced_makespan_us": round(base.makespan_us, 1),
        "traced_makespan_us": round(traced.makespan_us, 1),
        "traced_events": len(traced.events),
        "overhead_pct": round(pct, 3),
    }


def acceptance(pyramid: list, overhead: dict) -> dict:
    """The CI gates on the nb=16 matmul @ 16 cores workload."""
    out = {"overhead_pct": overhead["overhead_pct"],
           "overhead_pct_max": GATE["overhead_pct_max"],
           "overhead_ok": overhead["overhead_pct"]
           <= GATE["overhead_pct_max"]}
    means = {r["mode"]: r["mean_in_graph"] for r in pyramid
             if r["app"] == "matmul_fg" and r["nb"] == GATE["nb"]}
    out["checked"] = "sync" in means and "ddast" in means
    if out["checked"]:
        ratio = means["sync"] / max(means["ddast"], 1.0)
        out["pyramid_vs_roof_ratio"] = round(ratio, 2)
        out["pyramid_ok"] = ratio > GATE["pyramid_ratio_min"]
    return out


def collect(smoke: bool) -> dict:
    cfg = SMOKE if smoke else FULL
    t0 = time.time()
    pyramid = pyramid_sweep(cfg)
    # the gate overhead case runs at the acceptance scale regardless of
    # the sweep config (the smoke pyramid runs a smaller nb for speed)
    overhead = overhead_case(GATE["cores"], GATE["nb"], GATE["dur_us"],
                             GATE["mode"])
    return {
        "bench": "traces",
        "smoke": smoke,
        "pyramid": pyramid,
        "overhead": overhead,
        "acceptance": acceptance(pyramid, overhead),
        "bench_wall_s": round(time.time() - t0, 2),
    }


def run(csv_rows: list) -> None:
    """benchmarks.run suite entry point."""
    out = collect(smoke=True)
    stats: dict = {}
    for r in out["pyramid"]:
        stats.setdefault((r["app"], r["nb"]), {})[r["mode"]] = r
        csv_rows.append((
            f"traces.{r['app']}.nb{r['nb']}.{r['mode']}.peak_in_graph",
            r["peak_in_graph"],
            f"mean={r['mean_in_graph']:.0f} "
            f"peak_ready={r['peak_ready']} events={r['events']}"))
    for (app, nb), per_mode in stats.items():
        if "sync" not in per_mode or "ddast" not in per_mode:
            continue
        ratio = per_mode["sync"]["peak_in_graph"] / \
            max(per_mode["ddast"]["peak_in_graph"], 1)
        csv_rows.append((f"traces.{app}.nb{nb}.pyramid_vs_roof_ratio",
                         ratio,
                         "paper fig12/14: sync pyramid >> ddast roof"))
    ov = out["overhead"]
    csv_rows.append(("traces.overhead.traced_vs_untraced_pct",
                     ov["overhead_pct"],
                     f"gate<={GATE['overhead_pct_max']}% on "
                     f"{ov['cores']}-core nb{ov['nb']} matmul"))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small pyramid sweep, same gate workload (CI)")
    ap.add_argument("--out", default="BENCH_traces.json",
                    help="JSON output path")
    args = ap.parse_args()
    out = collect(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    acc = out["acceptance"]
    print(f"wrote {args.out} ({len(out['pyramid'])} pyramid records, "
          f"{out['bench_wall_s']}s)")
    failed = False
    if acc.get("checked"):
        print(f"matmul pyramid/roof ratio "
              f"{acc['pyramid_vs_roof_ratio']} (min "
              f"{GATE['pyramid_ratio_min']}) -> "
              f"{'OK' if acc['pyramid_ok'] else 'REGRESSION'}")
        failed |= not acc["pyramid_ok"]
    print(f"tracing overhead {acc['overhead_pct']}% of makespan on "
          f"{GATE['cores']}-core nb{GATE['nb']} matmul (max "
          f"{acc['overhead_pct_max']}%) -> "
          f"{'OK' if acc['overhead_ok'] else 'REGRESSION'}")
    failed |= not acc["overhead_ok"]
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
