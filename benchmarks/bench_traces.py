"""Figs 12-14 reproduction: the evolution of in-graph / ready task counts.
Nanos++ shows a 'pyramid' (every created task sits in the graph); DDAST a
flat 'roof' (tasks wait in the manager queues; the graph holds only what
is needed to discover parallelism)."""
from __future__ import annotations

import numpy as np

from repro.core import RuntimeSimulator
from repro.core.taskgraph_apps import sim_matmul_specs, sim_sparselu_specs


def trace_stats(trace, makespan):
    if not trace:
        return {}
    ts = np.array([t for t, _, _ in trace])
    ig = np.array([g for _, g, _ in trace])
    rd = np.array([r for _, _, r in trace])
    # time-weighted mean in-graph level
    mid = ig[ts < makespan * 0.9]
    return {"peak_in_graph": int(ig.max()),
            "mean_in_graph": float(mid.mean()) if len(mid) else 0.0,
            "peak_ready": int(rd.max())}


def run(csv_rows: list) -> None:
    for name, factory in (
            ("matmul_fg", lambda: sim_matmul_specs(16, dur_us=400.0)),
            ("sparselu", lambda: sim_sparselu_specs(
                14, dur_lu0=400, dur_fwd=320, dur_bdiv=320, dur_bmod=350))):
        stats = {}
        for mode in ("sync", "ddast"):
            r = RuntimeSimulator(num_cores=16, mode=mode, trace=True).run(
                factory())
            stats[mode] = trace_stats(r.trace, r.makespan_us)
            csv_rows.append((
                f"traces.{name}.{mode}.peak_in_graph",
                stats[mode]["peak_in_graph"],
                f"mean={stats[mode]['mean_in_graph']:.0f} "
                f"peak_ready={stats[mode]['peak_ready']}"))
        ratio = stats["sync"]["peak_in_graph"] / \
            max(stats["ddast"]["peak_in_graph"], 1)
        csv_rows.append((f"traces.{name}.pyramid_vs_roof_ratio", ratio,
                         "paper fig12/14: sync pyramid >> ddast roof"))
