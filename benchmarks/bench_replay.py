"""Record-and-replay benchmark: replay vs live iteration cost.

Simulated (virtual-time) comparison over the paper's three app graphs
(``taskgraph_apps``) submitted for several iterations, live vs with
``replay=True`` (``engine/replay.py``): iteration 1 records through the
live policy, every later structurally identical iteration bypasses
dependence analysis, locks, and mailboxes entirely. The headline
numbers are the per-iteration makespan / lock-acquisition / message
deltas (``SimResult.iter_*``). A real-threaded section runs the same
iteration loop on this host and reports the RuntimeStats deltas between
taskwaits — lock acquisitions and messages in replay steady state are
exactly zero there too, by construction, which is deterministic enough
to gate.

Standalone:

    PYTHONPATH=src python benchmarks/bench_replay.py            # full sweep
    PYTHONPATH=src python benchmarks/bench_replay.py --smoke    # ~10 s, CI
    ... [--out BENCH_replay.json]

or as a suite inside ``python -m benchmarks.run --only replay``.

Exit status doubles as the CI gate, on the 8x8 matmul graph over 4
iterations (the acceptance workload): non-zero when (a) replay
steady-state iterations perform ANY lock acquisition or process ANY
mailbox message, or (b) the steady-state replay iteration stops being
faster than the live steady-state iteration.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import RuntimeSimulator, TaskRuntime  # noqa: E402
from repro.core.taskgraph_apps import sim_app_specs  # noqa: E402
from repro.core.wd import DepMode  # noqa: E402

# The gate workload is fixed by the acceptance criterion: 8x8 matmul,
# 4 iterations — identical in smoke and full runs.
GATE = {"app": "matmul", "scale": 8, "iters": 4, "workers": 8}

FULL = {
    "apps": {"matmul": 8, "nbody": 8, "sparselu": 10},
    "workers": (8, 32),
    "modes": ("sync", "ddast", "sharded"),
    "iters": 4,
    "real_tasks": 300,
    "real_iters": 4,
}
SMOKE = {
    "apps": {"matmul": 8, "nbody": 4, "sparselu": 8},
    "workers": (8,),
    "modes": ("sync", "sharded"),
    "iters": 4,
    "real_tasks": 150,
    "real_iters": 3,
}


def _sim_pair(app: str, scale: int, workers: int, mode: str,
              iters: int) -> dict:
    specs = sim_app_specs(app, scale)
    live = RuntimeSimulator(workers, mode).run(specs, iterations=iters)
    rep = RuntimeSimulator(workers, mode, replay=True).run(
        specs, iterations=iters)
    return {
        "app": app, "workers": workers, "mode": mode, "iters": iters,
        "tasks": rep.tasks,
        "live_makespan_us": round(live.makespan_us, 1),
        "replay_makespan_us": round(rep.makespan_us, 1),
        "live_iter_us": [round(x, 1) for x in live.iter_makespans_us],
        "replay_iter_us": [round(x, 1) for x in rep.iter_makespans_us],
        "live_messages": live.messages,
        "replay_messages": rep.messages,
        "replay_steady_lock_acq": sum(rep.iter_lock_acq[1:]),
        "replay_steady_messages": sum(rep.iter_messages[1:]),
        "speedup_vs_live": round(live.makespan_us / rep.makespan_us, 3)
        if rep.makespan_us else 0.0,
    }


def sim_sweep(cfg: dict) -> list:
    records = []
    for app, scale in cfg["apps"].items():
        for p in cfg["workers"]:
            for mode in cfg["modes"]:
                records.append(_sim_pair(app, scale, p, mode,
                                         cfg["iters"]))
    return records


def real_sweep(cfg: dict) -> list:
    """Real threads: the spin-task iteration loop with and without
    replay; per-iteration RuntimeStats deltas (locks/messages are
    deterministic, wall time informational)."""
    records = []

    def spin():
        x = 0.0
        for i in range(200):
            x += i * i
        return x

    tasks, iters = cfg["real_tasks"], cfg["real_iters"]
    for mode, replay in (("sync", False), ("sync", True),
                         ("sharded", False), ("sharded", True)):
        iter_wall, iter_locks, iter_msgs = [], [], []
        with TaskRuntime(num_workers=4, mode=mode, num_shards=16,
                         replay=replay) as rt:
            prev_l = prev_m = 0
            for _ in range(iters):
                t0 = time.perf_counter()
                for i in range(tasks):
                    rt.task(spin, deps=[((i % 97,), DepMode.INOUT)])
                rt.taskwait()
                iter_wall.append(round(time.perf_counter() - t0, 4))
                st = rt.policy.stats()
                iter_locks.append(st["lock_acquisitions"] - prev_l)
                iter_msgs.append(st["messages_processed"] - prev_m)
                prev_l = st["lock_acquisitions"]
                prev_m = st["messages_processed"]
        records.append({
            "mode": mode, "replay": replay, "tasks": tasks, "iters": iters,
            "iter_wall_s": iter_wall,
            "iter_lock_acq": iter_locks,
            "iter_messages": iter_msgs,
            "steady_lock_acq": sum(iter_locks[1:]),
            "steady_messages": sum(iter_msgs[1:]),
            "replay_iterations": rt.stats.replay_iterations,
        })
    return records


def acceptance(sim_records: list, real_records: list) -> dict:
    """The CI gates, on the 8x8 matmul x 4 iteration workload: (a)
    replay steady-state lock acquisitions AND mailbox messages == 0
    (simulated and real-threaded), (b) steady-state replay iteration
    time < live iteration time (simulated — deterministic)."""
    g = [r for r in sim_records
         if r["app"] == GATE["app"] and r["workers"] == GATE["workers"]
         and r["iters"] == GATE["iters"]]
    out = {"checked": bool(g)}
    if g:
        worst_locks = max(r["replay_steady_lock_acq"] for r in g)
        worst_msgs = max(r["replay_steady_messages"] for r in g)
        # steady-state per-iteration time: best case excluded, compare
        # the worst replay iteration against the best live one
        slow_replay = max(max(r["replay_iter_us"][1:]) for r in g)
        fast_live = min(min(r["live_iter_us"][1:]) for r in g)
        out.update({
            "replay_steady_lock_acq": worst_locks,
            "replay_steady_messages": worst_msgs,
            "replay_steady_zero_cost": worst_locks == 0 and worst_msgs == 0,
            "replay_worst_steady_iter_us": slow_replay,
            "live_best_steady_iter_us": fast_live,
            "replay_iter_faster_than_live": slow_replay < fast_live,
        })
    real_rep = [r for r in real_records if r["replay"]]
    out["real_checked"] = bool(real_rep)
    if real_rep:
        out["real_steady_lock_acq"] = max(r["steady_lock_acq"]
                                          for r in real_rep)
        out["real_steady_messages"] = max(r["steady_messages"]
                                          for r in real_rep)
        out["real_steady_zero_cost"] = (out["real_steady_lock_acq"] == 0
                                        and out["real_steady_messages"]
                                        == 0)
    return out


def collect(smoke: bool, with_real: bool = True) -> dict:
    cfg = SMOKE if smoke else FULL
    t0 = time.time()
    sim = sim_sweep(cfg)
    # the gate workload runs regardless of the sweep config
    if not any(r["app"] == GATE["app"] and r["workers"] == GATE["workers"]
               for r in sim):
        sim.append(_sim_pair(GATE["app"], GATE["scale"], GATE["workers"],
                             "sharded", GATE["iters"]))
    real = real_sweep(cfg) if with_real else []
    return {
        "bench": "replay",
        "smoke": smoke,
        "sim": sim,
        "real": real,
        "acceptance": acceptance(sim, real),
        "bench_wall_s": round(time.time() - t0, 2),
    }


def run(csv_rows: list) -> None:
    """benchmarks.run suite entry point."""
    out = collect(smoke=True)
    for r in out["sim"]:
        tag = f"replay.sim.{r['app']}.p{r['workers']}.{r['mode']}"
        csv_rows.append((f"{tag}.speedup_vs_live", r["speedup_vs_live"],
                         f"steady_locks={r['replay_steady_lock_acq']} "
                         f"steady_msgs={r['replay_steady_messages']}"))
    for r in out["real"]:
        tag = (f"replay.real.{r['mode']}"
               + (".replay" if r["replay"] else ".live"))
        csv_rows.append((f"{tag}.steady_lock_acq", r["steady_lock_acq"],
                         f"steady_msgs={r['steady_messages']}"))
    acc = out["acceptance"]
    csv_rows.append(("replay.acceptance.steady_zero_cost",
                     int(acc.get("replay_steady_zero_cost", False)), ""))
    csv_rows.append(("replay.acceptance.iter_faster_than_live",
                     int(acc.get("replay_iter_faster_than_live", False)),
                     ""))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep, same gate workload (~10 s, CI)")
    ap.add_argument("--no-real", action="store_true",
                    help="skip the real-threaded section")
    ap.add_argument("--out", default="BENCH_replay.json",
                    help="JSON output path")
    args = ap.parse_args()
    out = collect(smoke=args.smoke, with_real=not args.no_real)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    acc = out["acceptance"]
    print(f"wrote {args.out} ({len(out['sim'])} sim + "
          f"{len(out['real'])} real records, {out['bench_wall_s']}s)")
    failed = False
    if acc.get("checked"):
        print(f"matmul 8x8 @ {GATE['workers']} workers x {GATE['iters']} "
              f"iters: replay steady locks="
              f"{acc['replay_steady_lock_acq']} "
              f"msgs={acc['replay_steady_messages']} -> "
              f"{'OK' if acc['replay_steady_zero_cost'] else 'REGRESSION'}")
        failed |= not acc["replay_steady_zero_cost"]
        print(f"steady iteration time: replay worst "
              f"{acc['replay_worst_steady_iter_us']}us vs live best "
              f"{acc['live_best_steady_iter_us']}us -> "
              f"{'OK' if acc['replay_iter_faster_than_live'] else 'REGRESSION'}")
        failed |= not acc["replay_iter_faster_than_live"]
    if acc.get("real_checked"):
        print(f"real threads: replay steady locks="
              f"{acc['real_steady_lock_acq']} "
              f"msgs={acc['real_steady_messages']} -> "
              f"{'OK' if acc['real_steady_zero_cost'] else 'REGRESSION'}")
        failed |= not acc["real_steady_zero_cost"]
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
