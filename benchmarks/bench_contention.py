"""Paper §1 motivation + simulator calibration: measure the REAL threaded
runtime's critical-section costs and lock contention on this host.

Emits the µs-scale constants that SimCosts defaults are calibrated from,
plus lock-wait statistics for sync vs ddast with real threads.

Standalone::

    PYTHONPATH=src python benchmarks/bench_contention.py --calibrate

prints the measured per-shard-portion overhead — the constant that
``SimCosts.portion_overhead`` models. The simulator used to charge an
idealized ``submit_cs / k`` per shard portion of a cross-shard task,
i.e. splitting a task across k shards was free; in the real runtime each
extra portion pays for mailbox dispatch, join-latch arithmetic and an
extra lock acquisition. The calibration isolates exactly that: the same
tasks with the same dependence count are pushed through a 1-shard router
(one portion per task) and a many-shard router (~k portions per task),
so the per-dependence cost cancels and the slope is the per-portion
overhead.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: F401,E402  (parity with sibling benches)

from repro.core import DDASTParams, TaskRuntime  # noqa: F401,E402
from repro.core.depgraph import DependenceGraph  # noqa: E402
from repro.core.queues import SPSCQueue  # noqa: E402
from repro.core.shards import (ShardRouter,  # noqa: E402
                               ShardedDependenceGraph)
from repro.core.wd import DepMode, WorkDescriptor  # noqa: E402


def calibrate() -> dict:
    """Single-thread microbenchmarks of the runtime primitives."""
    n = 20_000
    # WD creation
    t0 = time.perf_counter()
    wds = [WorkDescriptor(func=None, deps=((("r", i % 64), DepMode.INOUT),))
           for i in range(n)]
    create_us = (time.perf_counter() - t0) / n * 1e6
    # queue push/pop
    q = SPSCQueue()
    t0 = time.perf_counter()
    for w in wds:
        q.push(w)
    push_us = (time.perf_counter() - t0) / n * 1e6
    # graph submit / complete
    g = DependenceGraph()
    t0 = time.perf_counter()
    for w in wds:
        g.submit(w)
    submit_us = (time.perf_counter() - t0) / n * 1e6
    t0 = time.perf_counter()
    for w in wds:
        g.complete(w)
    done_us = (time.perf_counter() - t0) / n * 1e6
    return {"create_us": create_us, "push_us": push_us,
            "submit_cs_us": submit_us, "done_cs_us": done_us}


def calibrate_portion(tasks: int = 4000, k: int = 4) -> dict:
    """Measure the fixed cost of one extra shard portion
    (``SimCosts.portion_overhead``): identical k-dependence tasks through
    a 1-shard router (1 portion each) vs a 64-shard router (~k portions
    each); the per-dependence work cancels in the difference."""

    def measure(num_shards: int):
        graph = ShardedDependenceGraph(num_shards)
        router = ShardRouter(graph, on_ready=lambda wd: None)
        root = WorkDescriptor(func=None, label="root")
        wds = []
        for i in range(tasks):
            deps = tuple((("r", j, i % 61), DepMode.INOUT)
                         for j in range(k))
            wds.append(WorkDescriptor(func=None, deps=deps, parent=root))
        t0 = time.perf_counter()
        for wd in wds:
            router.route_submit(wd)
        router.drain_all()
        for wd in wds:
            wd.mark_finished()
            router.route_done(wd)
        router.drain_all()
        elapsed_us = (time.perf_counter() - t0) * 1e6
        portions = sum(len(wd.shard_parts) for wd in wds) * 2  # sub + done
        return elapsed_us, portions

    t1, p1 = measure(1)
    tk, pk = measure(64)
    if pk <= p1:                        # degenerate hash collapse
        return {"portion_overhead_us": 0.0, "portions_single": p1,
                "portions_spread": pk}
    return {
        "portion_overhead_us": (tk - t1) / (pk - p1),
        "portions_single": p1,
        "portions_spread": pk,
        "per_task_single_us": t1 / tasks,
        "per_task_spread_us": tk / tasks,
    }


def lock_contention(num_workers: int = 4, tasks: int = 600) -> dict:
    """Real threads: same independent-task workload under sync vs ddast;
    report graph-lock acquisitions + wait time."""
    out = {}

    def spin():
        x = 0.0
        for i in range(200):
            x += i * i
        return x

    for mode in ("sync", "ddast"):
        with TaskRuntime(num_workers=num_workers, mode=mode) as rt:
            for i in range(tasks):
                rt.task(spin, deps=[((i % 97,), DepMode.INOUT)])
            rt.taskwait()
        out[mode] = {
            "lock_acq": rt.stats.lock_acquisitions,
            "lock_wait_ms": rt.stats.lock_wait_s * 1e3,
            "wall_s": rt.stats.wall_s,
            "msgs": rt.stats.messages_processed,
        }
    return out


def run(csv_rows: list) -> None:
    cal = calibrate()
    for key, v in cal.items():
        csv_rows.append((f"calibrate.{key}", v, ""))
    por = calibrate_portion()
    csv_rows.append(("calibrate.portion_overhead_us",
                     por["portion_overhead_us"],
                     f"portions {por['portions_single']}->"
                     f"{por['portions_spread']}"))
    lc = lock_contention()
    for mode, st in lc.items():
        csv_rows.append((f"contention.{mode}.lock_wait_ms",
                         st["lock_wait_ms"],
                         f"acq={st['lock_acq']} msgs={st['msgs']}"))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--calibrate", action="store_true",
                    help="measure the per-shard-portion overhead from the "
                         "threaded runtime and print the value to use for "
                         "SimCosts.portion_overhead")
    args = ap.parse_args()
    if args.calibrate:
        por = calibrate_portion()
        print(f"measured portion_overhead: "
              f"{por['portion_overhead_us']:.3f} us/portion "
              f"({por['portions_single']} -> {por['portions_spread']} "
              f"portions)")
        print(f"suggested: SimCosts(portion_overhead="
              f"{por['portion_overhead_us']:.2f})")
        return
    rows: list = []
    run(rows)
    for name, value, note in rows:
        print(f"{name:42s} {value:10.4f}  {note}")


if __name__ == "__main__":
    main()
