"""Paper §1 motivation + simulator calibration: measure the REAL threaded
runtime's critical-section costs and lock contention on this host.

Emits the µs-scale constants that SimCosts defaults are calibrated from,
plus lock-wait statistics for sync vs ddast with real threads.

Standalone::

    PYTHONPATH=src python benchmarks/bench_contention.py --calibrate

prints the measured per-shard-portion overhead — the constant that
``SimCosts.portion_overhead`` models. The simulator used to charge an
idealized ``submit_cs / k`` per shard portion of a cross-shard task,
i.e. splitting a task across k shards was free; in the real runtime each
extra portion pays for mailbox dispatch, join-latch arithmetic and an
extra lock acquisition. The calibration isolates exactly that: the same
tasks with the same dependence count are pushed through a 1-shard router
(one portion per task) and a many-shard router (~k portions per task),
so the per-dependence cost cancels and the slope is the per-portion
overhead.
"""
from __future__ import annotations

import argparse
import multiprocessing
import os
import pickle
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: F401,E402  (parity with sibling benches)

from repro.core import DDASTParams, TaskRuntime  # noqa: F401,E402
from repro.core.depgraph import DependenceGraph  # noqa: E402
from repro.core.messages import (DONE_NO_RESULT,  # noqa: E402
                                 decode_done_batch, decode_submit_batch,
                                 encode_done_batch, encode_submit_batch)
from repro.core.procs import apps  # noqa: E402
from repro.core.procs import serial  # noqa: E402
from repro.core.procs.rings import ShmRing  # noqa: E402
from repro.core.queues import SPSCQueue  # noqa: E402
from repro.core.shards import (ShardRouter,  # noqa: E402
                               ShardedDependenceGraph)
from repro.core.wd import DepMode, WorkDescriptor  # noqa: E402


def calibrate() -> dict:
    """Single-thread microbenchmarks of the runtime primitives."""
    n = 20_000
    # WD creation
    t0 = time.perf_counter()
    wds = [WorkDescriptor(func=None, deps=((("r", i % 64), DepMode.INOUT),))
           for i in range(n)]
    create_us = (time.perf_counter() - t0) / n * 1e6
    # queue push/pop
    q = SPSCQueue()
    t0 = time.perf_counter()
    for w in wds:
        q.push(w)
    push_us = (time.perf_counter() - t0) / n * 1e6
    # graph submit / complete
    g = DependenceGraph()
    t0 = time.perf_counter()
    for w in wds:
        g.submit(w)
    submit_us = (time.perf_counter() - t0) / n * 1e6
    t0 = time.perf_counter()
    for w in wds:
        g.complete(w)
    done_us = (time.perf_counter() - t0) / n * 1e6
    return {"create_us": create_us, "push_us": push_us,
            "submit_cs_us": submit_us, "done_cs_us": done_us}


def calibrate_portion(tasks: int = 4000, k: int = 4) -> dict:
    """Measure the fixed cost of one extra shard portion
    (``SimCosts.portion_overhead``): identical k-dependence tasks through
    a 1-shard router (1 portion each) vs a 64-shard router (~k portions
    each); the per-dependence work cancels in the difference."""

    def measure(num_shards: int):
        graph = ShardedDependenceGraph(num_shards)
        router = ShardRouter(graph, on_ready=lambda wd: None)
        root = WorkDescriptor(func=None, label="root")
        wds = []
        for i in range(tasks):
            deps = tuple((("r", j, i % 61), DepMode.INOUT)
                         for j in range(k))
            wds.append(WorkDescriptor(func=None, deps=deps, parent=root))
        t0 = time.perf_counter()
        for wd in wds:
            router.route_submit(wd)
        router.drain_all()
        for wd in wds:
            wd.mark_finished()
            router.route_done(wd)
        router.drain_all()
        elapsed_us = (time.perf_counter() - t0) * 1e6
        portions = sum(len(wd.shard_parts) for wd in wds) * 2  # sub + done
        return elapsed_us, portions

    t1, p1 = measure(1)
    tk, pk = measure(64)
    if pk <= p1:                        # degenerate hash collapse
        return {"portion_overhead_us": 0.0, "portions_single": p1,
                "portions_spread": pk}
    return {
        "portion_overhead_us": (tk - t1) / (pk - p1),
        "portions_single": p1,
        "portions_spread": pk,
        "per_task_single_us": t1 / tasks,
        "per_task_spread_us": tk / tasks,
    }


def _ipc_echo_child(exec_name: str, done_name: str,
                    exec_fbq, done_fbq) -> None:
    """Worker half of the IPC calibration: pop a real EXEC frame off the
    shared-memory ring, answer it with a real DONE frame — the exact
    frame shapes and codecs the process backend ships per batch. Exits
    on the first CTRL frame."""
    ex = ShmRing.attach(exec_name, fallback=exec_fbq)
    dn = ShmRing.attach(done_name, fallback=done_fbq)
    while True:
        frame = ex.pop()
        if frame is None:
            # a real (if tiny) sleep: sleep(0) never deschedules on
            # Linux, and on a single-core host the two pollers must
            # alternate or each spins out a full scheduler quantum
            time.sleep(1e-6)
            continue
        kind, body = serial.parse(frame)
        if kind == serial.K_CTRL:
            break
        dones = [(wd_id, 0.0, 0.0, DONE_NO_RESULT, b"")
                 for wd_id, _payload, _label in body]
        dn.push(serial.frame_done(dones))
    ex.close()
    dn.close()


def calibrate_ipc(rounds: int = 400, batch: int = 8) -> dict:
    """Measure ``SimCosts.ipc_submit_us`` / ``ipc_done_us`` from REAL
    ring round-trips: fork an echo child over a ShmRing pair, push
    EXEC frames (the wire form of ``SubmitBatchMessage``), wait for the
    answering DONE frames, and split the per-task round-trip into its
    submit and done legs. Each leg = its codec cost (measured
    separately, in-process) + half the residual transport cost, so the
    asymmetry between the ~variable-size submit entry (pickled
    func+args) and the fixed 29-byte done header is preserved."""
    # a representative submit payload: a real kernel + scalar args, the
    # same shape ProcessDispatch pickles per task
    payload = pickle.dumps((apps.spin, (100.0,)), protocol=4)
    entries = [(i, payload, f"cal[{i}]") for i in range(batch)]
    dones = [(i, 0.0, 0.0, DONE_NO_RESULT, b"") for i in range(batch)]

    # codec-only legs, amortized per task (no transport)
    reps = 2000
    t0 = time.perf_counter()
    for _ in range(reps):
        decode_submit_batch(encode_submit_batch(entries))
    sub_codec_us = (time.perf_counter() - t0) / (reps * batch) * 1e6
    t0 = time.perf_counter()
    for _ in range(reps):
        decode_done_batch(encode_done_batch(dones))
    done_codec_us = (time.perf_counter() - t0) / (reps * batch) * 1e6

    # real round-trips against a forked echo child
    try:
        ctx = multiprocessing.get_context("fork")
    except ValueError:                   # pragma: no cover - non-POSIX
        ctx = multiprocessing.get_context()
    exec_fbq, done_fbq = ctx.SimpleQueue(), ctx.SimpleQueue()
    ex = ShmRing(1 << 16, fallback=exec_fbq)
    dn = ShmRing(1 << 16, fallback=done_fbq)
    child = ctx.Process(target=_ipc_echo_child,
                        args=(ex.name, dn.name, exec_fbq, done_fbq),
                        daemon=True)
    child.start()
    try:
        frame = serial.frame_exec(entries)

        def roundtrip(n: int) -> float:
            t0 = time.perf_counter()
            for _ in range(n):
                ex.push(frame)
                while dn.pop() is None:
                    time.sleep(1e-6)     # deschedule: don't starve the
                                         # child of the core (see child)
            return (time.perf_counter() - t0) / n * 1e6

        roundtrip(max(20, rounds // 10))           # warm-up
        rtt_us = roundtrip(rounds)
    finally:
        try:
            ex.push(serial.frame_ctrl(serial.OP_SHUTDOWN))
        except BufferError:              # pragma: no cover - dead child
            pass
        child.join(timeout=5.0)
        if child.is_alive():             # pragma: no cover - dead child
            child.terminate()
            child.join(timeout=1.0)
        ex.close()
        dn.close()
        ex.unlink()
        dn.unlink()

    rtt_task_us = rtt_us / batch
    transport_us = max(0.0, rtt_task_us - sub_codec_us - done_codec_us)
    return {
        "ipc_submit_us": sub_codec_us + transport_us / 2,
        "ipc_done_us": done_codec_us + transport_us / 2,
        "rtt_task_us": rtt_task_us,
        "sub_codec_us": sub_codec_us,
        "done_codec_us": done_codec_us,
        "batch": batch,
        "rounds": rounds,
    }


def lock_contention(num_workers: int = 4, tasks: int = 600) -> dict:
    """Real threads: same independent-task workload under sync vs ddast;
    report graph-lock acquisitions + wait time."""
    out = {}

    def spin():
        x = 0.0
        for i in range(200):
            x += i * i
        return x

    for mode in ("sync", "ddast"):
        with TaskRuntime(num_workers=num_workers, mode=mode) as rt:
            for i in range(tasks):
                rt.task(spin, deps=[((i % 97,), DepMode.INOUT)])
            rt.taskwait()
        out[mode] = {
            "lock_acq": rt.stats.lock_acquisitions,
            "lock_wait_ms": rt.stats.lock_wait_s * 1e3,
            "wall_s": rt.stats.wall_s,
            "msgs": rt.stats.messages_processed,
        }
    return out


def run(csv_rows: list) -> None:
    cal = calibrate()
    for key, v in cal.items():
        csv_rows.append((f"calibrate.{key}", v, ""))
    por = calibrate_portion()
    csv_rows.append(("calibrate.portion_overhead_us",
                     por["portion_overhead_us"],
                     f"portions {por['portions_single']}->"
                     f"{por['portions_spread']}"))
    ipc = calibrate_ipc()
    for key in ("ipc_submit_us", "ipc_done_us"):
        csv_rows.append((f"calibrate.{key}", ipc[key],
                         f"rtt/task={ipc['rtt_task_us']:.2f}us "
                         f"batch={ipc['batch']}"))
    lc = lock_contention()
    for mode, st in lc.items():
        csv_rows.append((f"contention.{mode}.lock_wait_ms",
                         st["lock_wait_ms"],
                         f"acq={st['lock_acq']} msgs={st['msgs']}"))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--calibrate", action="store_true",
                    help="measure the per-shard-portion overhead and the "
                         "process-backend IPC frame costs on this host; "
                         "print the values to use for "
                         "SimCosts.portion_overhead / ipc_submit_us / "
                         "ipc_done_us")
    args = ap.parse_args()
    if args.calibrate:
        por = calibrate_portion()
        print(f"measured portion_overhead: "
              f"{por['portion_overhead_us']:.3f} us/portion "
              f"({por['portions_single']} -> {por['portions_spread']} "
              f"portions)")
        ipc = calibrate_ipc()
        print(f"measured ring round-trip: {ipc['rtt_task_us']:.3f} "
              f"us/task (batch={ipc['batch']}, {ipc['rounds']} rounds)")
        print(f"  submit leg: {ipc['ipc_submit_us']:.3f} us "
              f"(codec {ipc['sub_codec_us']:.3f})   "
              f"done leg: {ipc['ipc_done_us']:.3f} us "
              f"(codec {ipc['done_codec_us']:.3f})")
        print(f"suggested: SimCosts(portion_overhead="
              f"{por['portion_overhead_us']:.2f}, "
              f"ipc_submit_us={ipc['ipc_submit_us']:.2f}, "
              f"ipc_done_us={ipc['ipc_done_us']:.2f})")
        return
    rows: list = []
    run(rows)
    for name, value, note in rows:
        print(f"{name:42s} {value:10.4f}  {note}")


if __name__ == "__main__":
    main()
