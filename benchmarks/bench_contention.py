"""Paper §1 motivation + simulator calibration: measure the REAL threaded
runtime's critical-section costs and lock contention on this host.

Emits the µs-scale constants that SimCosts defaults are calibrated from,
plus lock-wait statistics for sync vs ddast with real threads.

Standalone::

    PYTHONPATH=src python benchmarks/bench_contention.py --calibrate
    PYTHONPATH=src python benchmarks/bench_contention.py \
        [--smoke] [--out BENCH_contention.json]

``--calibrate`` prints the measured per-shard-portion overhead — the
constant that ``SimCosts.portion_overhead`` models. The simulator used to charge an
idealized ``submit_cs / k`` per shard portion of a cross-shard task,
i.e. splitting a task across k shards was free; in the real runtime each
extra portion pays for mailbox dispatch, join-latch arithmetic and an
extra lock acquisition. The calibration isolates exactly that: the same
tasks with the same dependence count are pushed through a 1-shard router
(one portion per task) and a many-shard router (~k portions per task),
so the per-dependence cost cancels and the slope is the per-portion
overhead. It also measures the delegation fast-path constants
(``SimCosts.delegate_us`` / ``combine_us``): a delegate is one request
publication against a HELD shard lock (GIL-atomic append + failed
trylock — the whole wait-free path), a combine is the session-fixed
cost of draining the request list, separated from the per-portion
apply cost by a two-point intercept.

The default run adds the delegation sweep: the simulator's
16-core x 8-shard contended workloads under delegation vs blocking
shard locks. Exit status doubles as the CI gate: non-zero when
(a) delegated shard-lock wait exceeds 0.7x the blocking wait on any
gated app, or (b) the delegated run's per-region dependence orderings
(write order + read-sees-writer) diverge from the ``sync`` oracle.
"""
from __future__ import annotations

import argparse
import json
import multiprocessing
import os
import pickle
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: F401,E402  (parity with sibling benches)

from repro.core import (DDASTParams, RuntimeSimulator,  # noqa: F401,E402
                        SimTaskSpec, TaskRuntime)
from repro.core.taskgraph_apps import sim_app_specs  # noqa: E402
from repro.core.depgraph import DependenceGraph  # noqa: E402
from repro.core.messages import (DONE_NO_RESULT,  # noqa: E402
                                 decode_done_batch, decode_submit_batch,
                                 encode_done_batch, encode_submit_batch)
from repro.core.procs import apps  # noqa: E402
from repro.core.procs import serial  # noqa: E402
from repro.core.procs.rings import ShmRing  # noqa: E402
from repro.core.queues import SPSCQueue  # noqa: E402
from repro.core.shards import (ShardRouter,  # noqa: E402
                               ShardedDependenceGraph)
from repro.core.wd import DepMode, WorkDescriptor  # noqa: E402


def calibrate() -> dict:
    """Single-thread microbenchmarks of the runtime primitives."""
    n = 20_000
    # WD creation
    t0 = time.perf_counter()
    wds = [WorkDescriptor(func=None, deps=((("r", i % 64), DepMode.INOUT),))
           for i in range(n)]
    create_us = (time.perf_counter() - t0) / n * 1e6
    # queue push/pop
    q = SPSCQueue()
    t0 = time.perf_counter()
    for w in wds:
        q.push(w)
    push_us = (time.perf_counter() - t0) / n * 1e6
    # graph submit / complete
    g = DependenceGraph()
    t0 = time.perf_counter()
    for w in wds:
        g.submit(w)
    submit_us = (time.perf_counter() - t0) / n * 1e6
    t0 = time.perf_counter()
    for w in wds:
        g.complete(w)
    done_us = (time.perf_counter() - t0) / n * 1e6
    return {"create_us": create_us, "push_us": push_us,
            "submit_cs_us": submit_us, "done_cs_us": done_us}


def calibrate_portion(tasks: int = 4000, k: int = 4) -> dict:
    """Measure the fixed cost of one extra shard portion
    (``SimCosts.portion_overhead``): identical k-dependence tasks through
    a 1-shard router (1 portion each) vs a 64-shard router (~k portions
    each); the per-dependence work cancels in the difference."""

    def measure(num_shards: int):
        graph = ShardedDependenceGraph(num_shards)
        router = ShardRouter(graph, on_ready=lambda wd: None)
        root = WorkDescriptor(func=None, label="root")
        wds = []
        for i in range(tasks):
            deps = tuple((("r", j, i % 61), DepMode.INOUT)
                         for j in range(k))
            wds.append(WorkDescriptor(func=None, deps=deps, parent=root))
        t0 = time.perf_counter()
        for wd in wds:
            router.route_submit(wd)
        router.drain_all()
        for wd in wds:
            wd.mark_finished()
            router.route_done(wd)
        router.drain_all()
        elapsed_us = (time.perf_counter() - t0) * 1e6
        portions = sum(len(wd.shard_parts) for wd in wds) * 2  # sub + done
        return elapsed_us, portions

    t1, p1 = measure(1)
    tk, pk = measure(64)
    if pk <= p1:                        # degenerate hash collapse
        return {"portion_overhead_us": 0.0, "portions_single": p1,
                "portions_spread": pk}
    return {
        "portion_overhead_us": (tk - t1) / (pk - p1),
        "portions_single": p1,
        "portions_spread": pk,
        "per_task_single_us": t1 / tasks,
        "per_task_spread_us": tk / tasks,
    }


def calibrate_delegation() -> dict:
    """Measure ``SimCosts.delegate_us`` / ``combine_us`` on this host.

    delegate: the shard lock is held by this thread, so every
    ``route_submit`` takes the wait-free path — GIL-atomic append onto
    the shard's request list plus one failed trylock — and returns.
    combine: strand k requests behind the held lock, release, then time
    one ``_try_combine`` session for k=1 and k=16; the session-fixed
    cost (staging, bucket rotation, lock traffic) is the two-point
    intercept of ``t(k) = session + k * apply``, so the per-portion
    graph-insert work cancels.
    """
    from repro.core.shards import ShardedDependenceGraph, ShardRouter
    graph = ShardedDependenceGraph(1)
    router = ShardRouter(graph, on_ready=lambda wd: None)
    root = WorkDescriptor(func=None, label="root")
    shard = graph.shards[0]

    def fresh(n):
        return [WorkDescriptor(func=None, parent=root,
                               deps=((("d", i % 61), DepMode.INOUT),))
                for i in range(n)]

    def retire(wds):
        for wd in wds:
            wd.mark_finished()
            router.route_done(wd)
        router.drain_all()

    n = 20_000
    wds = fresh(n)
    assert shard.lock.try_acquire()
    t0 = time.perf_counter()
    for wd in wds:
        router.route_submit(wd)
    delegate_us = (time.perf_counter() - t0) / n * 1e6
    shard.lock.release()
    router.drain_all()
    retire(wds)

    def combine_session_us(k: int, reps: int) -> float:
        total = 0.0
        for _ in range(reps):
            wds = fresh(k)
            assert shard.lock.try_acquire()
            for wd in wds:
                router.route_submit(wd)     # stranded behind held lock
            shard.lock.release()
            t0 = time.perf_counter()
            router._try_combine(0)
            total += time.perf_counter() - t0
            retire(wds)
        return total / reps * 1e6

    combine_session_us(8, 200)               # warm-up
    t1 = combine_session_us(1, 2000)
    t16 = combine_session_us(16, 500)
    # intercept of t(k) = session + k*apply through (1, t1), (16, t16)
    combine_us = max(0.0, (16.0 * t1 - 1.0 * t16) / 15.0)
    return {"delegate_us": delegate_us, "combine_us": combine_us,
            "combine_t1_us": t1, "combine_t16_us": t16}


def _sim_canonical(specs, result) -> dict:
    """Reduce a simulator run to its dependence semantics: per region,
    the write order and each read's last-seen writer, derived from
    ``exec_order`` (execution-start order; the event loop is
    deterministic, and a read can only start after its writer finished,
    before any successor writer starts — so a start-order scan
    reconstructs exactly which writer each read observed). Specs must
    carry unique integer labels."""
    by_label = {s.label: (i, s) for i, s in enumerate(specs)}
    events: dict = {}
    for lbl in result.exec_order:
        idx, s = by_label[lbl]
        for region, m in s.deps:
            events.setdefault(region, []).append(
                (idx, "w" if m.writes else "r"))
    out = {}
    for region, evs in events.items():
        writes = tuple(i for i, k in evs if k == "w")
        last = {}
        cur = -1
        for i, k in evs:
            if k == "w":
                cur = i
            else:
                last[i] = cur
        out[region] = (writes, tuple(sorted(last.items())))
    return out


def delegation_sweep(cfg: dict) -> tuple:
    """Simulator: contended paper apps on ``cores`` x ``shards``,
    delegation vs blocking shard locks. Returns (records, gates):
    gate (a) delegated shard-lock wait <= 0.7x blocking at the top
    core count, (b) per-region dependence orderings identical to the
    ``sync`` oracle for both transports."""
    shards = cfg["shards"]
    gate_cores = max(cfg["cores"])
    records, gates = [], {}
    for app, scale in cfg["apps"].items():
        specs = [SimTaskSpec(dur=s.dur, deps=s.deps, label=str(i))
                 for i, s in enumerate(sim_app_specs(app, scale))]
        oracle = _sim_canonical(
            specs, RuntimeSimulator(4, "sync").run(specs))
        for cores in cfg["cores"]:
            runs = {}
            for deleg in (True, False):
                r = RuntimeSimulator(cores, "sharded", num_shards=shards,
                                     delegation=deleg).run(specs)
                runs[deleg] = r
                records.append({
                    "app": app, "cores": cores, "shards": shards,
                    "delegation": deleg,
                    "makespan_us": round(r.makespan_us, 1),
                    "lock_wait_us": round(r.lock_wait_us, 1),
                    "lock_handoffs": sum(r.lock_handoffs),
                    "delegated_portions": r.delegated_portions,
                    "combined_drains": r.combined_drains,
                })
            if cores == gate_cores:
                d, b = runs[True], runs[False]
                gates[f"lock_wait_{app}"] = (
                    d.lock_wait_us <= 0.7 * b.lock_wait_us
                    if b.lock_wait_us > 0 else d.lock_wait_us == 0.0)
                gates[f"ordering_{app}"] = (
                    _sim_canonical(specs, d) == oracle
                    and _sim_canonical(specs, b) == oracle)
    return records, gates


def _ipc_echo_child(exec_name: str, done_name: str,
                    exec_fbq, done_fbq) -> None:
    """Worker half of the IPC calibration: pop a real EXEC frame off the
    shared-memory ring, answer it with a real DONE frame — the exact
    frame shapes and codecs the process backend ships per batch. Exits
    on the first CTRL frame."""
    ex = ShmRing.attach(exec_name, fallback=exec_fbq)
    dn = ShmRing.attach(done_name, fallback=done_fbq)
    while True:
        frame = ex.pop()
        if frame is None:
            # a real (if tiny) sleep: sleep(0) never deschedules on
            # Linux, and on a single-core host the two pollers must
            # alternate or each spins out a full scheduler quantum
            time.sleep(1e-6)
            continue
        kind, body = serial.parse(frame)
        if kind == serial.K_CTRL:
            break
        dones = [(wd_id, 0.0, 0.0, DONE_NO_RESULT, b"")
                 for wd_id, _payload, _label in body]
        dn.push(serial.frame_done(dones))
    ex.close()
    dn.close()


def calibrate_ipc(rounds: int = 400, batch: int = 8) -> dict:
    """Measure ``SimCosts.ipc_submit_us`` / ``ipc_done_us`` from REAL
    ring round-trips: fork an echo child over a ShmRing pair, push
    EXEC frames (the wire form of ``SubmitBatchMessage``), wait for the
    answering DONE frames, and split the per-task round-trip into its
    submit and done legs. Each leg = its codec cost (measured
    separately, in-process) + half the residual transport cost, so the
    asymmetry between the ~variable-size submit entry (pickled
    func+args) and the fixed 29-byte done header is preserved."""
    # a representative submit payload: a real kernel + scalar args, the
    # same shape ProcessDispatch pickles per task
    payload = pickle.dumps((apps.spin, (100.0,)), protocol=4)
    entries = [(i, payload, f"cal[{i}]") for i in range(batch)]
    dones = [(i, 0.0, 0.0, DONE_NO_RESULT, b"") for i in range(batch)]

    # codec-only legs, amortized per task (no transport)
    reps = 2000
    t0 = time.perf_counter()
    for _ in range(reps):
        decode_submit_batch(encode_submit_batch(entries))
    sub_codec_us = (time.perf_counter() - t0) / (reps * batch) * 1e6
    t0 = time.perf_counter()
    for _ in range(reps):
        decode_done_batch(encode_done_batch(dones))
    done_codec_us = (time.perf_counter() - t0) / (reps * batch) * 1e6

    # real round-trips against a forked echo child
    try:
        ctx = multiprocessing.get_context("fork")
    except ValueError:                   # pragma: no cover - non-POSIX
        ctx = multiprocessing.get_context()
    exec_fbq, done_fbq = ctx.SimpleQueue(), ctx.SimpleQueue()
    ex = ShmRing(1 << 16, fallback=exec_fbq)
    dn = ShmRing(1 << 16, fallback=done_fbq)
    child = ctx.Process(target=_ipc_echo_child,
                        args=(ex.name, dn.name, exec_fbq, done_fbq),
                        daemon=True)
    child.start()
    try:
        frame = serial.frame_exec(entries)

        def roundtrip(n: int) -> float:
            t0 = time.perf_counter()
            for _ in range(n):
                ex.push(frame)
                while dn.pop() is None:
                    time.sleep(1e-6)     # deschedule: don't starve the
                                         # child of the core (see child)
            return (time.perf_counter() - t0) / n * 1e6

        roundtrip(max(20, rounds // 10))           # warm-up
        rtt_us = roundtrip(rounds)
    finally:
        try:
            ex.push(serial.frame_ctrl(serial.OP_SHUTDOWN))
        except BufferError:              # pragma: no cover - dead child
            pass
        child.join(timeout=5.0)
        if child.is_alive():             # pragma: no cover - dead child
            child.terminate()
            child.join(timeout=1.0)
        ex.close()
        dn.close()
        ex.unlink()
        dn.unlink()

    rtt_task_us = rtt_us / batch
    transport_us = max(0.0, rtt_task_us - sub_codec_us - done_codec_us)
    return {
        "ipc_submit_us": sub_codec_us + transport_us / 2,
        "ipc_done_us": done_codec_us + transport_us / 2,
        "rtt_task_us": rtt_task_us,
        "sub_codec_us": sub_codec_us,
        "done_codec_us": done_codec_us,
        "batch": batch,
        "rounds": rounds,
    }


def lock_contention(num_workers: int = 4, tasks: int = 600) -> dict:
    """Real threads: same independent-task workload under sync vs ddast,
    plus the sharded manager with delegated vs blocking shard locks
    (informational — wall-clock on real threads is noisy; the sim sweep
    is the gated comparison). Reports lock acquisitions + wait time;
    the sharded rows add handoffs and delegated-portion counts."""
    out = {}

    def spin():
        x = 0.0
        for i in range(200):
            x += i * i
        return x

    for mode in ("sync", "ddast"):
        with TaskRuntime(num_workers=num_workers, mode=mode) as rt:
            for i in range(tasks):
                rt.task(spin, deps=[((i % 97,), DepMode.INOUT)])
            rt.taskwait()
        out[mode] = {
            "lock_acq": rt.stats.lock_acquisitions,
            "lock_wait_ms": rt.stats.lock_wait_s * 1e3,
            "wall_s": rt.stats.wall_s,
            "msgs": rt.stats.messages_processed,
        }
    for deleg in (True, False):
        with TaskRuntime(num_workers=num_workers, mode="sharded",
                         num_shards=8, delegation=deleg) as rt:
            for i in range(tasks):
                rt.task(spin, deps=[((i % 97,), DepMode.INOUT)])
            rt.taskwait()
        st = rt.stats
        out["sharded+delegation" if deleg else "sharded+blocking"] = {
            "lock_acq": st.lock_acquisitions,
            "lock_wait_ms": (st.lock_wait_s
                             + sum(st.shard_lock_wait_s)) * 1e3,
            "wall_s": st.wall_s,
            "msgs": st.messages_processed,
            "handoffs": sum(st.shard_lock_handoffs),
            "delegated_portions": st.delegated_portions,
            "combined_drains": st.combined_drains,
        }
    return out


FULL = {
    "apps": {"matmul": 8, "sparselu": 10},
    "cores": (4, 16),
    "shards": 8,
}
SMOKE = {
    "apps": {"matmul": 6, "sparselu": 8},
    "cores": (16,),
    "shards": 8,
}


def run(csv_rows: list, smoke: bool = True, out: str = None) -> bool:
    """``benchmarks.run`` suite entry point (single-arg call = smoke
    config, like the sibling suites; the standalone CLI picks via
    ``--smoke``). Returns the combined delegation-gate verdict."""
    cfg = SMOKE if smoke else FULL
    cal = calibrate()
    for key, v in cal.items():
        csv_rows.append((f"calibrate.{key}", v, ""))
    por = calibrate_portion()
    csv_rows.append(("calibrate.portion_overhead_us",
                     por["portion_overhead_us"],
                     f"portions {por['portions_single']}->"
                     f"{por['portions_spread']}"))
    ipc = calibrate_ipc()
    for key in ("ipc_submit_us", "ipc_done_us"):
        csv_rows.append((f"calibrate.{key}", ipc[key],
                         f"rtt/task={ipc['rtt_task_us']:.2f}us "
                         f"batch={ipc['batch']}"))
    dele = calibrate_delegation()
    for key in ("delegate_us", "combine_us"):
        csv_rows.append((f"calibrate.{key}", dele[key], ""))
    lc = lock_contention()
    for mode, st in lc.items():
        csv_rows.append((f"contention.{mode}.lock_wait_ms",
                         st["lock_wait_ms"],
                         f"acq={st['lock_acq']} msgs={st['msgs']}"))
    sweep, gates = delegation_sweep(cfg)
    for rec in sweep:
        tag = "delegation" if rec["delegation"] else "blocking"
        csv_rows.append(
            (f"contention.sim.{rec['app']}.p{rec['cores']}.{tag}"
             f".lock_wait_us", rec["lock_wait_us"],
             f"handoffs={rec['lock_handoffs']} "
             f"portions={rec['delegated_portions']}"))
    gates["ok"] = all(gates.values())
    csv_rows.append(("contention.gates.ok", int(gates["ok"]), str(gates)))
    if out:
        with open(out, "w") as f:
            json.dump({"calibrate": {**cal, **por, **ipc, **dele},
                       "lock_contention": lc, "delegation_sweep": sweep,
                       "gates": gates,
                       "config": {k: list(v) if isinstance(v, tuple)
                                  else v for k, v in cfg.items()}},
                      f, indent=2, default=str)
    return gates["ok"]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--calibrate", action="store_true",
                    help="measure the per-shard-portion overhead, the "
                         "process-backend IPC frame costs, and the "
                         "delegation fast-path costs on this host; "
                         "print the values to use for "
                         "SimCosts.portion_overhead / ipc_submit_us / "
                         "ipc_done_us / delegate_us / combine_us")
    ap.add_argument("--delegation", action="store_true",
                    help="run only the delegation-vs-blocking case: the "
                         "simulated cores x shards sweep (lock-wait + "
                         "delegated-portion ratio vs the blocking "
                         "baseline, with the ordering/0.7x gates) plus "
                         "the real-threaded sharded contention rows")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.calibrate:
        por = calibrate_portion()
        print(f"measured portion_overhead: "
              f"{por['portion_overhead_us']:.3f} us/portion "
              f"({por['portions_single']} -> {por['portions_spread']} "
              f"portions)")
        ipc = calibrate_ipc()
        print(f"measured ring round-trip: {ipc['rtt_task_us']:.3f} "
              f"us/task (batch={ipc['batch']}, {ipc['rounds']} rounds)")
        print(f"  submit leg: {ipc['ipc_submit_us']:.3f} us "
              f"(codec {ipc['sub_codec_us']:.3f})   "
              f"done leg: {ipc['ipc_done_us']:.3f} us "
              f"(codec {ipc['done_codec_us']:.3f})")
        dele = calibrate_delegation()
        print(f"measured delegation: delegate {dele['delegate_us']:.3f} "
              f"us/publication, combine session "
              f"{dele['combine_us']:.3f} us "
              f"(t(1)={dele['combine_t1_us']:.3f}, "
              f"t(16)={dele['combine_t16_us']:.3f})")
        print(f"suggested: SimCosts(portion_overhead="
              f"{por['portion_overhead_us']:.2f}, "
              f"ipc_submit_us={ipc['ipc_submit_us']:.2f}, "
              f"ipc_done_us={ipc['ipc_done_us']:.2f}, "
              f"delegate_us={dele['delegate_us']:.2f}, "
              f"combine_us={dele['combine_us']:.2f})")
        return 0
    if args.delegation:
        cfg = SMOKE if args.smoke else FULL
        sweep, gates = delegation_sweep(cfg)
        for rec in sweep:
            tag = "delegation" if rec["delegation"] else "blocking"
            print(f"sim.{rec['app']}.p{rec['cores']}x{rec['shards']}."
                  f"{tag:10s} lock_wait={rec['lock_wait_us']:10.1f}us "
                  f"portions={rec['delegated_portions']:5d} "
                  f"handoffs={rec['lock_handoffs']}")
        lc = lock_contention()
        for mode in ("sharded+delegation", "sharded+blocking"):
            st = lc[mode]
            print(f"real.{mode:22s} lock_wait={st['lock_wait_ms']:8.3f}ms "
                  f"portions={st['delegated_portions']:5d} "
                  f"handoffs={st['handoffs']}")
        gates["ok"] = all(gates.values())
        print(f"# gates {'PASS' if gates['ok'] else 'FAIL'}: {gates}")
        return 0 if gates["ok"] else 1
    rows: list = []
    ok = run(rows, smoke=args.smoke, out=args.out)
    for name, value, note in rows:
        print(f"{name:52s} {value:10.4f}  {note}")
    print(f"# gates {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
