"""Paper §1 motivation + simulator calibration: measure the REAL threaded
runtime's critical-section costs and lock contention on this host.

Emits the µs-scale constants that SimCosts defaults are calibrated from,
plus lock-wait statistics for sync vs ddast with real threads."""
from __future__ import annotations

import time

import numpy as np

from repro.core import DDASTParams, TaskRuntime
from repro.core.depgraph import DependenceGraph
from repro.core.queues import SPSCQueue
from repro.core.wd import DepMode, WorkDescriptor


def calibrate() -> dict:
    """Single-thread microbenchmarks of the runtime primitives."""
    n = 20_000
    # WD creation
    t0 = time.perf_counter()
    wds = [WorkDescriptor(func=None, deps=((("r", i % 64), DepMode.INOUT),))
           for i in range(n)]
    create_us = (time.perf_counter() - t0) / n * 1e6
    # queue push/pop
    q = SPSCQueue()
    t0 = time.perf_counter()
    for w in wds:
        q.push(w)
    push_us = (time.perf_counter() - t0) / n * 1e6
    # graph submit / complete
    g = DependenceGraph()
    t0 = time.perf_counter()
    for w in wds:
        g.submit(w)
    submit_us = (time.perf_counter() - t0) / n * 1e6
    t0 = time.perf_counter()
    for w in wds:
        g.complete(w)
    done_us = (time.perf_counter() - t0) / n * 1e6
    return {"create_us": create_us, "push_us": push_us,
            "submit_cs_us": submit_us, "done_cs_us": done_us}


def lock_contention(num_workers: int = 4, tasks: int = 600) -> dict:
    """Real threads: same independent-task workload under sync vs ddast;
    report graph-lock acquisitions + wait time."""
    out = {}

    def spin():
        x = 0.0
        for i in range(200):
            x += i * i
        return x

    for mode in ("sync", "ddast"):
        with TaskRuntime(num_workers=num_workers, mode=mode) as rt:
            for i in range(tasks):
                rt.task(spin, deps=[((i % 97,), DepMode.INOUT)])
            rt.taskwait()
        out[mode] = {
            "lock_acq": rt.stats.lock_acquisitions,
            "lock_wait_ms": rt.stats.lock_wait_s * 1e3,
            "wall_s": rt.stats.wall_s,
            "msgs": rt.stats.messages_processed,
        }
    return out


def run(csv_rows: list) -> None:
    cal = calibrate()
    for k, v in cal.items():
        csv_rows.append((f"calibrate.{k}", v, ""))
    lc = lock_contention()
    for mode, st in lc.items():
        csv_rows.append((f"contention.{mode}.lock_wait_ms",
                         st["lock_wait_ms"],
                         f"acq={st['lock_acq']} msgs={st['msgs']}"))
