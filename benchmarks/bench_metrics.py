"""Live-metrics-plane overhead + live-detector agreement gates.

Three sections:

  * **sim overhead** — the acceptance matmul (nb=16, 400 us bodies, 16
    simulated cores) with ``metrics=False`` vs ``metrics=True``; every
    instrument stamp and sampler tick is priced in virtual time
    (``SimCosts.metric_event`` / ``metric_sample``), so the makespan
    delta is the honest, deterministic cost of the metrics plane.
  * **threads overhead** — the same claim on the real threads driver:
    interleaved base/metrics repeats (median of each) on a
    sleep-bodied task sweep. Wall-clock on a shared host is noisy, so
    the gate is enforced only with enough cores to parallelize
    (reported, not enforced, elsewhere — the bench_procs precedent).
  * **live detector agreement** — the incremental detector the sampler
    runs mid-phase (``core.trace.IncrementalDetector``) swept
    chunk-by-chunk over a fabricated starvation timeline must find the
    same verdict set as one post-hoc ``detect_all`` pass: live
    feedback may arrive earlier, never different.

Standalone:

    PYTHONPATH=src python benchmarks/bench_metrics.py           # full
    PYTHONPATH=src python benchmarks/bench_metrics.py --smoke   # CI
    ... [--out BENCH_metrics.json]

or as a suite inside ``python -m benchmarks.run --only metrics``.

Exit status is the CI gate: non-zero when either enforced overhead
exceeds ``GATE['overhead_pct_max']`` % of makespan or the live sweep
disagrees with the post-hoc detectors.
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import RuntimeSimulator, TaskRuntime  # noqa: E402
from repro.core.taskgraph_apps import sim_matmul_specs  # noqa: E402
from repro.core.trace import (EV_END, EV_READY, EV_START,  # noqa: E402
                              IncrementalDetector, TraceEvent,
                              detect_all)

# the acceptance workload: nb=16 matmul (400 us bodies) on 16 cores
GATE = {"nb": 16, "dur_us": 400.0, "cores": 16, "mode": "ddast",
        "overhead_pct_max": 2.0,
        # real-clock threads gate needs real parallelism to be stable
        "threads_min_cores": 4}

FULL = {"threads_tasks": 600, "threads_repeats": 13}
SMOKE = {"threads_tasks": 300, "threads_repeats": 9}


# ------------------------------------------------------- sim overhead
def sim_overhead() -> dict:
    """Same graph, metrics off vs on; virtual-time priced, so the
    delta is deterministic and host-independent."""
    specs = sim_matmul_specs(GATE["nb"], dur_us=GATE["dur_us"])
    base = RuntimeSimulator(GATE["cores"], GATE["mode"]).run(specs)
    lively = RuntimeSimulator(GATE["cores"], GATE["mode"],
                              metrics=True).run(specs)
    pct = (lively.makespan_us / base.makespan_us - 1.0) * 100.0
    samp = (lively.metrics or {}).get("sampler", {})
    return {
        "nb": GATE["nb"], "cores": GATE["cores"], "mode": GATE["mode"],
        "base_makespan_us": round(base.makespan_us, 1),
        "metrics_makespan_us": round(lively.makespan_us, 1),
        "samples": samp.get("samples", 0),
        "series": len(samp.get("series", {})),
        "overhead_pct": round(pct, 3),
    }


# --------------------------------------------------- threads overhead
def _threads_run(metrics: bool, tasks: int, workers: int) -> float:
    t0 = time.perf_counter()
    with TaskRuntime(num_workers=workers, mode="ddast",
                     metrics=metrics) as rt:
        for i in range(tasks):
            rt.task(time.sleep, 4e-4, label=f"t{i}")
        rt.taskwait()
    return time.perf_counter() - t0


def threads_overhead(cfg: dict) -> dict:
    """Interleaved base/metrics repeats: interleaving makes both
    populations see the same host drift. The gate uses the min of each
    population — sleep-bodied makespans carry additive scheduler
    noise (timer quantization swings single pairs by several %), and
    min is the standard robust estimator for the noise-free floor;
    the median is reported alongside."""
    workers = min(GATE["cores"], os.cpu_count() or 1)
    tasks = cfg["threads_tasks"]
    _threads_run(False, tasks // 4, workers)          # warm-up
    base, lively = [], []
    for _ in range(cfg["threads_repeats"]):
        base.append(_threads_run(False, tasks, workers))
        lively.append(_threads_run(True, tasks, workers))
    pct = (min(lively) / min(base) - 1.0) * 100.0
    med_pct = (statistics.median(lively) / statistics.median(base)
               - 1.0) * 100.0
    # noise guard: when the BASE population alone spreads wider than
    # the gate threshold, the host cannot resolve a 2% delta — report
    # the number, skip enforcement (the bench_procs precedent)
    noise_pct = (max(base) / min(base) - 1.0) * 100.0
    return {
        "workers": workers, "tasks": tasks,
        "repeats": cfg["threads_repeats"],
        "base_min_s": round(min(base), 4),
        "metrics_min_s": round(min(lively), 4),
        "overhead_pct": round(pct, 3),
        "median_overhead_pct": round(med_pct, 3),
        "host_noise_pct": round(noise_pct, 3),
        "enforced": (os.cpu_count() or 1) >= GATE["threads_min_cores"]
        and noise_pct <= GATE["overhead_pct_max"],
    }


# ------------------------------------------- live detector agreement
def _mk(t, ev, wd_id=-1, slot=-1, label="", scope=None, data=None):
    return TraceEvent(t, ev, wd_id, slot, label, scope, data)


def _starvation_timeline() -> list:
    """The detector test suite's oracle: workers 0/1 warm up, slot 1's
    deque piles 5 ready tasks while slot 0 idles the whole span."""
    evs = [
        _mk(0.0, EV_START, wd_id=900, slot=0, label="warm"),
        _mk(0.1, EV_END, wd_id=900, slot=0, label="warm"),
        _mk(0.0, EV_START, wd_id=901, slot=1, label="warm"),
        _mk(0.1, EV_END, wd_id=901, slot=1, label="warm"),
    ]
    for i in range(5):
        evs.append(_mk(1.0 + i * 0.01, EV_READY, wd_id=i, slot=1,
                       label=f"t{i}"))
    evs.append(_mk(100.0, EV_END, wd_id=901, slot=1))   # span closer
    return evs


def detector_agreement() -> dict:
    """Sweep the incremental detector over growing prefixes (what the
    sampler does tick by tick) and compare its accumulated verdicts
    against one post-hoc pass over the full timeline."""
    evs = _starvation_timeline()
    posthoc = detect_all(evs)
    det = IncrementalDetector()
    live: list = []
    for cut in range(2, len(evs) + 1, 2):
        live.extend(det.sweep(evs[:cut]))
    if len(evs) % 2:
        live.extend(det.sweep(evs))
    key = lambda f: (f.kind, round(f.t0, 9), f.slot)  # noqa: E731
    live_keys = {key(f) for f in live}
    post_keys = {key(f) for f in posthoc}
    return {
        "posthoc_findings": sorted(f.kind for f in posthoc),
        "live_findings": sorted(f.kind for f in live),
        "live_duplicates": len(live) - len(live_keys),
        "agrees": live_keys == post_keys and len(live) == len(live_keys)
        and bool(post_keys),
    }


# ----------------------------------------------------------- assembly
def acceptance(sim: dict, threads: dict, agree: dict) -> dict:
    mx = GATE["overhead_pct_max"]
    return {
        "overhead_pct_max": mx,
        "sim_overhead_pct": sim["overhead_pct"],
        "sim_ok": sim["overhead_pct"] <= mx,
        "threads_overhead_pct": threads["overhead_pct"],
        "threads_gate_enforced": threads["enforced"],
        "threads_ok": threads["overhead_pct"] <= mx,
        "detector_agreement_ok": agree["agrees"],
        "cores": os.cpu_count() or 1,
    }


def collect(smoke: bool) -> dict:
    cfg = SMOKE if smoke else FULL
    t0 = time.time()
    sim = sim_overhead()
    threads = threads_overhead(cfg)
    agree = detector_agreement()
    return {
        "bench": "metrics",
        "smoke": smoke,
        "sim_overhead": sim,
        "threads_overhead": threads,
        "detector_agreement": agree,
        "acceptance": acceptance(sim, threads, agree),
        "bench_wall_s": round(time.time() - t0, 2),
    }


def run(csv_rows: list) -> None:
    """benchmarks.run suite entry point."""
    out = collect(smoke=True)
    acc = out["acceptance"]
    csv_rows.append(("metrics.sim.overhead_pct",
                     acc["sim_overhead_pct"],
                     f"gate<={acc['overhead_pct_max']}% on "
                     f"{GATE['cores']}-core nb{GATE['nb']} matmul"))
    csv_rows.append(("metrics.threads.overhead_pct",
                     acc["threads_overhead_pct"],
                     f"enforced={int(acc['threads_gate_enforced'])}"))
    csv_rows.append(("metrics.detector_agreement",
                     int(acc["detector_agreement_ok"]),
                     "live sweep == post-hoc detect_all"))
    csv_rows.append(("metrics.sim.samples",
                     out["sim_overhead"]["samples"],
                     f"series={out['sim_overhead']['series']}"))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fewer threads repeats, same gates (CI)")
    ap.add_argument("--out", default="BENCH_metrics.json",
                    help="JSON output path")
    args = ap.parse_args()
    out = collect(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    acc = out["acceptance"]
    print(f"wrote {args.out} ({out['bench_wall_s']}s)")
    mx = acc["overhead_pct_max"]
    failed = False
    print(f"sim metrics overhead {acc['sim_overhead_pct']}% of makespan"
          f" on {GATE['cores']}-core nb{GATE['nb']} matmul (max {mx}%)"
          f" -> {'OK' if acc['sim_ok'] else 'REGRESSION'}")
    failed |= not acc["sim_ok"]
    if acc["threads_gate_enforced"]:
        print(f"threads metrics overhead {acc['threads_overhead_pct']}%"
              f" (max {mx}%) -> "
              f"{'OK' if acc['threads_ok'] else 'REGRESSION'}")
        failed |= not acc["threads_ok"]
    else:
        noise = out["threads_overhead"]["host_noise_pct"]
        print(f"threads overhead gate: SKIPPED ({acc['cores']} core(s),"
              f" host noise {noise}% — measured "
              f"{acc['threads_overhead_pct']}%; enforced on quiet "
              f"multi-core hosts)")
    print("live-vs-posthoc detector agreement -> "
          + ("OK" if acc["detector_agreement_ok"] else "REGRESSION"))
    failed |= not acc["detector_agreement_ok"]
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
