"""Benchmark harness — one module per paper table/figure + the roofline
table from the dry-run artifacts. Prints ``name,value,derived`` CSV;
``--summary`` additionally writes every row (all suites consolidated)
as one JSON artifact for CI upload and cross-run diffing.

  PYTHONPATH=src python -m benchmarks.run [--only contention,...]
      [--summary BENCH_summary.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

sys.path.insert(0, "src")

from benchmarks import (bench_chaos, bench_contention,  # noqa: E402
                        bench_metrics, bench_procs, bench_replay,
                        bench_roofline, bench_scalability, bench_sched,
                        bench_scopes, bench_shards, bench_traces,
                        bench_tuning)

SUITES = {
    "contention": bench_contention.run,     # §1 motivation + calibration
    "tuning": bench_tuning.run,             # Figs 5-8 / Table 5
    "scalability": bench_scalability.run,   # Figs 9-11
    "traces": bench_traces.run,             # Figs 12-14
    "roofline": bench_roofline.run,         # §Roofline table
    "shards": bench_shards.run,             # sharded manager sweep
    "replay": bench_replay.run,             # record-and-replay vs live
    "sched": bench_sched.run,               # placement x replay sweep
    "scopes": bench_scopes.run,             # multi-tenant scopes
    "procs": bench_procs.run,               # multi-process GIL escape
    "chaos": bench_chaos.run,               # fault-tolerance recovery
    "metrics": bench_metrics.run,           # live metrics plane
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--summary", default=None, metavar="PATH",
                    help="also write all rows as one JSON artifact")
    args = ap.parse_args()
    names = list(SUITES) if not args.only else args.only.split(",")
    rows: list = []
    summary: list = []
    print("name,value,derived")
    for name in names:
        t0 = time.time()
        SUITES[name](rows)
        rows.append((f"{name}.bench_wall_s", round(time.time() - t0, 1), ""))
        while rows:
            n, v, d = rows.pop(0)
            print(f"{n},{v},{d}", flush=True)
            summary.append({"name": n, "value": v, "derived": d,
                            "suite": name})
    if args.summary:
        with open(args.summary, "w") as f:
            json.dump({"suites": names, "rows": summary}, f, indent=1)


if __name__ == "__main__":
    main()
