"""The paper's hardest benchmark (Sparse LU, irregular dependence graph)
on the DDAST runtime, validated against a sequential oracle, plus the
same workload in the virtual-time simulator at 64 cores.

    PYTHONPATH=src python examples/sparselu_taskgraph.py
"""
import sys
sys.path.insert(0, "src")

import numpy as np
from repro.core import RuntimeSimulator, TaskRuntime
from repro.core.taskgraph_apps import (run_sparselu, sim_sparselu_specs,
                                       sparselu_oracle)

n, bs = 128, 32
m = np.random.rand(n, n).astype(np.float32) + np.eye(n, dtype=np.float32) * n

with TaskRuntime(num_workers=2, mode="ddast", trace=True) as rt:
    lu = run_sparselu(rt, m, bs)
ref = sparselu_oracle(m, bs)
print(f"real run: {rt.stats.tasks_executed} tasks, "
      f"max err {np.abs(lu - ref).max():.2e}, "
      f"peak in-graph {rt.stats.max_in_graph}")

for mode in ("sync", "ddast"):
    r = RuntimeSimulator(num_cores=64, mode=mode).run(sim_sparselu_specs(16))
    print(f"sim 64-core {mode:6s}: speedup {r.speedup:.1f} "
          f"(lock wait {r.lock_wait_us:.0f} us, peak graph {r.max_in_graph})")
