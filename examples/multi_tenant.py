"""Multi-tenant scopes: N client threads share ONE runtime.

Each client opens a JobScope — its own root context, dependence
namespace, record-and-replay slot, and weighted-fair share of
admission — and iterates its own taskgraph. After iteration 1 each
scope's recording freezes and further iterations replay with zero
locks and zero messages, independently per tenant.

    PYTHONPATH=src python examples/multi_tenant.py
"""
import sys
sys.path.insert(0, "src")

import threading

import numpy as np
from repro.core import TaskRuntime
from repro.core.taskgraph_apps import run_matmul_epochs

N_CLIENTS = 3
EPOCHS = 4
rng = np.random.default_rng(0)
a = rng.standard_normal((32, 32)).astype(np.float32)
b = rng.standard_normal((32, 32)).astype(np.float32)

with TaskRuntime(num_workers=4, mode="sharded", num_shards=8,
                 num_clients=N_CLIENTS, replay=True) as rt:
    outs = {}

    def client(idx: int) -> None:
        # heavier tenants get a bigger share of ready-task admission
        weight = float(N_CLIENTS - idx)
        with rt.open_scope(f"tenant{idx}", weight=weight) as sc:
            # inside the scope, plain rt.task()/rt.taskwait() land here:
            # each epoch re-submits the same graph, so epochs 2..N replay
            outs[idx] = run_matmul_epochs(rt, a, b, bs=8, epochs=EPOCHS)

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(N_CLIENTS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

ref = EPOCHS * (a.astype(np.float64) @ b.astype(np.float64))
for i, out in sorted(outs.items()):
    assert np.allclose(out, ref, atol=1e-2), f"tenant{i} wrong result"

print(f"{rt.stats.tasks_executed} tasks across {N_CLIENTS} tenants, "
      f"{rt.stats.replay_iterations} replayed iterations total")
for name, st in rt.stats.scopes.items():
    print(f"  {name}: {st['tasks']} tasks, weight {st['weight']:.0f}, "
          f"replay iters {st['replay_iterations']} "
          f"({st['replayed_tasks']} tasks analysis-free), "
          f"admitted {st['admitted']} "
          f"(waited {st['admission_waits']}x on admission)")
