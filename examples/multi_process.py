"""Multi-process backend: the same task API, bodies that really run in
parallel.

CPython threads share one GIL, so a CPU-bound task body (pure
arithmetic, no I/O, no numpy kernel) serializes the whole pool no
matter how clean the runtime's locking is. ``backend="processes"``
keeps the paper's runtime organization — sharded managers, Submit/Done
batches, record-and-replay — but executes bodies in worker *processes*,
shipping the §3.1 message shapes over shared-memory ring mailboxes.

Task data crosses the address-space boundary by name: kernels take the
names of ``multiprocessing.shared_memory`` blocks (see
``repro.core.procs.apps``) instead of closing over arrays.

    PYTHONPATH=src python examples/multi_process.py

Writes ``multi_process.trace`` + ``multi_process.trace.json`` — open
the JSON in Perfetto (https://ui.perfetto.dev) to see worker-process
lanes actually overlapping.
"""
import sys
import time

sys.path.insert(0, "src")

from repro.analysis import traceview
from repro.core import TaskRuntime
from repro.core.procs import apps

# -- 1. escape the GIL: identical CPU-bound graph, both backends --------
# 4 independent inout chains of pure-arithmetic spin tasks; threads
# serialize on the GIL, processes spread the chains over cores.
CHAINS, CHAIN_LEN, SPIN_US = 4, 6, 2000.0

for backend in ("threads", "processes"):
    with TaskRuntime(num_workers=4, mode="sharded", backend=backend) as rt:
        t0 = time.perf_counter()
        for c in range(CHAINS):
            for i in range(CHAIN_LEN):
                rt.task(apps.spin, SPIN_US,
                        deps=[(("chain", c), "inout")],
                        label=f"spin[{c},{i}]")
        rt.taskwait()
        wall = time.perf_counter() - t0
    print(f"{backend:9s}: {rt.stats.tasks_executed} CPU-bound tasks "
          f"in {wall*1e3:6.1f} ms")

# -- 2. real data through shared memory, checked against a serial oracle
# N-Body step: force rows read every position, update rows are
# order-sensitive multiply-accumulates — any ordering violation by the
# process backend would change the floats.
n = 12
P, V, A = apps.ShmArray(n), apps.ShmArray(n), apps.ShmArray(n)
P2, V2, A2 = apps.ShmArray(n), apps.ShmArray(n), apps.ShmArray(n)
for arr, arr2, seed in ((P, P2, 1), (V, V2, 2)):
    apps.fill_deterministic(arr, seed)
    apps.fill_deterministic(arr2, seed)

try:
    with TaskRuntime(num_workers=2, mode="sharded", trace=True,
                     backend="processes") as rt:
        calls = apps.submit_nbody(rt, P.name, V.name, A.name, n, steps=2)
        rt.taskwait()
    # serial oracle: same kernels, submission order, in-process,
    # against the twin arrays (remap the shm names in the args)
    twin = {P.name: P2.name, V.name: V2.name, A.name: A2.name}
    apps.run_serial([(f, tuple(twin.get(x, x) for x in a), d, l)
                     for f, a, d, l in calls])
    exact = all(P[i] == P2[i] and V[i] == V2[i] for i in range(n))
    print(f"processes: n-body x2 steps, {rt.stats.tasks_executed} tasks, "
          f"oracle match: {'EXACT' if exact else 'MISMATCH'}")

    # -- 3. export the merged multi-process trace ----------------------
    # worker events are stamped in the worker process against a shared
    # monotonic epoch, shipped at shutdown, and merged by the recorder
    rt.tracer.save("multi_process.trace")
    out = traceview.export("multi_process.trace")
    print(f"trace: multi_process.trace -> {out} "
          f"({len(rt.stats.events)} events; open in Perfetto)")
finally:
    for arr in (P, V, A, P2, V2, A2):
        arr.close_unlink()
