"""Serve a small model with batched requests: the continuous-batching
engine whose request scheduler IS the paper's DDAST callback (per-client
SPSC queues drained round-robin with MAX_OPS_THREAD / MIN_READY rules).

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys
sys.path.insert(0, "src")

from repro.launch.serve import serve

out = serve("qwen2-0.5b", num_requests=24, clients=4, slots=6, max_new=12)
print(f"{out['requests']} requests -> {out['tokens']} tokens in "
      f"{out['wall_s']:.1f}s ({out['tok_per_s']:.0f} tok/s, "
      f"{out['engine_steps']} engine steps)")
print("scheduler:", out["stats"])
