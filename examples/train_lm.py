"""End-to-end driver: train a reduced qwen2-0.5b for a few hundred steps
on CPU with the DDAST host runtime (idle threads prefetch data and flush
checkpoints), then resume from the checkpoint to prove exact restart.

    PYTHONPATH=src python examples/train_lm.py
"""
import sys
sys.path.insert(0, "src")

from repro.launch.train import train

out = train("qwen2-0.5b", tiny=True, steps=200, batch=8, seq=128,
            ckpt_dir="/tmp/repro_example_ckpt", schedule_steps=200)
print(f"loss {out['losses'][0]:.3f} -> {out['final_loss']:.3f} "
      f"({out['prefetch_async']} async prefetches, "
      f"{out['ckpt_writes']} async checkpoint writes)")
out2 = train("qwen2-0.5b", tiny=True, steps=220, batch=8, seq=128,
             ckpt_dir="/tmp/repro_example_ckpt", schedule_steps=200)
print(f"resumed and continued to {len(out2['losses'])} more steps, "
      f"final loss {out2['final_loss']:.3f}")
