"""Quickstart: the paper's task API in 20 lines.

Annotate work as tasks with data dependences (in/out/inout regions); the
runtime orders them. Pick the organization with `mode`:
  sync  = Nanos++-style (workers mutate the graph under a lock)
  ddast = the paper (workers enqueue requests; idle threads manage).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

import numpy as np
from repro.core import TaskRuntime
from repro.core.taskgraph_apps import run_matmul

a = np.random.rand(128, 128).astype(np.float32)
b = np.random.rand(128, 128).astype(np.float32)

for mode in ("sync", "ddast"):
    with TaskRuntime(num_workers=2, mode=mode) as rt:
        c = run_matmul(rt, a, b, bs=32)
    err = np.abs(c - a @ b).max()
    print(f"{mode:6s}: {rt.stats.tasks_executed} tasks, "
          f"lock wait {rt.stats.lock_wait_s*1e3:.2f} ms, "
          f"{rt.stats.messages_processed} messages, max err {err:.2e}")
