"""Task dependence graph (paper §2.2.1 / §3).

Region-based dependence tracking equivalent to Nanos++'s "regions" plugin
restricted to whole-region aliases (the granularity used by all three paper
benchmarks: one region per matrix block / particle block).

Per region the graph keeps the *last writer* and the *readers since the last
write*. Predecessor rules (classic task-dataflow):

  IN    dep -> predecessor is the last writer (RAW)
  OUT   dep -> predecessors are last writer (WAW) + readers since (WAR)
  INOUT dep -> both of the above

The graph is NOT internally synchronized. Callers serialize access:
 - sync (Nanos++-like) mode: a single spinlock around every graph operation;
 - ddast mode: manager threads, with per-worker Submit-queue exclusivity,
   are the only mutators (paper §3.1).

The graph also records instrumentation the paper plots (Figs 12-14): the
number of in-graph tasks over time and the high-water mark.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set

from .wd import DepMode, TaskState, WorkDescriptor


@dataclass
class _RegionState:
    last_writer: Optional[WorkDescriptor] = None
    readers: List[WorkDescriptor] = field(default_factory=list)


def collect_preds_and_register(regions: Dict[Any, _RegionState],
                               wd: WorkDescriptor, deps) -> set:
    """The RAW/WAW/WAR predecessor rules over a region-state map:
    collect `wd`'s predecessors from `deps` ((key, mode) pairs), then
    register `wd` as last-writer/reader. Shared by DependenceGraph
    (keys = regions) and shards.GraphShard (keys = (parent_id, region))
    so the dependence semantics live in exactly one place."""
    preds = set()
    for key, mode in deps:
        st = regions.get(key)
        if st is None:
            st = regions[key] = _RegionState()
        if mode.reads and st.last_writer is not None:
            preds.add(st.last_writer)
        if mode.writes:
            if st.last_writer is not None:
                preds.add(st.last_writer)
            preds.update(st.readers)
        # register wd on the region *after* collecting preds
        if mode.writes:
            st.last_writer = wd
            st.readers = []
        elif mode.reads:
            st.readers.append(wd)
    preds.discard(wd)
    return preds


def scrub_regions(regions: Dict[Any, _RegionState],
                  wd: WorkDescriptor, deps) -> None:
    """Remove a completed `wd` from the region records (shared by
    DependenceGraph and shards.GraphShard)."""
    for key, mode in deps:
        st = regions.get(key)
        if st is None:
            continue
        if st.last_writer is wd:
            st.last_writer = None
        if mode.reads and wd in st.readers:
            st.readers.remove(wd)
        if st.last_writer is None and not st.readers:
            del regions[key]


class DependenceGraph:
    """Graph of sibling tasks (one instance per parent WD, paper §2.2.1)."""

    def __init__(self) -> None:
        self._regions: Dict[Any, _RegionState] = {}
        self.in_graph: int = 0           # tasks submitted, not yet completed
        self.max_in_graph: int = 0
        self.total_submitted: int = 0
        self.total_edges: int = 0

    # ------------------------------------------------------------------
    def submit(self, wd: WorkDescriptor) -> bool:
        """Insert `wd`, computing predecessors from its deps.

        Returns True iff the task is immediately ready (no pending preds).
        Must be called in task-creation order for siblings (the Submit
        queue ordering invariant of §3.1).
        """
        preds: Set[WorkDescriptor] = collect_preds_and_register(
            self._regions, wd, wd.deps)
        live_preds = [p for p in preds
                      if p.state not in (TaskState.COMPLETED, TaskState.DELETED)]
        wd.num_predecessors = len(live_preds)
        for p in live_preds:
            p.successors.append(wd)
        self.total_edges += len(live_preds)
        self.in_graph += 1
        self.total_submitted += 1
        self.max_in_graph = max(self.max_in_graph, self.in_graph)
        wd.state = TaskState.SUBMITTED
        if wd.num_predecessors == 0:
            wd.mark_ready()
            return True
        return False

    # ------------------------------------------------------------------
    def complete(self, wd: WorkDescriptor) -> List[WorkDescriptor]:
        """Handle task finalization: remove `wd` from the graph, decrement
        successors, return the list of tasks that became ready."""
        newly_ready: List[WorkDescriptor] = []
        for succ in wd.successors:
            succ.num_predecessors -= 1
            if succ.num_predecessors == 0 and succ.state == TaskState.SUBMITTED:
                succ.mark_ready()
                newly_ready.append(succ)
        wd.successors = []
        # Scrub region records pointing at the completed task so the maps
        # do not grow without bound (region count is bounded by live data).
        scrub_regions(self._regions, wd, wd.deps)
        self.in_graph -= 1
        wd.mark_completed()
        return newly_ready
