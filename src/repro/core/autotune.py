"""Dynamic DDAST parameter tuning — the paper's stated future work (§8:
"the runtime manager will dynamically tune its parameters to fit the
application requirements").

A feedback controller registered as a (low-priority) Functionality
Dispatcher callback: idle threads occasionally sample runtime pressure
and adjust the DDASTParams in place:

  * queue backlog grows & ready pool starving -> more manager threads
    (up to num_threads/2) and bigger MAX_OPS_THREAD drains;
  * queues near-empty -> decay managers toward the tuned static default
    (num_threads/8) to recover locality (paper §5.1's finding).

All adjustments are bounded and hysteretic so the controller cannot
oscillate; the tuned static defaults remain the fixed point under calm
load.
"""
from __future__ import annotations

import math
import threading
import time
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, List, Tuple

from .ddast import DDASTParams

if TYPE_CHECKING:  # pragma: no cover
    from .runtime import TaskRuntime


@dataclass
class TunerConfig:
    interval_s: float = 0.002       # min time between adjustments
    backlog_high: int = 32          # pending msgs per worker: pressure
    backlog_low: int = 2
    ops_step: int = 4
    max_ops: int = 64


class DynamicTuner:
    def __init__(self, runtime: "TaskRuntime",
                 cfg: TunerConfig = TunerConfig()) -> None:
        self.rt = runtime
        self.cfg = cfg
        self._last = 0.0
        self._lock = threading.Lock()
        self.adjustments: List[Tuple[float, int, int]] = []
        p = runtime.params
        self._static_mgr = p.resolved_max_threads(runtime.num_workers)
        # ensure an explicit, mutable starting point
        if p.max_ddast_threads is None:
            p.max_ddast_threads = self._static_mgr
        runtime.dispatcher.register("ddast-autotune", self.callback,
                                    priority=0)

    # -- dispatcher callback --------------------------------------------
    def callback(self, worker_id: int) -> None:
        del worker_id
        now = time.perf_counter()
        with self._lock:
            if now - self._last < self.cfg.interval_s:
                return
            self._last = now
        rt, p, c = self.rt, self.rt.params, self.cfg
        n = rt.num_workers
        backlog = rt._pending_msgs() / max(n, 1)
        ready = rt.ready_count()
        mgr_cap = max(1, n // 2)
        if backlog > c.backlog_high and ready < p.min_ready_tasks:
            # pressure: the managers cannot keep up — widen the manager
            # pool and deepen per-queue drains
            p.max_ddast_threads = min(mgr_cap, p.max_ddast_threads + 1)
            p.max_ops_thread = min(c.max_ops, p.max_ops_thread + c.ops_step)
            self.adjustments.append((now, p.max_ddast_threads,
                                     p.max_ops_thread))
        elif backlog < c.backlog_low and \
                p.max_ddast_threads > self._static_mgr:
            # calm: shrink back toward the locality-friendly default
            p.max_ddast_threads -= 1
            p.max_ops_thread = max(8, p.max_ops_thread - c.ops_step)
            self.adjustments.append((now, p.max_ddast_threads,
                                     p.max_ops_thread))
