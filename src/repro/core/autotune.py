"""Dynamic DDAST parameter tuning — the paper's stated future work (§8:
"the runtime manager will dynamically tune its parameters to fit the
application requirements").

A feedback controller registered as a (low-priority) Functionality
Dispatcher callback: idle threads occasionally sample runtime pressure
and adjust the DDASTParams in place:

  * queue backlog grows & ready pool starving -> more manager threads
    (up to num_threads/2) and bigger MAX_OPS_THREAD drains;
  * queues near-empty -> decay managers toward the tuned static default
    (num_threads/8) to recover locality (paper §5.1's finding).

Since the unified policy engine, the tuner also hill-climbs the sharded
policy's ``num_shards`` online: at taskwait quiescence (the dispatcher's
``notify_quiescent`` hook — the only moment ``ShardedPolicy.resize`` is
legal) it reads the single ``ShardedPolicy.stats()`` dict, computes the
lock-wait cost per processed message since the previous adjustment, and
doubles/halves the shard count in the improving direction. Two
consecutive direction flips mean the optimum is bracketed and the
controller settles — the same bounded-hysteresis discipline as the
manager-thread loop, so it cannot oscillate.

With tracing on (``trace=True``), the tuner additionally closes the
observability loop: a quiescence hook runs the detrimental-pattern
detectors (``core.trace.detect``) over the events recorded since the
last boundary and folds their verdicts into the control decisions —
persistent ready-queue starvation votes for a wider manager pool and
un-settles the shard hill-climb so it re-brackets under the observed
load. Detection runs only at quiescence (never on the task hot path)
and only over the event delta, so its cost scales with traffic, not
with run length.

All adjustments are bounded and hysteretic; the tuned static defaults
remain the fixed point under calm load.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import TYPE_CHECKING, List, Optional, Tuple

if TYPE_CHECKING:  # pragma: no cover
    from .runtime import TaskRuntime


@dataclass
class TunerConfig:
    interval_s: float = 0.002       # min time between adjustments
    backlog_high: int = 32          # pending msgs per worker: pressure
    backlog_low: int = 2
    ops_step: int = 4
    max_ops: int = 64
    # -- num_shards hill-climb (sharded policy only) --------------------
    tune_shards: bool = True
    shard_min_messages: int = 64    # min msgs between shard adjustments
    shard_improve_eps: float = 0.05  # relative improvement to keep going
    shard_cap: Optional[int] = None  # default: max(64, 4 * num_workers)
    # -- trace-detector feedback (runtimes built with trace=True) -------
    trace_feedback: bool = True
    trace_starve_votes: int = 2     # starvation verdicts before acting


class DynamicTuner:
    def __init__(self, runtime: "TaskRuntime",
                 cfg: TunerConfig = TunerConfig()) -> None:
        self.rt = runtime
        self.cfg = cfg
        self._last = 0.0
        self._lock = threading.Lock()
        self.adjustments: List[Tuple[float, int, int]] = []
        p = runtime.params
        self._static_mgr = p.resolved_max_threads(runtime.num_workers)
        # ensure an explicit, mutable starting point
        if p.max_ddast_threads is None:
            p.max_ddast_threads = self._static_mgr
        runtime.dispatcher.register("ddast-autotune", self.callback,
                                    priority=0)
        # -- shard-count controller state -------------------------------
        self.shard_adjustments: List[Tuple[float, int]] = []
        self._shard_dir = 1            # +1: double, -1: halve
        self._shard_flips = 0
        self._shard_settled = False
        self._shard_prev_metric: Optional[float] = None
        self._m0 = 0                   # messages at last adjustment
        self._w0 = 0.0                 # lock wait at last adjustment
        self._h0 = 0                   # lock handoffs at last adjustment
        if cfg.tune_shards and hasattr(runtime.policy, "resize"):
            runtime.dispatcher.register_quiescent(
                "shard-autotune", self.quiescent_callback, priority=0)
        # -- trace-detector feedback state ------------------------------
        self.trace_verdicts: List = []   # every Finding the hook saw
        self.trace_actions: List[Tuple[float, str]] = []
        self._starve_votes = 0
        self._trace_seen = 0             # total_appended at last sweep
        if cfg.trace_feedback and getattr(runtime.tracer, "enabled",
                                          False):
            sampler = getattr(runtime, "sampler", None)
            if sampler is not None and \
                    getattr(sampler, "detector", None) is not None:
                # live metrics plane present: the sampler's incremental
                # detector sweeps the trailing trace window every tick,
                # so verdicts arrive MID-PHASE (already deduplicated)
                # instead of only at quiescence — the quiescence hook
                # would re-detect the same findings, so it stays off
                sampler.on_findings = self.note_trace_verdicts
            else:
                runtime.dispatcher.register_quiescent(
                    "trace-feedback", self.trace_callback, priority=1)

    # -- dispatcher callback --------------------------------------------
    def callback(self, worker_id: int) -> None:
        del worker_id
        now = time.perf_counter()
        with self._lock:
            if now - self._last < self.cfg.interval_s:
                return
            self._last = now
        rt, p, c = self.rt, self.rt.params, self.cfg
        n = rt.num_workers
        backlog = rt._pending_msgs() / max(n, 1)
        ready = rt.ready_count()
        mgr_cap = max(1, n // 2)
        if backlog > c.backlog_high and ready < p.min_ready_tasks:
            # pressure: the managers cannot keep up — widen the manager
            # pool and deepen per-queue drains
            p.max_ddast_threads = min(mgr_cap, p.max_ddast_threads + 1)
            p.max_ops_thread = min(c.max_ops, p.max_ops_thread + c.ops_step)
            self.adjustments.append((now, p.max_ddast_threads,
                                     p.max_ops_thread))
        elif backlog < c.backlog_low and \
                p.max_ddast_threads > self._static_mgr:
            # calm: shrink back toward the locality-friendly default
            p.max_ddast_threads -= 1
            p.max_ops_thread = max(8, p.max_ops_thread - c.ops_step)
            self.adjustments.append((now, p.max_ddast_threads,
                                     p.max_ops_thread))

    # -- quiescence callback: num_shards hill-climb ---------------------
    def quiescent_callback(self, worker_id: int) -> None:
        del worker_id
        pol = self.rt.policy
        if self._shard_settled or not hasattr(pol, "resize"):
            return
        # Nested taskwaits also notify, but their parent is still in the
        # graph — resize would refuse; don't consume a metric sample.
        if pol.pending() or pol.in_graph():
            return
        # Never resize under a live record-and-replay recording: the
        # recording freezes against the structures that exist when it
        # completes, and a mid-recording partition swap would also skew
        # the metric sample. (A *frozen* replay is unaffected — its
        # steady state never touches the shards — so tuning proceeds.)
        if getattr(pol, "recording_live", False):
            return
        self.consider_shard_step(pol.stats())

    def consider_shard_step(self, stats: dict) -> bool:
        """One hill-climb decision from a ``ShardedPolicy.stats()``
        snapshot. Split out from the dispatcher hook so the decision
        logic is testable with fabricated counter deltas. Returns True
        if a resize was applied."""
        pol, c = self.rt.policy, self.cfg
        if self._shard_settled:
            return False
        msgs = int(stats["messages_processed"])
        wait = float(stats["lock_wait_s"])
        handoffs = sum(stats.get("shard_lock_handoffs", []) or [0])
        dm = msgs - self._m0
        if dm < c.shard_min_messages:
            return False                 # not enough new signal yet
        if getattr(pol, "delegation", False):
            # Wait-free hot path: lock waits are ~0 by construction, so
            # the contention signal is combiner HANDOFFS per message —
            # each handoff is a post-release re-acquisition forced by
            # requests published behind the combiner's back, i.e. the
            # delegation-era analogue of a blocked acquire. All three
            # counters are cumulative across resize (the policy's
            # _carried merge), so the deltas stay monotone.
            metric = (handoffs - self._h0) / dm
        else:
            metric = (wait - self._w0) / dm  # lock-wait cost per message
        self._m0, self._w0, self._h0 = msgs, wait, handoffs
        prev = self._shard_prev_metric
        self._shard_prev_metric = metric
        bracketed = False
        if prev is not None and metric > prev * (1.0 - c.shard_improve_eps):
            # Stopped improving: reverse. Flips accumulate across the
            # whole climb (an improving leg does NOT reset them —
            # otherwise a clean unimodal metric bounces S/2 -> S -> 2S
            # forever). The second flip means the optimum is bracketed:
            # take one final step back toward it, then settle.
            self._shard_dir = -self._shard_dir
            self._shard_flips += 1
            bracketed = self._shard_flips >= 2
        cap = c.shard_cap or max(64, 4 * self.rt.num_workers)
        target = (pol.num_shards * 2 if self._shard_dir > 0
                  else pol.num_shards // 2)
        target = max(1, min(target, cap))
        if target == pol.num_shards:
            # nowhere to step (boundary); if bracketed we are done here
            self._shard_settled = bracketed or self._shard_settled
            return False
        if not pol.resize(target):
            # refused (work in flight): retry at the next quiescence
            # rather than latching settled at the worse bracket end
            return False
        self._shard_settled = bracketed or self._shard_settled
        self.shard_adjustments.append((time.perf_counter(), target))
        return True

    @property
    def shards_settled(self) -> bool:
        return self._shard_settled

    # -- trace-detector feedback ----------------------------------------
    def trace_callback(self, worker_id: int) -> None:
        """Quiescence hook: sweep the detectors over the trace and fold
        the verdicts in. Skipped when nothing new was recorded since
        the last boundary (replayed iterations append only lifecycle +
        quiesce events, so the probe stays cheap there too)."""
        del worker_id
        tracer = self.rt.tracer
        appended = tracer.total_appended
        if appended <= self._trace_seen:
            return
        self._trace_seen = appended
        # deferred import: autotune must stay importable without trace
        from .trace import detect_all
        self.note_trace_verdicts(detect_all(tracer.events()))

    def note_trace_verdicts(self, findings) -> bool:
        """Fold detector verdicts into the control loops (split out so
        tests can feed fabricated findings). Persistent ready-queue
        starvation — ``cfg.trace_starve_votes`` sweeps that each saw at
        least one starvation span — votes to widen the manager pool and
        to un-settle the shard hill-climb so it re-brackets under the
        load the detectors actually observed. Inversion/affinity
        verdicts are recorded for reporting but drive no knob: the
        former is a placement-band artifact, the latter is the load
        balancer's deliberate trade. Returns True if a knob moved."""
        from .trace import STARVATION
        self.trace_verdicts.extend(findings)
        if not any(f.kind == STARVATION for f in findings):
            return False
        self._starve_votes += 1
        if self._starve_votes < self.cfg.trace_starve_votes:
            return False
        self._starve_votes = 0
        now = time.perf_counter()
        p = self.rt.params
        mgr_cap = max(1, self.rt.num_workers // 2)
        acted = False
        if p.max_ddast_threads < mgr_cap:
            p.max_ddast_threads += 1
            self.adjustments.append((now, p.max_ddast_threads,
                                     p.max_ops_thread))
            self.trace_actions.append((now, "widen_managers"))
            acted = True
        if self._shard_settled:
            self._shard_settled = False
            self._shard_flips = 0
            self._shard_prev_metric = None
            self.trace_actions.append((now, "unsettle_shards"))
            acted = True
        return acted
