"""Functionality Dispatcher (paper §3.2, Fig. 4).

A runtime-core module mediating between subsystems: any module registers a
callback during init (or mid-run); worker threads that become idle notify
the dispatcher, which hands them a registered callback to execute. This is
how runtime functionality runs WITHOUT dedicated resources — the DDAST
manager is simply one registered callback; this framework also registers
async checkpoint flushing, data prefetch and metric flushing (DESIGN.md §2).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List


@dataclass
class _Callback:
    name: str
    fn: Callable[[int], None]     # receives the idle worker's id
    priority: int = 0
    calls: int = 0


class FunctionalityDispatcher:
    def __init__(self) -> None:
        self._callbacks: List[_Callback] = []
        self._quiescent: List[_Callback] = []
        self._lock = threading.Lock()

    def register(self, name: str, fn: Callable[[int], None],
                 priority: int = 0) -> None:
        with self._lock:
            self._callbacks.append(_Callback(name, fn, priority))
            self._callbacks.sort(key=lambda c: -c.priority)

    def register_quiescent(self, name: str, fn: Callable[[int], None],
                           priority: int = 0) -> None:
        """Register a callback run at taskwait quiescence (the blocked
        thread observed zero live children and zero pending messages) —
        the only moments global reconfiguration (e.g. shard-count
        retuning) is safe."""
        with self._lock:
            self._quiescent.append(_Callback(name, fn, priority))
            self._quiescent.sort(key=lambda c: -c.priority)

    def unregister(self, name: str) -> None:
        with self._lock:
            self._callbacks = [c for c in self._callbacks if c.name != name]
            self._quiescent = [c for c in self._quiescent if c.name != name]

    def notify_idle(self, worker_id: int) -> bool:
        """An idle worker offers itself; run registered callbacks (highest
        priority first). Returns True if any callback ran."""
        ran = False
        for cb in list(self._callbacks):
            cb.fn(worker_id)
            cb.calls += 1
            ran = True
        return ran

    def notify_quiescent(self, worker_id: int) -> bool:
        """A taskwait reached quiescence on ``worker_id``'s thread."""
        ran = False
        for cb in list(self._quiescent):
            cb.fn(worker_id)
            cb.calls += 1
            ran = True
        return ran

    def stats(self) -> Dict[str, int]:
        return {c.name: c.calls
                for c in self._callbacks + self._quiescent}
