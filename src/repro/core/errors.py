"""Structured runtime failures shared by both drivers.

The fault-tolerance contract (paper §3: dependence state lives in the
manager, workers are expendable) needs one vocabulary of failures that
the threaded driver, the process driver, the scopes layer, and the ring
transport all agree on — and that tests can import without touching a
driver module. Every exception here is raised at a *quiescence point*
(a ``taskwait``), never from inside a worker, so the dependence graph
is always consistent when user code sees it.
"""
from __future__ import annotations

from typing import List, Optional, Sequence


class WorkerLost(RuntimeError):
    """A worker process died with non-retryable task(s) in flight
    (``retries=0``, the default). Raised at the next ``taskwait``
    (instead of hanging its quiescence wait) naming the in-flight
    task(s). Tasks submitted with ``retries=N`` never surface this:
    the supervisor respawns the worker and re-dispatches them."""


class TaskFailed(RuntimeError):
    """A task body raised, or a retryable task exhausted its retry
    budget (poisoned). Carries the traceback(s) and, for poisoned
    tasks, the per-attempt history; raised at the owning scope's
    ``taskwait`` after quiescence (the graph stays consistent: the
    failing task completes, successors run)."""

    def __init__(self, msg: str, failures: Optional[Sequence] = None
                 ) -> None:
        super().__init__(msg)
        #: list of (label, traceback_or_reason, attempts) tuples — the
        #: structured form of the message, one entry per failed task
        self.failures: List = list(failures or [])


class ScopeExpired(RuntimeError):
    """A :class:`~repro.core.scopes.JobScope` exceeded its ``deadline=``
    (wall seconds since open) or ``budget=`` (summed body-execution
    seconds). The scope's own unrun tasks are drained and failed;
    other tenants are untouched. Raised once, at the expired scope's
    ``taskwait``."""

    def __init__(self, msg: str, scope: Optional[str] = None,
                 reason: Optional[str] = None, drained: int = 0) -> None:
        super().__init__(msg)
        self.scope = scope
        self.reason = reason            # "deadline" | "budget"
        self.drained = drained          # tasks skipped without running


class RingCorruption(RuntimeError):
    """A shared-memory ring frame failed its CRC32 check. The consumer
    advances past the frame before raising, so the transport stays
    usable; the process driver treats it as a worker fault (the
    producing worker is killed and respawned, its in-flight tasks
    retried or poisoned)."""

    def __init__(self, msg: str, ring: Optional[str] = None,
                 expected: int = 0, actual: int = 0) -> None:
        super().__init__(msg)
        self.ring = ring
        self.expected = expected
        self.actual = actual
