"""Deterministic fault injection for the process backend.

A :class:`FaultPlan` is a seeded script of failures the driver consults
at well-defined points (a test-only hook: pass it as
``ProcessRuntime(fault_plan=...)``):

  * ``kill_worker(widx, after_tasks=k)`` — SIGKILL worker ``widx`` the
    moment the k-th task has been shipped to the exec rings ("kill
    worker W before task K+1"). With ``ipc_batch=1`` the trigger point
    is exact; larger batches quantize it to a frame boundary.
  * ``kill_worker_at_iter(widx, nth_iter=n)`` — SIGKILL worker ``widx``
    just after the n-th replay-plane ITER broadcast, exercising the
    plane-recovery path.
  * ``stall_body(label_contains, stall_s, times=t)`` — each worker
    process sleeps ``stall_s`` before the first ``t`` bodies whose
    label matches (per process: a respawned worker stalls again), the
    lever for driving tasks past their ``timeout=``.
  * ``drop_done(widx, nth)`` / ``delay_done(widx, nth, delay_s)`` —
    the reaper swallows or delays the n-th done frame from worker
    ``widx`` (a lost done looks like a stuck task: only a ``timeout=``
    recovers it).
  * ``corrupt_exec_frame(widx, nth)`` — flip a payload byte of the
    n-th exec frame to worker ``widx`` after its CRC is computed; the
    worker detects :class:`~repro.core.errors.RingCorruption` and
    exits, and the supervisor respawns it.
  * ``ignore_sigterm`` — workers install SIG_IGN for SIGTERM, forcing
    the shutdown escalation path all the way to SIGKILL.

Everything is counter-based, not time-based, so a plan replays the
same failure sequence on any machine. :meth:`seeded_kills` derives a
reproducible random plan from a seed — the chaos soak tests sweep
seeds, and a failing seed is a one-line repro.

The parent-side hooks (`on_task_shipped`, `on_iter_broadcast`,
`on_done_frame`, `exec_frame_corrupt`) mutate plan state and are only
ever called from the driver's submit path and reaper thread; the
worker-side piece (`worker_stalls`) is a plain picklable list shipped
at spawn.
"""
from __future__ import annotations

import random
from typing import Dict, List, Optional, Tuple, Union


class FaultPlan:
    """A deterministic, seeded script of injected failures."""

    def __init__(self, seed: int = 0) -> None:
        self.seed = seed
        self.ignore_sigterm = False
        self._kills: List[List] = []         # [after_tasks, widx, done]
        self._iter_kills: List[List] = []    # [nth_iter, widx, done]
        self._stalls: List[Tuple[str, float, int]] = []
        self._done_actions: Dict[int, List[List]] = {}  # widx -> [[nth,
        #                                       action, arg, done], ...]
        self._corrupt: Dict[int, List[int]] = {}   # widx -> [nth, ...]
        self._shipped = 0
        self._iters = 0
        self._done_seen: Dict[int, int] = {}
        self._exec_seen: Dict[int, int] = {}

    # -- authoring ------------------------------------------------------
    def kill_worker(self, widx: int, after_tasks: int) -> "FaultPlan":
        if after_tasks < 1:
            raise ValueError("after_tasks must be >= 1")
        self._kills.append([after_tasks, widx, False])
        self._kills.sort(key=lambda e: e[0])
        return self

    def kill_worker_at_iter(self, widx: int, nth_iter: int = 1
                            ) -> "FaultPlan":
        if nth_iter < 1:
            raise ValueError("nth_iter must be >= 1")
        self._iter_kills.append([nth_iter, widx, False])
        return self

    def stall_body(self, label_contains: str, stall_s: float,
                   times: int = 1) -> "FaultPlan":
        self._stalls.append((label_contains, stall_s, times))
        return self

    def drop_done(self, widx: int, nth: int = 1) -> "FaultPlan":
        self._done_actions.setdefault(widx, []).append(
            [nth, "drop", 0.0, False])
        return self

    def delay_done(self, widx: int, nth: int = 1,
                   delay_s: float = 0.01) -> "FaultPlan":
        self._done_actions.setdefault(widx, []).append(
            [nth, "delay", delay_s, False])
        return self

    def corrupt_exec_frame(self, widx: int, nth: int = 1) -> "FaultPlan":
        self._corrupt.setdefault(widx, []).append(nth)
        return self

    @classmethod
    def seeded_kills(cls, seed: int, num_workers: int, total_tasks: int,
                     kills: int = 2) -> "FaultPlan":
        """A reproducible random plan: ``kills`` worker kills at
        distinct points of a ``total_tasks``-task run."""
        plan = cls(seed)
        rng = random.Random(seed)
        hi = max(2, total_tasks)
        points = rng.sample(range(1, hi), min(kills, hi - 1))
        for after in sorted(points):
            plan.kill_worker(rng.randrange(num_workers), after)
        return plan

    # -- driver hooks (parent side) -------------------------------------
    def on_task_shipped(self, count: int = 1) -> List[int]:
        """Advance the shipped-task counter; return worker indices whose
        kill threshold was crossed by this ship."""
        self._shipped += count
        fire = []
        for entry in self._kills:
            if not entry[2] and entry[0] <= self._shipped:
                entry[2] = True
                fire.append(entry[1])
        return fire

    def on_iter_broadcast(self) -> List[int]:
        """Advance the plane-iteration counter; return worker indices
        to kill after this ITER broadcast."""
        self._iters += 1
        fire = []
        for entry in self._iter_kills:
            if not entry[2] and entry[0] == self._iters:
                entry[2] = True
                fire.append(entry[1])
        return fire

    def on_done_frame(self, widx: int
                      ) -> Optional[Union[str, Tuple[str, float]]]:
        """Called per done frame popped from worker ``widx``; returns
        None, ``"drop"``, or ``("delay", seconds)``."""
        acts = self._done_actions.get(widx)
        if not acts:
            return None
        nth = self._done_seen[widx] = self._done_seen.get(widx, 0) + 1
        for entry in acts:
            if not entry[3] and entry[0] == nth:
                entry[3] = True
                return entry[1] if entry[1] == "drop" \
                    else (entry[1], entry[2])
        return None

    def exec_frame_corrupt(self, widx: int) -> bool:
        """Called per exec frame shipped to worker ``widx``; True means
        corrupt this frame's payload post-CRC."""
        nths = self._corrupt.get(widx)
        if not nths:
            return False
        nth = self._exec_seen[widx] = self._exec_seen.get(widx, 0) + 1
        return nth in nths

    # -- worker side ----------------------------------------------------
    def worker_stalls(self) -> List[Tuple[str, float, int]]:
        """The picklable stall spec shipped to every worker at spawn."""
        return list(self._stalls)
