"""Multi-process backend (``backend="processes"``): worker processes
execute task bodies while the shard-pinned manager stack stays in the
parent; cross-process traffic is the §3.1 message shapes in compact
binary form over shared-memory SPSC rings; frozen replay graphs map
into every worker so steady-state replayed iterations ship only latch
generations. See ``driver.py`` for the full design notes."""
from .chaos import FaultPlan
from .driver import (ProcessDispatch, ProcessRuntime, TaskFailed,
                     WorkerLost)
from .rings import RingCorruption, ShmRing, attach_shm

__all__ = ["ProcessRuntime", "ProcessDispatch", "WorkerLost",
           "TaskFailed", "FaultPlan", "RingCorruption", "ShmRing",
           "attach_shm"]
