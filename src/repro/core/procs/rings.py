"""SPSC byte rings over ``multiprocessing.shared_memory``.

The process backend's mailboxes (paper §3.1, per-worker queue pairs)
must cross an address-space boundary, so the in-process ``SPSCQueue``
(a plain deque) is replaced by a shared-memory ring of length-prefixed
frames:

    [ head u64 | tail u64 | capacity u64 | data region ... ]

The creator writes the *logical* capacity into the header and attachers
read it back from there — never from ``shm.size``, which platforms that
page-round segments (macOS ``ftruncate``) report larger than requested;
a derived capacity would differ between the two sides and corrupt the
ring at the first wrap. ``head``/``tail`` are *monotonic byte counters*
(never wrapped); the
data offset is ``counter % capacity``. The producer owns ``tail``, the
consumer owns ``head`` — single writer per cursor, so no cross-process
lock is needed. 8-byte aligned cursor stores are effectively atomic on
x86-64/ARM64 (CPython writes them with one memcpy), and the payload is
fully written *before* the tail store that publishes it; on strongly
ordered x86 that suffices, and in practice the GIL release around the
syscall-free memoryview writes keeps ARM happy too. This is the same
"good-enough SPSC" contract real runtimes (e.g. AMReX/Perilla forwarders)
use for worker mailboxes.

Frames are ``u32 length | u32 crc32`` + payload, always contiguous:
when a frame does not fit before the end of the data region the
producer writes a ``WRAP`` marker (or, with < 4 bytes left, nothing)
and skips to the region start; the consumer mirrors the skip. The CRC
covers the payload; a mismatch at pop raises
:class:`~repro.core.errors.RingCorruption` *after* advancing past the
frame, so one corrupt frame costs one structured error, not a desynced
ring — the process driver treats it as a worker fault (kill + respawn
+ retry). Frames larger than half the capacity — or pushes that time
out against a full ring — take the **fallback lane**: the raw frame
goes through a ``SimpleQueue`` (pipe) and a 4-byte ``FALLBACK`` marker
keeps its position in the ring (the pipe transport has its own
integrity, so fallback frames carry no ring-side CRC), preserving FIFO
order even for payloads the ring cannot hold.
"""
from __future__ import annotations

import struct
import time
import zlib
from multiprocessing import shared_memory
from typing import Optional

from ..errors import RingCorruption

_U64 = struct.Struct("<Q")
_U32 = struct.Struct("<I")

_HDR = 24                      # head u64 @0, tail u64 @8, capacity @16
_FHDR = 8                      # frame header: u32 length + u32 crc32
WRAP = 0xFFFFFFFF              # skip to data-region start
FALLBACK = 0xFFFFFFFE          # pop one frame from the fallback queue

# one frame must leave room for a trailing marker; keep it conservative
_MAX_INLINE_FRAC = 2           # inline frames <= capacity // 2


def attach_shm(name: str) -> shared_memory.SharedMemory:
    """Attach to an existing segment without adopting ownership. On
    CPython < 3.13 ``SharedMemory(name=...)`` re-registers the segment
    with the ``resource_tracker`` (bpo-39959); that is harmless here
    because every attacher is a ``multiprocessing`` child of the
    creator, so the whole tree shares ONE tracker process and the
    re-register is a set-add no-op. Do NOT unregister (the tempting
    bpo-39959 workaround): that would strip the creator's own tracker
    entry and turn its eventual ``unlink()`` into tracker-side KeyError
    noise. The creator remains the sole unlinker."""
    return shared_memory.SharedMemory(name=name)


class ShmRing:
    """One direction of a worker mailbox. Construct with ``create=True``
    in the owning (parent) process; workers attach with
    :meth:`attach`. Exactly one producer process/thread and one consumer
    process/thread; the parent side serializes its multiple producer
    threads externally (``ProcessDispatch`` holds one lock per ring)."""

    def __init__(self, capacity: int = 1 << 20, *, create: bool = True,
                 name: Optional[str] = None, fallback=None) -> None:
        if create and capacity < 64:
            raise ValueError("capacity must be >= 64 bytes")
        if create:
            self.shm = shared_memory.SharedMemory(
                create=True, size=_HDR + capacity)
            self.capacity = capacity
            self.shm.buf[:_HDR] = b"\0" * _HDR
            _U64.pack_into(self.shm.buf, 16, capacity)
        else:
            self.shm = attach_shm(name)
            # read the creator's logical capacity from the header:
            # shm.size may be page-rounded above what was requested
            self.capacity = _U64.unpack_from(self.shm.buf, 16)[0]
        self.name = self.shm.name
        self.owner = create
        self.fallback = fallback         # SimpleQueue for oversize frames
        self.consumer_alive = None       # optional liveness probe; see push
        # local-side counters (not shared; each side counts its own ops)
        self.pushed = 0
        self.popped = 0
        self.fallbacks = 0
        # fault-injection hook (core.procs.chaos): flip one payload byte
        # of the next inline push AFTER its CRC is computed, so the
        # consumer's check fires deterministically
        self._corrupt_next = False

    @classmethod
    def attach(cls, name: str, fallback=None) -> "ShmRing":
        return cls(create=False, name=name, fallback=fallback)

    # -- cursor access --------------------------------------------------
    def _head(self) -> int:
        return _U64.unpack_from(self.shm.buf, 0)[0]

    def _tail(self) -> int:
        return _U64.unpack_from(self.shm.buf, 8)[0]

    def _set_head(self, v: int) -> None:
        _U64.pack_into(self.shm.buf, 0, v)

    def _set_tail(self, v: int) -> None:
        _U64.pack_into(self.shm.buf, 8, v)

    def __len__(self) -> int:
        return self._tail() - self._head()

    # -- producer -------------------------------------------------------
    def try_push(self, frame: bytes) -> bool:
        """Append one frame if it fits (inline or as a fallback marker
        when a fallback queue is wired and the frame is oversize).
        Returns False when the ring lacks space right now."""
        n = len(frame)
        if self.fallback is not None and \
                n + _FHDR > self.capacity // _MAX_INLINE_FRAC:
            return self._push_fallback(frame)
        return self._push_inline(frame)

    def push(self, frame: bytes, spin_s: float = 0.5) -> None:
        """Blocking append: spin (with micro-sleeps) until the consumer
        frees space, then degrade to the fallback lane if one exists.
        A ring that stays full past ``spin_s`` is not by itself a dead
        consumer — a worker grinding through a long task body with a
        full exec ring is alive and will drain eventually — so when a
        ``consumer_alive`` probe is wired (the driver points it at
        ``Process.is_alive`` / a getppid check) the producer keeps
        waiting while it returns True. BufferError is raised only when
        the probe says dead, or no probe exists to say otherwise."""
        deadline = time.perf_counter() + spin_s
        while True:
            if self.try_push(frame):
                return
            if time.perf_counter() > deadline:
                if self.fallback is not None and \
                        self._push_fallback(frame, spin_s):
                    return
                if self.consumer_alive is not None \
                        and self.consumer_alive():
                    deadline = time.perf_counter() + spin_s
                    continue             # slow consumer, not a dead one
                raise BufferError(
                    f"ring {self.name} full for {spin_s}s "
                    f"(consumer dead?)")
            time.sleep(5e-6)

    def _push_inline(self, frame: bytes) -> bool:
        n = len(frame)
        cap = self.capacity
        if n + _FHDR > cap // _MAX_INLINE_FRAC:
            return False                 # never fits: caller's problem
        head, tail = self._head(), self._tail()
        free = cap - (tail - head)
        off = tail % cap
        contig = cap - off
        if contig < n + _FHDR:
            # frame would straddle the region end: burn `contig` bytes
            # (with a WRAP marker when the length field fits)
            if free < contig + n + _FHDR:
                return False
            if contig >= 4:
                _U32.pack_into(self.shm.buf, _HDR + off, WRAP)
            tail += contig
            off = 0
        elif free < n + _FHDR:
            return False
        _U32.pack_into(self.shm.buf, _HDR + off, n)
        _U32.pack_into(self.shm.buf, _HDR + off + 4,
                       zlib.crc32(frame) & 0xFFFFFFFF)
        self.shm.buf[_HDR + off + _FHDR:_HDR + off + _FHDR + n] = frame
        if self._corrupt_next and n:
            self.shm.buf[_HDR + off + _FHDR] ^= 0xFF
            self._corrupt_next = False
        self._set_tail(tail + _FHDR + n)  # publish AFTER the payload
        self.pushed += 1
        return True

    def _push_fallback(self, frame: bytes, spin_s: float = 0.5) -> bool:
        """Route the frame through the pipe, keeping its FIFO slot with
        an in-ring marker. Ordering matters, twice over. The marker is
        secured and published BEFORE the put(): (a) a timed-out attempt
        then leaves NOTHING behind — enqueueing first would orphan the
        queue entry on timeout and the caller's retry would enqueue a
        duplicate, desynchronizing every later FALLBACK pop from its
        frame; (b) put() on a ``multiprocessing.SimpleQueue`` blocks
        once the frame outgrows the pipe buffer and only unblocks when
        the consumer get()s — the consumer must already be able to see
        the marker that tells it to, or both sides deadlock. The
        consumer's get() at worst blocks briefly on a put() still in
        flight, which is harmless."""
        deadline = time.perf_counter() + spin_s
        cap = self.capacity
        while True:
            head, tail = self._head(), self._tail()
            off = tail % cap
            contig = cap - off
            if contig < 4 and cap - (tail - head) >= contig + 4:
                tail += contig           # markerless end-of-region skip
                off, contig = 0, cap
            if contig >= 4 and cap - (tail - head) >= 4:
                _U32.pack_into(self.shm.buf, _HDR + off, FALLBACK)
                self._set_tail(tail + 4)
                self.fallback.put(frame)  # put AFTER the marker publish
                self.pushed += 1
                self.fallbacks += 1
                return True
            if time.perf_counter() > deadline:
                return False
            time.sleep(5e-6)

    # -- consumer -------------------------------------------------------
    def pop(self) -> Optional[bytes]:
        """Dequeue one frame, or None when the ring is empty. Raises
        :class:`RingCorruption` when a frame's payload fails its CRC32
        check — the head has already advanced past the bad frame, so
        the next pop reads the next frame."""
        while True:
            head, tail = self._head(), self._tail()
            if head == tail:
                return None
            cap = self.capacity
            off = head % cap
            contig = cap - off
            if contig < 4:               # producer skipped, markerless
                self._set_head(head + contig)
                continue
            n = _U32.unpack_from(self.shm.buf, _HDR + off)[0]
            if n == WRAP:
                self._set_head(head + contig)
                continue
            if n == FALLBACK:
                self._set_head(head + 4)
                self.popped += 1
                return self.fallback.get()
            crc = _U32.unpack_from(self.shm.buf, _HDR + off + 4)[0]
            frame = bytes(self.shm.buf[_HDR + off + _FHDR:
                                       _HDR + off + _FHDR + n])
            self._set_head(head + _FHDR + n)
            self.popped += 1
            actual = zlib.crc32(frame) & 0xFFFFFFFF
            if actual != crc:
                raise RingCorruption(
                    f"ring {self.name}: frame at offset {off} failed "
                    f"CRC32 (stored {crc:#010x}, computed "
                    f"{actual:#010x})", ring=self.name,
                    expected=crc, actual=actual)
            return frame

    # -- lifecycle ------------------------------------------------------
    def close(self) -> None:
        try:
            self.shm.close()
        except Exception:                # pragma: no cover - teardown
            pass

    def unlink(self) -> None:
        """Owner-side destroy. Safe to call once; attachers never do."""
        if not self.owner:
            return
        try:
            self.shm.unlink()
        except FileNotFoundError:        # pragma: no cover - teardown
            pass
