"""Frame layer for the process backend's ring mailboxes.

One ring frame = 1 kind byte + a kind-specific body. The two hot kinds
are exactly the §3.1 message shapes in their compact binary wire form
(``core.messages.encode_submit_batch`` / ``encode_done_batch``); control
and trace frames are cold-path and carry a small pickled payload.

    EXEC   parent -> worker   submit batch: [(wd_id, payload, label)]
    DONE   worker -> parent   done batch:   [(wd_id, t0, t1, st, blob)]
    CTRL   parent -> worker   u8 op + pickled body
                               SHUTDOWN: body None — ship trace, exit
                               ITER: body = replay-plane descriptor dict
                               (shm names + offsets + generation); the
                               ONE boundary message per worker a
                               replayed iteration costs
    TRACE  worker -> parent   pickled list of event tuples (shipped once
                               at shutdown; merged by TraceRecorder)
"""
from __future__ import annotations

import pickle
from typing import Any, List, Sequence, Tuple

from ..messages import (decode_done_batch, decode_submit_batch,
                        encode_done_batch, encode_submit_batch)

K_EXEC = 1
K_DONE = 2
K_CTRL = 3
K_TRACE = 4

OP_SHUTDOWN = 0
OP_ITER = 1


def frame_exec(entries: Sequence[Tuple[int, bytes, str]]) -> bytes:
    return bytes([K_EXEC]) + encode_submit_batch(entries)


def frame_done(
        entries: Sequence[Tuple[int, float, float, int, bytes]]) -> bytes:
    return bytes([K_DONE]) + encode_done_batch(entries)


def frame_ctrl(op: int, body: Any = None) -> bytes:
    return bytes([K_CTRL, op]) + pickle.dumps(body, protocol=4)


def frame_trace(events: List[tuple]) -> bytes:
    return bytes([K_TRACE]) + pickle.dumps(events, protocol=4)


def parse(frame: bytes):
    """-> (kind, decoded body). CTRL bodies decode to (op, payload)."""
    kind = frame[0]
    if kind == K_EXEC:
        return kind, decode_submit_batch(frame, 1)
    if kind == K_DONE:
        return kind, decode_done_batch(frame, 1)
    if kind == K_CTRL:
        return kind, (frame[1], pickle.loads(frame[2:]))
    if kind == K_TRACE:
        return kind, pickle.loads(frame[1:])
    raise ValueError(f"unknown frame kind {kind}")
