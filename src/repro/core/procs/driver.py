"""``ProcessRuntime`` — the ``backend="processes"`` driver.

Under CPython threads the GIL serializes task *bodies*, so the threaded
driver can only ever demonstrate the paper's lock-wait story, never real
parallel throughput. This driver keeps the entire dependence-management
stack exactly where the engine refactor put it — the same
``SyncPolicy`` / ``DdastPolicy`` / ``ShardedPolicy`` objects, unchanged —
and moves only the task *bodies* into worker processes:

    main thread (slot 1)        submits; taskwait drains managers
    reaper thread (slot 0)      consumes Done rings, runs idle-manager
                                callbacks (the DDAST discipline: a
                                thread with nothing else to do drains
                                shard mailboxes)
    worker process i (slot 2+i) pops Submit batches from its exec ring,
                                runs bodies, ships Done batches back

Cross-process traffic reuses the §3.1 message shapes in compact binary
wire form (``core.messages.encode_submit_batch`` / ``encode_done_batch``)
over ``multiprocessing.shared_memory`` SPSC rings (``procs.rings``), one
exec + one done ring per worker, with a ``SimpleQueue`` fallback lane
for oversize frames. Dependence analysis itself stays in the parent:
the shard graphs hold live WorkDescriptor references and per-slot
AtomicCounters that cannot cross an address space without a full
shared-heap redesign — README documents this split honestly.

Record-and-replay goes further: once an iteration's structure is frozen
(``engine/replay.py``), the parent builds a **replay plane** — the
frozen ``ReplayGraph``'s flat successor arrays (CSR), per-task latches,
a shared ready ring and the pickled task payloads — in shared memory,
mapped by every worker. A structurally matching iteration then ships
ONE control frame per worker (the latch generation + plane descriptor)
and the workers self-schedule the whole graph: pop sid, run body, dec
successor latches under one shared lock, push newly-ready sids. Zero
Submit/Done mailbox messages cross the process boundary in steady
state — the property ``bench_procs.py`` gates in CI.

Not supported here (documented, enforced): nested tasks (bodies run in
workers and cannot submit), multi-tenant scopes, non-picklable task
functions/args (use ``procs.apps``-style shared-memory data planes; the
fallback lane covers oversize payloads, not unpicklable ones).
"""
from __future__ import annotations

import os
import pickle
import signal
import threading
import time
import traceback
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

from ..ddast import DDASTParams
from ..dispatcher import FunctionalityDispatcher
from ..engine import make_policy
from ..engine.replay import RECORDING, REPLAYING
from ..errors import RingCorruption, TaskFailed, WorkerLost
from ..metrics import (NULL_METRICS, MetricsSampler, ShmCounterPlane,
                       WorkerCounterView)
from ..messages import (DONE_ERROR, DONE_NO_RESULT, DONE_OK,
                        DONE_PLANE_ERROR, decode_done_batch,
                        decode_submit_batch, encode_done_batch)
from ..trace import (EV_CREATED, EV_END, EV_READY, EV_RESPAWN, EV_RETRY,
                     EV_START, EV_TIMEOUT_KILL, EV_TRACE_LOST,
                     EV_WORKER_LOST, NULL_TRACER, IncrementalDetector,
                     TraceRecorder, replay_iterations_of)
from ..wd import TaskState, WorkDescriptor
from . import serial
from .chaos import FaultPlan
from .rings import ShmRing
from .serial import (K_CTRL, K_DONE, K_EXEC, K_TRACE, OP_ITER,
                     OP_SHUTDOWN, frame_ctrl, frame_exec)

PROC_MODES = ("sync", "dast", "ddast", "sharded")

__all__ = ["ProcessDispatch", "ProcessRuntime", "TaskFailed",
           "WorkerLost", "RingCorruption", "FaultPlan", "PROC_MODES"]


# ---------------------------------------------------------------------------
# replay plane: shm layout shared by parent and workers
#
#   gen i64 @0 | remaining i32 @8 | ready_head i32 @12 | ready_tail i32
#   @16 | (pad to 32) | ready i32[n] | preds i32[n] | succ_off i32[n+1]
#   | succ_tgt i32[E] | latch i32[n] | exec_slot i32[n] | (pad to 8) |
#   times f64[2n]
#
# All mutation of remaining/ready/latch happens under ONE
# multiprocessing.Lock created before the workers fork; the static
# arrays (preds/succ_*) are written once at freeze and only read after.

_PL_REMAINING = 2          # i32 index (byte 8)
_PL_HEAD = 3               # i32 index (byte 12)
_PL_TAIL = 4               # i32 index (byte 16)
_PL_RING0 = 8              # i32 index (byte 32)


def _plane_offsets(n: int, nedges: int) -> Dict[str, int]:
    off: Dict[str, int] = {}
    b = 32
    off["ready"] = b
    b += 4 * n
    off["preds"] = b
    b += 4 * n
    off["succ_off"] = b
    b += 4 * (n + 1)
    off["succ_tgt"] = b
    b += 4 * nedges
    off["latch"] = b
    b += 4 * n
    off["exec_slot"] = b
    b += 4 * n
    b = (b + 7) & ~7
    off["times"] = b
    off["size"] = b + 16 * n
    return off


class _ReplayImage:
    """Parent-side owner of one frozen graph's replay plane."""

    def __init__(self, g, payload_entries: List[Tuple[bytes, str]]) -> None:
        from multiprocessing import shared_memory
        n = g.n
        nedges = sum(len(s) for s in g.succs)
        off = _plane_offsets(n, nedges)
        self.n = n
        self.g = g
        self.off = off
        self.roots = [sid for sid in range(n) if g.preds[sid] == 0]
        self.labels = [lb for _, lb in payload_entries]
        self.arrays = shared_memory.SharedMemory(create=True,
                                                 size=off["size"])
        self.arrays.buf[:off["size"]] = b"\0" * off["size"]
        blob = pickle.dumps(payload_entries, protocol=4)
        self.payload = shared_memory.SharedMemory(create=True,
                                                  size=len(blob))
        self.payload.buf[:len(blob)] = blob
        ints = self.arrays.buf.cast("i")
        base = off["preds"] // 4
        for sid in range(n):
            ints[base + sid] = g.preds[sid]
        so = off["succ_off"] // 4
        st = off["succ_tgt"] // 4
        k = 0
        for sid in range(n):
            ints[so + sid] = k
            for tgt in g.succs[sid]:
                ints[st + k] = tgt
                k += 1
        ints[so + n] = k
        self.desc = {"arrays": self.arrays.name,
                     "payload": self.payload.name,
                     "payload_size": len(blob),
                     "n": n, "nedges": nedges, "gen": 0}
        self._gen = 0

    def reset(self) -> int:
        """Arm the plane for one iteration; returns the new generation.
        Runs at a quiescent point (remaining==0, no task in flight),
        before the ITER broadcast — but the caller must hold the plane
        lock: a straggler worker can still be inside ``_run_plane``
        (micro-sleeping in its empty-ring branch) and re-read the plane
        mid-reset. Workers only read remaining/head/tail under the same
        lock, so the lock's barriers guarantee they observe either the
        fully-old or fully-new plane — on any memory model, not just
        x86-TSO."""
        ints = self.arrays.buf.cast("i")
        dbls = self.arrays.buf.cast("d")
        off = self.off
        n = self.n
        lat = off["latch"] // 4
        prd = off["preds"] // 4
        exc = off["exec_slot"] // 4
        for sid in range(n):
            ints[lat + sid] = ints[prd + sid]
            ints[exc + sid] = -1
        tm = off["times"] // 8
        for i in range(2 * n):
            dbls[tm + i] = 0.0
        for i, sid in enumerate(self.roots):
            ints[_PL_RING0 + i] = sid
        ints[_PL_HEAD] = 0
        ints[_PL_TAIL] = len(self.roots)
        ints[_PL_REMAINING] = n
        self._gen += 1
        self.arrays.buf.cast("q")[0] = self._gen
        self.desc["gen"] = self._gen
        return self._gen

    def remaining(self) -> int:
        return self.arrays.buf.cast("i")[_PL_REMAINING]

    def times(self, sid: int) -> Tuple[float, float]:
        dbls = self.arrays.buf.cast("d")
        tm = self.off["times"] // 8
        return dbls[tm + 2 * sid], dbls[tm + 2 * sid + 1]

    def exec_slot(self, sid: int) -> int:
        return self.arrays.buf.cast("i")[self.off["exec_slot"] // 4 + sid]

    def unfinished_labels(self) -> List[str]:
        ints = self.arrays.buf.cast("i")
        lat = self.off["latch"] // 4
        del lat
        out = []
        for sid in range(self.n):
            t0, t1 = self.times(sid)
            if t1 == 0.0:
                out.append(self.labels[sid])
        del ints
        return out

    def shm_names(self) -> List[str]:
        return [self.arrays.name, self.payload.name]

    def close_unlink(self) -> None:
        for shm in (self.arrays, self.payload):
            try:
                shm.close()
                shm.unlink()
            except FileNotFoundError:    # pragma: no cover - teardown
                pass


# ---------------------------------------------------------------------------
# worker process side


class _PlaneView:
    """Worker-side attachment to a replay plane (cached per shm name)."""

    def __init__(self, desc: dict) -> None:
        from .rings import attach_shm
        self.arrays = attach_shm(desc["arrays"])
        payload = attach_shm(desc["payload"])
        entries = pickle.loads(bytes(payload.buf[:desc["payload_size"]]))
        payload.close()
        self.payloads = entries          # [(payload_bytes, label)]
        self.tasks: Dict[int, Tuple] = {}  # sid -> (func, args, label)
        self.n = desc["n"]
        off = _plane_offsets(self.n, desc["nedges"])
        ints = self.arrays.buf.cast("i")
        so = off["succ_off"] // 4
        st = off["succ_tgt"] // 4
        # static topology copied to plain lists once: no shm reads on
        # the per-task hot path
        self.succ_off = [ints[so + i] for i in range(self.n + 1)]
        self.succ_tgt = [ints[st + i] for i in range(desc["nedges"])]
        self.latch_i = off["latch"] // 4
        self.exec_i = off["exec_slot"] // 4
        self.times_i = off["times"] // 8
        del ints

    def task(self, sid: int) -> Tuple:
        t = self.tasks.get(sid)
        if t is None:
            payload, label = self.payloads[sid]
            func, args = pickle.loads(payload)
            t = self.tasks[sid] = (func, args, label)
        return t

    def close(self) -> None:
        try:
            self.arrays.close()
        except Exception:                # pragma: no cover - teardown
            pass


def _run_plane(desc: dict, planes: Dict[str, _PlaneView], lock,
               done_ring: ShmRing, clock, slot: int,
               stalls, stall_counts, counters=None) -> None:
    view = planes.get(desc["arrays"])
    if view is None:
        view = planes[desc["arrays"]] = _PlaneView(desc)
    ints = view.arrays.buf.cast("i")
    dbls = view.arrays.buf.cast("d")
    n = view.n
    while True:
        sid = -1
        with lock:
            if ints[_PL_REMAINING] == 0:
                break
            h = ints[_PL_HEAD]
            if h != ints[_PL_TAIL]:
                sid = ints[_PL_RING0 + (h % n)]
                ints[_PL_HEAD] = h + 1
                # claim stamped at POP, under the lock: if this worker
                # dies mid-body the parent's recovery can tell exactly
                # which sid it owed (exec_slot set, end time still 0)
                ints[view.exec_i + sid] = slot
                dbls[view.times_i + 2 * sid] = clock()
        if sid < 0:
            time.sleep(2e-6)
            continue
        func, args, label = view.task(sid)
        if stalls:
            _maybe_stall(stalls, stall_counts, label)
        if counters is not None:
            counters.task_start()
        t0 = clock()
        try:
            func(*args)
        except BaseException:
            done_ring.push(frame_done_one(
                sid, t0, clock(), DONE_PLANE_ERROR,
                traceback.format_exc().encode("utf-8")))
        t1 = clock()
        if counters is not None:
            counters.task_end(t1 - t0)
        dbls[view.times_i + 2 * sid] = t0
        dbls[view.times_i + 2 * sid + 1] = t1
        with lock:
            for k in range(view.succ_off[sid], view.succ_off[sid + 1]):
                tgt = view.succ_tgt[k]
                v = ints[view.latch_i + tgt] - 1
                ints[view.latch_i + tgt] = v
                if v == 0:
                    t = ints[_PL_TAIL]
                    ints[_PL_RING0 + (t % n)] = tgt
                    ints[_PL_TAIL] = t + 1
            ints[_PL_REMAINING] -= 1
    del ints, dbls


def frame_done_one(wd_id: int, t0: float, t1: float, status: int,
                   blob: bytes) -> bytes:
    return bytes([K_DONE]) + encode_done_batch(
        [(wd_id, t0, t1, status, blob)])


def _maybe_stall(stalls, counts: Dict[int, int], label: str) -> None:
    """Chaos hook: sleep before a body whose label matches a stall spec
    (per process — a respawned worker starts its counts over)."""
    for i, (substr, stall_s, times) in enumerate(stalls):
        if substr in label and counts.get(i, 0) < times:
            counts[i] = counts.get(i, 0) + 1
            time.sleep(stall_s)


def _worker_main(widx: int, slot: int, exec_name: str, done_name: str,
                 exec_fbq, done_fbq, plane_lock, epoch: float,
                 parent_pid: int, stalls=(),
                 ignore_sigterm: bool = False,
                 counters_name: str = "") -> None:
    if ignore_sigterm:                   # chaos: force the kill path
        signal.signal(signal.SIGTERM, signal.SIG_IGN)
    exec_ring = ShmRing.attach(exec_name, fallback=exec_fbq)
    done_ring = ShmRing.attach(done_name, fallback=done_fbq)
    # the Done ring's consumer is the parent's reaper thread: keep
    # pushing while the parent process lives
    done_ring.consumer_alive = lambda: os.getppid() == parent_pid
    # live-metrics counter plane (metrics=True): this worker stamps its
    # own row of the parent's shm matrix — single-writer f64 stores, so
    # the parent scrapes task/busy counters with ZERO extra IPC frames
    counters = WorkerCounterView(counters_name, widx) \
        if counters_name else None
    planes: Dict[str, _PlaneView] = {}
    stall_counts: Dict[int, int] = {}

    def clock() -> float:
        # perf_counter is CLOCK_MONOTONIC on Linux: one epoch, every
        # process — worker timestamps merge directly with the parent's
        return time.perf_counter() - epoch

    try:
        idle_checks = 0
        while True:
            try:
                frame = exec_ring.pop()
            except RingCorruption:
                # a corrupt submit cannot be attributed to a task: die
                # quietly (exitcode 3) and let the supervisor respawn
                # this worker and retry/poison its in-flight tasks
                raise SystemExit(3)
            if frame is None:
                time.sleep(2e-5)
                idle_checks += 1
                if idle_checks >= 256:   # orphan watchdog (~5 ms cost)
                    idle_checks = 0
                    if os.getppid() != parent_pid:
                        return
                continue
            kind = frame[0]
            if kind == K_EXEC:
                entries = decode_submit_batch(frame, 1)
                dones = []
                for wd_id, payload, label in entries:
                    if stalls:
                        _maybe_stall(stalls, stall_counts, label)
                    if counters is not None:
                        counters.task_start()
                    t0 = clock()
                    status, blob = DONE_OK, b""
                    try:
                        func, args = pickle.loads(payload)
                        res = func(*args)
                        if res is not None:
                            try:
                                blob = pickle.dumps(res, protocol=4)
                            except Exception:
                                status = DONE_NO_RESULT
                    except BaseException:
                        status = DONE_ERROR
                        blob = traceback.format_exc().encode("utf-8")
                    t1 = clock()
                    if counters is not None:
                        counters.task_end(t1 - t0)
                    dones.append((wd_id, t0, t1, status, blob))
                done_ring.push(bytes([K_DONE]) + encode_done_batch(dones))
            elif kind == K_CTRL:
                op, body = serial.parse(frame)[1]
                if op == OP_SHUTDOWN:
                    return
                if op == OP_ITER:
                    _run_plane(body, planes, plane_lock, done_ring,
                               clock, slot, stalls, stall_counts,
                               counters)
    finally:
        for view in planes.values():
            view.close()
        if counters is not None:
            counters.close()
        exec_ring.close()
        done_ring.close()


# ---------------------------------------------------------------------------
# parent side


class ProcessDispatch:
    """The placement the parent-side policies push ready tasks into.
    Implements the ``PlacementPolicy`` surface, but ``push`` serializes
    the task and routes it to the least-loaded worker's exec ring
    (batched: up to ``ipc_batch`` entries per frame) instead of a local
    deque. ``push_replay`` is the capture hook: while an iteration is
    being replayed against a built plane, ready roots are captured
    instead of shipped, and the plane executes them."""

    wants_replay_priorities = True       # receive (wd, sid) on replay

    def __init__(self, rt: "ProcessRuntime") -> None:
        self.rt = rt
        self.charge: Any = None          # wired by the policy ctor
        self.tracer: Any = NULL_TRACER   # ditto
        self.deques: List[Any] = []      # protocol compat (unused)
        self.scope_steals: Dict[int, int] = {}
        self.capture = False             # replay-plane capture mode
        self.discard = False             # plane drain: swallow pushes
        self.captured: List[Tuple[WorkDescriptor, int]] = []
        self.record_payloads = False     # keep payloads for image builds
        self.payload_of: Dict[int, Tuple[bytes, str]] = {}
        # wd_id -> (wd, widx, dispatch time); the dispatch time anchors
        # per-task timeout= enforcement (dispatch-to-done deadline)
        self.inflight: Dict[int, Tuple[WorkDescriptor, int, float]] = {}
        W = rt.num_workers
        self._load = [0] * W
        self._buffers: List[List[Tuple[int, bytes, str]]] = \
            [[] for _ in range(W)]
        # RLocks: a worker-death harvest holds its worker's lock while
        # draining done frames, whose completions may push back through
        # the same lock on the same (reaper) thread
        self._locks = [threading.RLock() for _ in range(W)]
        # paused[widx]: the supervisor is swapping this worker's rings;
        # buffer but do not ship (the buffer flushes to the replacement)
        self.paused = [False] * W
        self.sub_msgs = [0] * W          # exec frames shipped, per ring
        # plane-recovery routing: when an aborted plane iteration falls
        # back to live analysis, sids that already finished (or were
        # poisoned) on the plane are completed from here instead of
        # being re-shipped to a worker
        self.plane_done: Optional[Dict[int, str]] = None
        self.plane_ready: deque = deque()

    # -- PlacementPolicy surface ---------------------------------------
    def push(self, wd: WorkDescriptor) -> None:
        if self.capture:
            # a live push while capturing means the iteration diverged
            # from the recorded structure: ship the captured prefix
            self.flush_capture_live()
        payload = wd._proc_payload
        if self.record_payloads:
            self.payload_of[wd.wd_id] = (payload, wd.label)
        load = self._load
        widx = min(range(len(load)), key=load.__getitem__)
        load[widx] += 1
        if self.tracer.enabled:
            self.tracer.task_event(EV_READY, wd, 2 + widx)
        with self._locks[widx]:
            # inflight registration under the ring lock: the supervisor
            # harvests inflight-vs-buffered under the same lock, so a
            # task is never both "lost" (retried) and still buffered
            # for the replacement worker (double execution)
            self.inflight[wd.wd_id] = (wd, widx, time.perf_counter())
            buf = self._buffers[widx]
            buf.append((wd.wd_id, payload, wd.label))
            if len(buf) >= self.rt.ipc_batch and not self.paused[widx]:
                self._ship(widx)

    def push_replay(self, wd: WorkDescriptor, sid: int) -> None:
        if self.discard:
            return
        if self.plane_done is not None and sid in self.plane_done:
            # this sid already ran (or was poisoned) on the aborted
            # plane generation: complete it, don't re-execute it
            self.plane_ready.append((wd, sid))
            return
        if self.capture:
            self.captured.append((wd, sid))
            return
        self.push(wd)

    def pop(self, slot: int) -> Optional[WorkDescriptor]:
        return None                      # parent threads never run bodies

    def ready_count(self) -> int:
        return len(self.inflight)

    def note_executed(self, wd: WorkDescriptor, slot: int) -> None:
        pass

    def set_replay_priorities(self, levels, scope=None) -> None:
        pass                             # workers self-schedule the plane

    def clear_replay_priorities(self, scope=None) -> None:
        pass

    def stats(self) -> Dict[str, int]:
        return {"pushed": sum(self.sub_msgs)}

    # -- shipping -------------------------------------------------------
    def _ship(self, widx: int) -> None:
        """Encode + push the worker's buffer. Caller holds its lock."""
        buf = self._buffers[widx]
        if not buf:
            return
        self._buffers[widx] = []
        ring = self.rt._exec_rings[widx]
        plan = self.rt.fault_plan
        if plan is not None and plan.exec_frame_corrupt(widx):
            ring._corrupt_next = True
        ring.push(frame_exec(buf))
        self.sub_msgs[widx] += 1
        if self.charge is not None:
            self.charge.ipc_submit()
        if plan is not None:
            self.rt._chaos_shipped(len(buf))

    def flush_all(self) -> int:
        n = 0
        for widx in range(len(self._buffers)):
            if self._buffers[widx] and not self.paused[widx]:
                with self._locks[widx]:
                    if self._buffers[widx] and not self.paused[widx]:
                        self._ship(widx)
                        n += 1
        return n

    def flush_capture_live(self) -> None:
        self.capture = False
        cap, self.captured = self.captured, []
        for wd, _sid in cap:
            self.push(wd)

    def task_done(self, wd_id: int) -> Optional[Tuple[WorkDescriptor,
                                                      int]]:
        entry = self.inflight.pop(wd_id, None)
        if entry is not None:
            self._load[entry[1]] -= 1
        return entry


class ProcessRuntime:
    """Multi-process sibling of :class:`~repro.core.runtime.TaskRuntime`
    (also reachable as ``TaskRuntime(backend="processes")``). Same task
    API, same modes, same policies — bodies run in worker processes.

    Constraints: task funcs/args must be picklable and module-level
    importable; no nested tasks; no multi-tenant scopes. Defaults to
    ``mode="sharded"`` — the configuration the GIL-escape argument is
    about."""

    backend = "processes"

    def __init__(self, num_workers: int = 4, mode: str = "sharded",
                 params: Optional[DDASTParams] = None,
                 trace: bool = False,
                 manager_eligible: Optional[set] = None,
                 num_shards: Optional[int] = None,
                 batch_size: Optional[int] = None,
                 placement: Any = "round_robin",
                 replay: bool = False,
                 num_clients: int = 0,
                 delegation: bool = True, *,
                 backend: str = "processes",
                 ring_capacity: int = 1 << 20,
                 ipc_batch: int = 8,
                 trace_capacity: int = 1 << 14,
                 fault_plan: Optional[FaultPlan] = None,
                 max_respawns: int = 16,
                 shutdown_grace: float = 5.0,
                 metrics: bool = False,
                 metrics_interval_s: float = 0.002) -> None:
        if backend != "processes":
            raise ValueError("ProcessRuntime is the backend='processes' "
                             "driver")
        if mode not in PROC_MODES:
            raise ValueError(f"mode must be one of {PROC_MODES}")
        if num_clients:
            raise ValueError("multi-tenant scopes are not supported by "
                             "the process backend")
        if placement != "round_robin":
            raise ValueError("the process backend owns placement "
                             "(least-loaded worker rings); only "
                             "'round_robin' is accepted")
        if num_workers < 1:
            raise ValueError("num_workers must be >= 1")
        self.num_workers = num_workers
        self.mode = mode
        self.params = params or DDASTParams()
        self.trace_enabled = trace
        self.num_shards = num_shards or max(2, num_workers)
        self.batch_size = batch_size
        self.replay = replay
        self.delegation = delegation
        self.ipc_batch = max(1, ipc_batch)
        self.ring_capacity = ring_capacity
        self.trace_capacity = trace_capacity
        # fault tolerance: the (test-only) injection plan, the respawn
        # budget (a crash-looping worker must not respawn forever), and
        # the teardown drain grace before escalation
        self.fault_plan = fault_plan
        self.max_respawns = max_respawns
        self.shutdown_grace = shutdown_grace

        # slots: 0 = reaper/manager thread, 1 = main thread, 2+i = worker
        # process i (trace attribution only — workers hold no policy
        # state)
        self._trace_t0 = time.perf_counter()
        self.tracer = TraceRecorder(
            2 + num_workers,
            clock=lambda: time.perf_counter() - self._trace_t0,
            time_unit="s") if trace else NULL_TRACER
        self._dispatch = ProcessDispatch(self)
        self._dispatch.record_payloads = replay
        self.placement = self._dispatch
        self.policy: Any = make_policy(
            mode, 2,
            num_workers=2,
            params=self.params,
            placement=self._dispatch,
            manager_eligible=manager_eligible,
            main_slot=1,
            num_shards=self.num_shards,
            batch_size=batch_size,
            delegation=delegation,
            replay=replay,
            tracer=self.tracer)
        self.dispatcher = FunctionalityDispatcher()
        if self.policy.uses_idle_managers:
            self.dispatcher.register("policy", self.policy.callback,
                                     priority=10)

        from ..runtime import RuntimeStats
        self.stats = RuntimeStats()
        self._root = WorkDescriptor(func=None, label="main")
        self._root.state = TaskState.RUNNING
        self._stop = threading.Event()
        self._started = False
        self._torn_down = False
        self._main_thread: Optional[threading.Thread] = None
        self._reaper: Optional[threading.Thread] = None
        self._manager_thread: Optional[threading.Thread] = None
        self._procs: List[Any] = []
        self._exec_rings: List[ShmRing] = []
        self._done_rings: List[ShmRing] = []
        self._fbqs: List[Any] = []
        self._errors: List[Tuple[str, str]] = []   # (where, traceback)
        self._errors_lock = threading.Lock()
        self._lost: Optional[str] = None           # WorkerLost message
        self._last_check = 0.0
        self._shm_created: set = set()   # every segment ever created;
        #                                  the teardown leak scan's base
        # supervision state: serializes ring-list access between the
        # reaper (pump, single-worker respawn) and the main thread
        # (plane recovery swaps every ring)
        self._rings_lock = threading.RLock()
        self._plane_active = False
        self._plane_dead: Optional[int] = None     # widx seen dead
        self._recover_img: Optional[_ReplayImage] = None
        self._parent_pid = os.getpid()
        self.respawns = 0
        self.retries = 0
        self.poisoned = 0
        self.timeout_kills = 0
        self.transport_errors = 0
        self.trace_lost_n = 0
        self.zombies = 0
        self.leaked_shm: List[str] = []
        self.done_msgs = 0
        self.ctrl_msgs = 0
        self.iter_ipc: List[Tuple[int, int]] = []  # (submit, done) per
        self._ipc_mark = (0, 0)                    # root quiescence
        self._images: Dict[int, _ReplayImage] = {}
        self._image_graphs: Dict[int, Any] = {}    # keep graphs alive
        self._plane_lock = None
        self._ctx = None
        # -- live metrics plane ----------------------------------------
        # The parent holds no per-task instruments (workers execute the
        # bodies); the shm counter plane IS the process backend's
        # instrument layer. The sampler rides the reaper loop + the
        # dispatcher's quiescence hook — never a task hot path.
        self.metrics_enabled = metrics
        self.instruments = NULL_METRICS
        self._counter_plane: Optional[ShmCounterPlane] = None
        self._plane_final: Optional[dict] = None
        self.sampler: Optional[MetricsSampler] = None
        if metrics:
            det = IncrementalDetector() if trace else None
            sampler = MetricsSampler(
                clock=lambda: time.perf_counter() - self._trace_t0,
                interval=metrics_interval_s,
                tracer=self.tracer if trace else None,
                detector=det)
            sampler.add_probe(
                "inflight", lambda: len(self._dispatch.inflight))
            sampler.add_probe("pending_msgs", self.policy.pending)
            sampler.add_probe(
                "ipc_submit_msgs",
                lambda: sum(self._dispatch.sub_msgs))
            sampler.add_probe("ipc_done_msgs", lambda: self.done_msgs)
            # plane probes return None until start() creates the plane
            sampler.add_probe(
                "busy_workers",
                lambda: (self._counter_plane.busy_count()
                         if self._counter_plane is not None else None))
            sampler.add_probe(
                "plane",
                lambda: (self._counter_plane.totals()
                         if self._counter_plane is not None else None))
            self.dispatcher.register_quiescent(
                "metrics-sampler", sampler.quiescent_callback,
                priority=2)
            self.sampler = sampler

    # ------------------------------------------------------------------
    # lifecycle
    def __enter__(self) -> "ProcessRuntime":
        self.start()
        return self

    def __exit__(self, *exc) -> bool:
        self.shutdown()
        return False

    def start(self) -> None:
        if self._started:
            return
        import multiprocessing as mp
        methods = mp.get_all_start_methods()
        self._ctx = mp.get_context(
            "fork" if "fork" in methods else methods[0])
        self._trace_t0 = time.perf_counter()
        self._main_thread = threading.current_thread()
        # ONE lock, created before the workers exist, guards every
        # replay-plane mutation (latches, ready ring, remaining); a
        # plane recovery replaces it (the dead worker may have held it)
        self._plane_lock = self._ctx.Lock()
        self._parent_pid = os.getpid()
        if self.metrics_enabled:
            self._counter_plane = ShmCounterPlane(self.num_workers)
            self._shm_created.add(self._counter_plane.name)
        for i in range(self.num_workers):
            p, exec_ring, done_ring = self._spawn_worker(i)
            self._exec_rings.append(exec_ring)
            self._done_rings.append(done_ring)
            self._procs.append(p)
        self._reaper = threading.Thread(target=self._reaper_loop,
                                        name="proc-reaper", daemon=True)
        self._reaper.start()
        if self.policy.needs_manager_thread:
            self._manager_thread = threading.Thread(
                target=self._manager_loop, name="proc-manager",
                daemon=True)
            self._manager_thread.start()
        self._started = True

    def _spawn_worker(self, widx: int) -> Tuple[Any, ShmRing, ShmRing]:
        """Create one worker process with a fresh exec/done ring pair.
        Used both at start() and by the supervisor's respawn path."""
        exec_fbq = self._ctx.SimpleQueue()
        done_fbq = self._ctx.SimpleQueue()
        exec_ring = ShmRing(self.ring_capacity, fallback=exec_fbq)
        done_ring = ShmRing(self.ring_capacity, fallback=done_fbq)
        self._fbqs += [exec_fbq, done_fbq]
        self._shm_created.update((exec_ring.name, done_ring.name))
        plan = self.fault_plan
        p = self._ctx.Process(
            target=_worker_main,
            args=(widx, 2 + widx, exec_ring.name, done_ring.name,
                  exec_fbq, done_fbq, self._plane_lock, self._trace_t0,
                  self._parent_pid,
                  plan.worker_stalls() if plan is not None else (),
                  plan.ignore_sigterm if plan is not None else False,
                  self._counter_plane.name
                  if self._counter_plane is not None else ""),
            name=f"procworker-{widx}", daemon=True)
        p.start()
        # a full exec ring + live worker means a slow consumer (long
        # task body), not a dead one: let push() keep waiting
        exec_ring.consumer_alive = p.is_alive
        return p, exec_ring, done_ring

    def _respawn_worker(self, widx: int, count: bool = True) -> None:
        """Swap in a fresh process + ring pair at ``widx``. The caller
        holds ``_rings_lock``, has joined the old process, and keeps
        ``dispatch.paused[widx]`` set until the swap lands (so no frame
        ships to the ring being retired)."""
        old_exec = self._exec_rings[widx]
        old_done = self._done_rings[widx]
        p, exec_ring, done_ring = self._spawn_worker(widx)
        self._exec_rings[widx] = exec_ring
        self._done_rings[widx] = done_ring
        self._procs[widx] = p
        for ring in (old_exec, old_done):
            ring.close()
            ring.unlink()
        if count:
            self.respawns += 1
        if self.tracer.enabled:
            self.tracer.mgr_event(EV_RESPAWN, 2 + widx,
                                  {"widx": widx, "pid": p.pid})

    def shutdown(self) -> None:
        if self._torn_down:
            return
        err: Optional[BaseException] = None
        if self._started and self._lost is None:
            try:
                self.taskwait()
            except BaseException as e:
                err = e
        self._teardown()
        self._aggregate_stats()
        if err is not None:
            raise err

    def _teardown(self) -> None:
        if self._torn_down:
            return
        self._torn_down = True
        self._stop.set()
        if self._reaper is not None:
            self._reaper.join(timeout=5.0)
        if self._manager_thread is not None:
            self._manager_thread.join(timeout=5.0)
        for ring in self._exec_rings:
            try:
                # drop the liveness probe for teardown: a stuck-but-
                # alive worker must not spin this push forever — it is
                # terminated just below anyway
                ring.consumer_alive = None
                ring.push(frame_ctrl(OP_SHUTDOWN), spin_s=0.2)
                self.ctrl_msgs += 1
            except BufferError:          # pragma: no cover - dead worker
                pass
        # escalation ladder: drain-join -> SIGTERM -> SIGKILL. Each
        # rung only fires for workers the previous one failed to stop;
        # a worker still alive at the SIGKILL rung counts as a zombie
        # (it ignored or blocked SIGTERM) in RuntimeStats.
        grace = max(0.1, self.shutdown_grace)
        deadline = time.perf_counter() + grace
        while any(p.is_alive() for p in self._procs) \
                and time.perf_counter() < deadline:
            self._pump_dones()           # drain final Done frames
            time.sleep(1e-3)
        for p in self._procs:
            if p.is_alive():
                p.terminate()            # SIGTERM
        for p in self._procs:
            if p.is_alive():
                p.join(timeout=min(2.0, grace))
        for p in self._procs:
            if p.is_alive():             # survived SIGTERM: escalate
                self.zombies += 1
                p.kill()                 # SIGKILL
        for p in self._procs:
            p.join(timeout=2.0)
        self._pump_dones()
        for ring in self._exec_rings + self._done_rings:
            ring.close()
            ring.unlink()
        for img in self._images.values():
            img.close_unlink()
        if self._counter_plane is not None:
            # final scrape before the segment dies: _aggregate_stats
            # runs after teardown, so metrics() serves this snapshot
            self._plane_final = self._counter_plane.snapshot()
            self._counter_plane.close_unlink()
            self._counter_plane = None
        for q in self._fbqs:
            try:
                q.close()
            except Exception:            # pragma: no cover - teardown
                pass
        # post-unlink leak scan: any segment this runtime ever created
        # that still exists in /dev/shm leaked (reported, not raised —
        # the chaos soak asserts the list is empty)
        try:
            live = set(os.listdir("/dev/shm"))
        except OSError:                  # pragma: no cover - non-Linux
            live = set()
        self.leaked_shm = sorted(
            n for n in self._shm_created if n.lstrip("/") in live)

    def _aggregate_stats(self) -> None:
        self.stats.wall_s = time.perf_counter() - self._trace_t0
        self.stats.ddast_callback_entries = self.policy.callback_entries
        st = self.policy.stats()
        self.stats.messages_processed = st["messages_processed"]
        self.stats.lock_acquisitions = st["lock_acquisitions"]
        self.stats.lock_wait_s = st["lock_wait_s"]
        self.stats.max_in_graph = st["max_in_graph"]
        self.stats.total_edges = st["total_edges"]
        self.stats.shard_messages = st.get("shard_messages", [])
        self.stats.shard_lock_wait_s = st.get("shard_lock_wait_s", [])
        self.stats.delegated_portions = st.get("delegated_portions", 0)
        self.stats.combined_drains = st.get("combined_drains", 0)
        self.stats.shard_lock_handoffs = list(
            st.get("shard_lock_handoffs", []))
        self.stats.ipc_submit_msgs = sum(self._dispatch.sub_msgs)
        self.stats.ipc_done_msgs = self.done_msgs
        self.stats.ipc_ctrl_msgs = self.ctrl_msgs
        self.stats.ipc_iter = list(self.iter_ipc)
        self.stats.worker_respawns = self.respawns
        self.stats.task_retries = self.retries
        self.stats.tasks_poisoned = self.poisoned
        self.stats.timeout_kills = self.timeout_kills
        self.stats.transport_errors = self.transport_errors
        self.stats.trace_lost = self.trace_lost_n
        self.stats.zombie_workers = self.zombies
        self.stats.leaked_shm = list(self.leaked_shm)
        if self.tracer.enabled:
            self.stats.events = self.tracer.events()
            self.stats.trace_dropped = self.tracer.dropped
        rep = st.get("replay")
        if rep:
            self.stats.replay_iterations = rep["replay_iterations"]
            self.stats.replayed_tasks = rep["replayed_tasks"]
            self.stats.replay_invalidations = rep["invalidations"]
            self.stats.replay_cache_hits = rep["cache_hits"]
        if self.metrics_enabled:
            self.stats.metrics = self.metrics()

    def shm_names(self) -> List[str]:
        """Every shared-memory segment this runtime owns (rings + replay
        planes) — the leak-check hook for tests."""
        names = [r.name for r in self._exec_rings + self._done_rings]
        for img in self._images.values():
            names += img.shm_names()
        if self._counter_plane is not None:
            names.append(self._counter_plane.name)
        return names

    def metrics(self) -> Dict[str, Any]:
        """Live metrics snapshot: the shm counter plane scraped in
        place (zero IPC frames), parent-side gauges, and the sampler's
        series rings. Callable while a run is in flight; after teardown
        it serves the final pre-unlink scrape."""
        plane = (self._counter_plane.snapshot()
                 if self._counter_plane is not None
                 else self._plane_final)
        out: Dict[str, Any] = {
            "time_unit": "s",
            "backend": "processes",
            "workers": plane or {},
            "gauges": {
                "inflight": len(self._dispatch.inflight),
                "pending_msgs": self.policy.pending(),
                "ipc_submit_msgs": sum(self._dispatch.sub_msgs),
                "ipc_done_msgs": self.done_msgs,
            },
        }
        if self.sampler is not None:
            out["sampler"] = self.sampler.snapshot()
        return out

    # ------------------------------------------------------------------
    # task API
    def task(self, func, *args, deps=(), label: str = "task",
             retries: int = 0, timeout: Optional[float] = None
             ) -> WorkDescriptor:
        """Submit one task. ``retries=N`` lets the supervisor re-dispatch
        the task up to N times after a worker death, per-task timeout, or
        body exception (at-least-once: retried bodies must be
        idempotent); 0 preserves fail-fast ``WorkerLost`` semantics.
        ``timeout=`` (seconds, dispatch-to-done) makes the supervisor
        SIGKILL a worker stuck past the deadline and retry or poison the
        task."""
        if not self._started:
            raise RuntimeError("ProcessRuntime.task() before start(): "
                               "use it as a context manager")
        if threading.current_thread() is not self._main_thread:
            raise RuntimeError("the process backend supports submissions "
                               "from the starting thread only (no nested "
                               "tasks, no client threads)")
        try:
            payload = pickle.dumps((func, args), protocol=4)
        except Exception as e:
            raise ValueError(
                f"process backend requires picklable task funcs/args "
                f"(task {label!r}): {e}") from e
        from ..runtime import _parse_deps
        wd = WorkDescriptor(func=func, args=args, deps=_parse_deps(deps),
                            label=label, parent=self._root,
                            retries=max(0, retries), timeout=timeout)
        wd._proc_payload = payload
        self._maybe_enter_capture()
        if self.tracer.enabled:
            self.tracer.task_event(EV_CREATED, wd, 1)
        self.policy.submit(wd, 1)
        self._after_submit_capture_check()
        return wd

    def taskwait(self) -> None:
        pol = self.policy
        d = self._dispatch
        pol.flush(0)
        pol.flush(1)
        if d.capture:
            g = getattr(pol, "replay_graph", None)
            img = self._images.get(id(g)) if g is not None else None
            if img is not None and pol.steady_iteration_complete():
                if self._plane_iteration(img):
                    return
                # the plane aborted mid-iteration (worker death):
                # recovery routed already-finished sids through
                # d.plane_done and re-shipped the rest live — fall
                # through to the generic drain loop
            else:
                d.flush_capture_live()
        d.flush_all()
        while True:
            if self._lost is not None:
                raise WorkerLost(self._lost)
            if self._root.num_children_alive == 0 and not pol.pending() \
                    and not d.inflight and not d.plane_ready:
                break
            worked = self._drain_plane_ready()
            worked += pol.callback(1) if pol.uses_idle_managers else 0
            if pol.pending() and not worked:
                worked += pol.drain_all()
            worked += d.flush_all()
            if not worked:
                time.sleep(2e-5)
        if d.plane_done is not None:     # recovery iteration finished
            d.plane_done = None
            d.plane_ready.clear()
            self._recover_img = None
        self._quiesce()
        self._raise_task_errors()

    # ------------------------------------------------------------------
    # replay-plane machinery
    def _maybe_enter_capture(self) -> None:
        if not self.replay:
            return
        d = self._dispatch
        if d.capture or d.captured:
            return
        pol = self.policy
        if getattr(pol, "replay_state", None) != REPLAYING:
            return
        if pol._diverged or pol._iter_started:
            return                       # only at an iteration boundary
        g = pol.replay_graph
        if g is not None and id(g) in self._images:
            d.capture = True

    def _after_submit_capture_check(self) -> None:
        d = self._dispatch
        if not d.capture:
            return
        pol = self.policy
        g = getattr(pol, "replay_graph", None)
        if pol._diverged or pol.replay_state == RECORDING \
                or g is None or id(g) not in self._images:
            d.flush_capture_live()

    def _plane_iteration(self, img: _ReplayImage) -> bool:
        """Steady-state replayed iteration: every task of the frozen
        graph runs worker-side off the shared plane. Cross-process cost:
        one CTRL(ITER) frame per worker — zero Submit/Done messages.

        Returns True when the iteration completed on the plane; False
        when a worker died mid-iteration and :meth:`_recover_plane`
        invalidated this generation (the caller falls back to the live
        drain loop to finish the iteration)."""
        pol = self.policy
        d = self._dispatch
        self._plane_dead = None
        self._plane_active = True
        try:
            with self._plane_lock:
                img.reset()
            with self._rings_lock:
                for widx, ring in enumerate(self._exec_rings):
                    ring.push(frame_ctrl(OP_ITER, dict(img.desc)))
                    self.ctrl_msgs += 1
            plan = self.fault_plan
            if plan is not None:
                doomed = plan.on_iter_broadcast()
                if doomed:
                    time.sleep(5e-3)     # let workers claim some sids
                    for w in doomed:
                        self._kill_worker_proc(w)
            fired: set = set()
            while img.remaining() != 0:
                if self._lost is not None:
                    stuck = ", ".join(img.unfinished_labels()[:4])
                    raise WorkerLost(
                        f"{self._lost} (replay plane stalled; "
                        f"unfinished: {stuck})")
                if self._plane_dead is not None:
                    self._recover_plane(img)
                    return False
                self._plane_timeouts(img, fired)
                time.sleep(2e-5)
        finally:
            self._plane_active = False
        d.capture = False
        d.captured = []
        d.discard = True
        try:
            tr = self.tracer
            for sid in range(img.n):
                wd = pol._iter_wds[sid]
                t0, t1 = img.times(sid)
                wd.exec_dur = t1 - t0
                wd.exec_span = (t0, t1)
                wd.mark_finished()
                if tr.enabled:
                    slot = img.exec_slot(sid)
                    tr.ingest([(t0, EV_START, wd.wd_id, slot, wd.label,
                                wd.scope, None),
                               (t1, EV_END, wd.wd_id, slot, wd.label,
                                wd.scope, None)])
                pol.complete(wd, 0)
                self.stats.tasks_executed += 1
        finally:
            d.discard = False
        self._quiesce()
        self._raise_task_errors()
        return True

    def _plane_timeouts(self, img: _ReplayImage, fired: set) -> None:
        """Per-task ``timeout=`` enforcement during a plane iteration:
        a sid claimed (t0 stamped at pop) but unfinished past its
        deadline gets its worker SIGKILLed; the death flows through
        :meth:`_recover_plane`, which classifies the sid as a culprit
        and retries or poisons it."""
        wds = getattr(self.policy, "_iter_wds", None)
        if not wds:
            return
        now = time.perf_counter() - self._trace_t0
        for sid in range(img.n):
            if sid in fired:
                continue
            wd = wds[sid]
            if wd is None or wd.timeout is None:
                continue
            t0, t1 = img.times(sid)
            if t0 == 0.0 or t1 != 0.0 or now - t0 <= wd.timeout:
                continue
            slot = img.exec_slot(sid)
            if slot < 2:                 # pragma: no cover - defensive
                continue
            fired.add(sid)
            wd._timed_out = True
            self.timeout_kills += 1
            if self.tracer.enabled:
                self.tracer.task_event(EV_TIMEOUT_KILL, wd, slot,
                                       {"timeout": wd.timeout})
            self._kill_worker_proc(slot - 2)

    def _recover_plane(self, img: _ReplayImage) -> None:
        """A worker died mid plane iteration. Invalidate ONLY this
        generation: wait for the survivors to stall, kill + join every
        worker (a survivor may be blocked on the plane lock the dead
        worker held), classify each sid — finished, culprit (claimed by
        a genuinely dead worker: retry or poison), or innocent (claimed
        by a worker we killed ourselves: rerun free) — then respawn the
        fleet against a fresh plane lock and route the remainder of the
        iteration through live analysis via ``dispatch.plane_done``."""
        pol = self.policy
        d = self._dispatch
        prev = img.remaining()
        stable = time.perf_counter()
        deadline = stable + 2.0
        while time.perf_counter() < deadline and img.remaining() != 0:
            rem = img.remaining()
            if rem != prev:
                prev, stable = rem, time.perf_counter()
            elif time.perf_counter() - stable > 0.05:
                break                    # progress stalled: harvest now
            time.sleep(1e-3)
        with self._rings_lock:
            dead = {w for w, p in enumerate(self._procs)
                    if not p.is_alive()}
            for w in range(self.num_workers):
                self._kill_worker_proc(w)
            for p in self._procs:
                p.join(timeout=5.0)
            self._pump_dones()           # final DONE_PLANE_ERROR frames
            done_map: Dict[int, str] = {}
            culprits: List[int] = []
            for sid in range(img.n):
                t0, t1 = img.times(sid)
                slot = img.exec_slot(sid)
                if t1 != 0.0:
                    done_map[sid] = "done"
                elif slot >= 2 and (slot - 2) in dead:
                    culprits.append(sid)
                # else: never claimed, or claimed by a worker we killed
                # ourselves — reruns live without burning a retry
            wds = pol._iter_wds
            hard = [sid for sid in culprits
                    if wds[sid].retries == 0
                    and not getattr(wds[sid], "_timed_out", False)]
            if hard:
                labels = ", ".join(wds[sid].label for sid in hard[:4])
                self._lost = (
                    f"worker process(es) {sorted(dead)} died mid "
                    f"replay-plane iteration with {len(culprits)} "
                    f"claimed task(s) in flight: {labels}")
                raise WorkerLost(self._lost)
            if self.tracer.enabled:
                for w in sorted(dead):
                    self.tracer.mgr_event(
                        EV_WORKER_LOST, 2 + w,
                        {"widx": w, "plane": True,
                         "lost": [wds[sid].label for sid in culprits
                                  if img.exec_slot(sid) == 2 + w]})
            self.trace_lost_n += len(culprits)
            for sid in culprits:
                wd = wds[sid]
                reason = "timeout" if getattr(wd, "_timed_out", False) \
                    else "worker_lost"
                wd.attempts.append(
                    {"worker": img.exec_slot(sid) - 2, "reason": reason,
                     "t": time.perf_counter() - self._trace_t0})
                if self.tracer.enabled:
                    self.tracer.task_event(
                        EV_TRACE_LOST, wd, img.exec_slot(sid), None)
                if wd.retries_left > 0:
                    wd.retries_left -= 1
                    wd._timed_out = False
                    self.retries += 1
                    if self.tracer.enabled:
                        self.tracer.task_event(
                            EV_RETRY, wd, 1,
                            {"attempt": len(wd.attempts),
                             "reason": reason})
                else:
                    done_map[sid] = "poisoned"
                    self.poisoned += 1
                    with self._errors_lock:
                        self._errors.append(
                            (wd.label,
                             f"{reason} on the replay plane (retries "
                             f"exhausted)", list(wd.attempts)))
            if self.respawns + len(dead) > self.max_respawns:
                self._lost = (f"respawn budget ({self.max_respawns}) "
                              f"exhausted during plane recovery")
                raise WorkerLost(self._lost)
            # fresh plane lock: the old one may be held by a dead
            # process, which would deadlock every future iteration
            self._plane_lock = self._ctx.Lock()
            for w in range(self.num_workers):
                self._respawn_worker(w, count=(w in dead))
        # route the rest of the iteration through live analysis: roots
        # re-enter via push_replay, which completes plane-finished (and
        # poisoned) sids from plane_done instead of re-executing them
        d.plane_done = done_map
        self._recover_img = img
        d.capture = False
        cap, d.captured = d.captured, []
        for wd, sid in cap:
            d.push_replay(wd, sid)

    def _drain_plane_ready(self) -> int:
        """Complete tasks the aborted plane generation already ran (or
        poisoned): stamp their plane times, ingest trace stamps, and
        cascade through the policy so successors become ready."""
        d = self._dispatch
        if not d.plane_ready:
            return 0
        pol = self.policy
        img = self._recover_img
        n = 0
        while d.plane_ready:
            wd, sid = d.plane_ready.popleft()
            if d.plane_done.get(sid) == "done" and img is not None:
                t0, t1 = img.times(sid)
                wd.exec_dur = t1 - t0
                wd.exec_span = (t0, t1)
                if self.tracer.enabled:
                    slot = img.exec_slot(sid)
                    self.tracer.ingest(
                        [(t0, EV_START, wd.wd_id, slot, wd.label,
                          wd.scope, None),
                         (t1, EV_END, wd.wd_id, slot, wd.label,
                          wd.scope, None)])
                self.stats.tasks_executed += 1
            wd.mark_finished()
            pol.complete(wd, 0)
            n += 1
        return n

    def _quiesce(self) -> None:
        pol = self.policy
        sid_snapshot = None
        if self.replay and getattr(pol, "replay_state", None) == RECORDING:
            sid_snapshot = dict(pol._rec_sid_of)
        pol.notify_quiescent(True)
        if self.tracer.enabled:
            self.tracer.quiesce(
                {"scope": None,
                 "replay_iterations": replay_iterations_of(pol, None)})
        self.dispatcher.notify_quiescent(1)
        sub = sum(self._dispatch.sub_msgs)
        done = self.done_msgs
        self.iter_ipc.append((sub - self._ipc_mark[0],
                              done - self._ipc_mark[1]))
        self._ipc_mark = (sub, done)
        if sid_snapshot is not None:
            self._maybe_build_image(sid_snapshot)

    def _maybe_build_image(self, sid_snapshot: Dict[int, int]) -> None:
        """A recording may just have frozen: materialize its replay
        plane in shared memory. The process backend admits no nested
        tasks, so every recording is flat (one namespace) and the
        recording's sid numbering is exactly the frozen graph's."""
        pol = self.policy
        d = self._dispatch
        payload_of, d.payload_of = d.payload_of, {}
        if pol.replay_state != REPLAYING:
            return
        g = pol.replay_graph
        if g is None or id(g) in self._images:
            self._prune_images()
            return
        if len(sid_snapshot) != g.n:
            return                       # not this recording's graph
        entries: List[Optional[Tuple[bytes, str]]] = [None] * g.n
        for wd_id, sid in sid_snapshot.items():
            entries[sid] = payload_of.get(wd_id)
        if any(e is None for e in entries):
            return                       # payload missing: stay live
        self._images[id(g)] = _ReplayImage(g, entries)
        self._image_graphs[id(g)] = g
        self._prune_images()

    def _prune_images(self) -> None:
        pol = self.policy
        cache = getattr(pol, "_cache", {})
        alive = {id(g) for g in cache.values()}
        g = getattr(pol, "replay_graph", None)
        if g is not None:
            alive.add(id(g))
        for key in list(self._images):
            if key not in alive:
                self._images.pop(key).close_unlink()
                self._image_graphs.pop(key, None)

    # ------------------------------------------------------------------
    # reaper: the single consumer of every Done ring
    def _reaper_loop(self) -> None:
        pol = self.policy
        while not self._stop.is_set():
            with self._rings_lock:
                n = self._pump_dones()
            n += self._dispatch.flush_all()
            if pol.uses_idle_managers:
                n += pol.callback(0)
            self._check_workers()
            # the reaper never reaches the dispatcher's notify_idle
            # path, so it ticks the sampler directly between polls
            if self.sampler is not None:
                self.sampler.tick()
            if not n:
                time.sleep(2e-5)

    def _pump_dones(self) -> int:
        """Drain every Done ring. Callers hold ``_rings_lock`` (except
        teardown, which runs after the reaper joined). A CRC failure on
        a frame is a structured transport error: count it and kill the
        producing worker — the supervision path respawns it and retries
        its in-flight tasks."""
        n = 0
        plan = self.fault_plan
        for widx in range(len(self._done_rings)):
            ring = self._done_rings[widx]
            while True:
                try:
                    frame = ring.pop()
                except RingCorruption:
                    self.transport_errors += 1
                    if not self._torn_down:
                        self._kill_worker_proc(widx)
                    break
                if frame is None:
                    break
                if plan is not None:
                    act = plan.on_done_frame(widx)
                    if act == "drop":    # lost done: only timeout=
                        continue         # recovers the task
                    if isinstance(act, tuple):
                        time.sleep(act[1])
                n += 1
                self._handle_frame(frame, widx)
        return n

    def _handle_frame(self, frame: bytes, widx: int) -> None:
        kind = frame[0]
        if kind == K_TRACE:              # pragma: no cover - legacy
            if self.tracer.enabled:
                self.tracer.ingest(serial.parse(frame)[1])
            return
        if kind != K_DONE:               # pragma: no cover - defensive
            return
        self.done_msgs += 1
        if self.policy.charge is not None:
            self.policy.charge.ipc_done()
        for wd_id, t0, t1, status, blob in decode_done_batch(frame, 1):
            if status == DONE_PLANE_ERROR:
                with self._errors_lock:
                    self._errors.append(
                        (f"replay sid {wd_id}",
                         blob.decode("utf-8", "replace"), []))
                continue
            entry = self._dispatch.task_done(wd_id)
            if entry is None:            # pragma: no cover - defensive
                continue
            wd, w, _t_enq = entry
            wd.exec_dur = t1 - t0
            wd.exec_span = (t0, t1)
            if self.tracer.enabled:
                # parent-side lifecycle reconstruction: workers ship no
                # trace frames; START/END come from the done stamps, so
                # a crashed worker costs only its un-acked tasks' events
                self.tracer.ingest(
                    [(t0, EV_START, wd.wd_id, 2 + w, wd.label,
                      wd.scope, None),
                     (t1, EV_END, wd.wd_id, 2 + w, wd.label,
                      wd.scope, None)])
            if status == DONE_OK and blob:
                try:
                    wd.result = pickle.loads(blob)
                except Exception:        # pragma: no cover - defensive
                    pass
            elif status == DONE_ERROR:
                if wd.retries_left > 0:
                    self._retry(wd, w, "error")
                    continue             # not finished: re-dispatched
                self.poisoned += 1
                with self._errors_lock:
                    self._errors.append(
                        (wd.label, blob.decode("utf-8", "replace"),
                         list(wd.attempts)))
            wd.mark_finished()
            self.policy.complete(wd, 0)
            self.stats.tasks_executed += 1

    # ------------------------------------------------------------------
    # supervision: death detection, timeouts, respawn, retry/poison
    def _check_workers(self) -> None:
        now = time.perf_counter()
        if now - self._last_check < 5e-3 or self._lost is not None:
            return
        self._last_check = now
        if not self._plane_active:
            self._timeout_scan(now)
        for widx, p in enumerate(self._procs):
            if p.is_alive():
                continue
            if self._plane_active:
                # the main thread owns plane recovery: just flag it
                self._plane_dead = widx
                return
            self._handle_worker_death(widx)
            return                       # one death per tick; the next
            #                              tick catches any others

    def _timeout_scan(self, now: float) -> None:
        """Enforce per-task ``timeout=``: a task dispatched longer ago
        than its deadline gets its worker SIGKILLed (the only way to
        interrupt a stuck body in another process); the death handler
        then retries or poisons it with reason ``timeout``."""
        for wd, widx, t_enq in list(self._dispatch.inflight.values()):
            if wd.timeout is None or getattr(wd, "_timed_out", False):
                continue
            if now - t_enq <= wd.timeout:
                continue
            wd._timed_out = True
            self.timeout_kills += 1
            if self.tracer.enabled:
                self.tracer.task_event(EV_TIMEOUT_KILL, wd, 2 + widx,
                                       {"timeout": wd.timeout})
            self._kill_worker_proc(widx)

    def _handle_worker_death(self, widx: int) -> None:
        """Runs on the reaper thread when worker ``widx`` is found dead
        outside a plane iteration: harvest its final done frames, split
        its in-flight tasks into buffered (never shipped — they flush
        to the replacement) and lost, fail fast if a lost task has
        ``retries=0`` (and did not time out), otherwise respawn the
        worker and retry or poison each lost task."""
        d = self._dispatch
        p = self._procs[widx]
        p.join(timeout=5.0)
        pid, exitcode = p.pid, p.exitcode
        with d._locks[widx]:
            d.paused[widx] = True        # buffer, don't ship, while the
            #                              rings are being swapped
        with self._rings_lock:
            self._pump_dones()           # completed != lost
            with d._locks[widx]:
                buffered = {e[0] for e in d._buffers[widx]}
                lost = [wd for wd_id, (wd, w, _t)
                        in list(d.inflight.items())
                        if w == widx and wd_id not in buffered]
                for wd in lost:
                    d.task_done(wd.wd_id)
            hard = [wd for wd in lost if wd.retries == 0
                    and not getattr(wd, "_timed_out", False)]
            if hard:
                labels = ", ".join(wd.label for wd in hard[:4])
                self._lost = (
                    f"worker process {widx} (pid {pid}, exitcode "
                    f"{exitcode}) died with {len(lost)} task(s) in "
                    f"flight: {labels or 'none'}")
                return                   # retries=0 keeps fail-fast
            #                              semantics: no respawn
            if self.tracer.enabled:
                self.tracer.mgr_event(
                    EV_WORKER_LOST, 2 + widx,
                    {"widx": widx, "pid": pid, "exitcode": exitcode,
                     "lost": [wd.label for wd in lost]})
                for wd in lost:
                    # their START events can never be reconstructed:
                    # the done stamps died with the worker
                    self.tracer.task_event(EV_TRACE_LOST, wd,
                                           2 + widx, None)
            self.trace_lost_n += len(lost)
            if self.respawns >= self.max_respawns:
                self._lost = (f"respawn budget ({self.max_respawns}) "
                              f"exhausted after worker {widx} died")
                return
            self._respawn_worker(widx)
        with d._locks[widx]:
            d.paused[widx] = False       # buffered tasks flush to the
            #                              replacement via flush_all
        for wd in lost:
            reason = "timeout" if getattr(wd, "_timed_out", False) \
                else "worker_lost"
            self._retry_or_poison(wd, widx, reason)

    def _retry(self, wd: WorkDescriptor, widx: int, reason: str) -> None:
        wd.retries_left -= 1
        wd._timed_out = False            # fresh deadline on re-dispatch
        wd.attempts.append({"worker": widx, "reason": reason,
                            "t": time.perf_counter() - self._trace_t0})
        self.retries += 1
        if self.tracer.enabled:
            self.tracer.task_event(EV_RETRY, wd, 2 + widx,
                                   {"attempt": len(wd.attempts),
                                    "reason": reason})
        self._dispatch.push(wd)

    def _retry_or_poison(self, wd: WorkDescriptor, widx: int,
                         reason: str) -> None:
        if wd.retries_left > 0:
            self._retry(wd, widx, reason)
            return
        wd.attempts.append({"worker": widx, "reason": reason,
                            "t": time.perf_counter() - self._trace_t0})
        self.poisoned += 1
        with self._errors_lock:
            self._errors.append(
                (wd.label,
                 f"{reason} (retries exhausted after "
                 f"{len(wd.attempts)} attempt(s))", list(wd.attempts)))
        wd.mark_finished()
        self.policy.complete(wd, 0)

    def _kill_worker_proc(self, widx: int) -> None:
        p = self._procs[widx]
        if p.pid is None:                # pragma: no cover - defensive
            return
        try:
            os.kill(p.pid, signal.SIGKILL)
        except (ProcessLookupError, PermissionError):
            pass                         # already gone

    def _chaos_shipped(self, count: int) -> None:
        """Fault-plan hook, called by dispatch after shipping a frame of
        ``count`` tasks: fire any kill whose threshold was crossed."""
        plan = self.fault_plan
        if plan is None:                 # pragma: no cover - defensive
            return
        doomed = plan.on_task_shipped(count)
        if doomed:
            time.sleep(2e-3)             # let the victim pop the frame
            for widx in doomed:
                self._kill_worker_proc(widx)

    def _raise_task_errors(self) -> None:
        with self._errors_lock:
            if not self._errors:
                return
            errors, self._errors = self._errors, []
        where, tb, attempts = errors[0]
        more = f" (+{len(errors) - 1} more)" if len(errors) > 1 else ""
        att = f" after {len(attempts)} attempt(s)" if attempts else ""
        raise TaskFailed(f"task {where!r} raised in a worker "
                         f"process{att}{more}:\n{tb}", failures=errors)

    def _manager_loop(self) -> None:
        while not self._stop.is_set():
            if self.policy.drain_all() == 0:
                time.sleep(1e-6)

    # -- probes mirroring TaskRuntime ----------------------------------
    def ready_count(self) -> int:
        return self._dispatch.ready_count()

    def in_graph_count(self) -> int:
        return self.policy.in_graph()
