"""Process-safe oracle applications for the ``backend="processes"``
driver.

The taskgraph apps used by the threaded tests close over numpy/JAX
arrays living in the submitting process — useless once bodies execute in
a worker process. These kernels instead keep all task data in named
``multiprocessing.shared_memory`` blocks (float64, attached on first
touch and cached per process) and are module-level functions of plain
picklable arguments, so they ship over the exec rings and over the
replay plane alike.

Every kernel is **order-sensitive by construction**: updates are
multiply-accumulate chains (``x = x * c + delta``-shaped), not plain
sums, so executing two tasks that the dependence discipline orders would
produce *different floats* if the runtime ever ran them the other way
round. The test oracle is therefore exact equality against a serial
run of the same kernels in submission order — the strongest ordering
check floats admit.

Three classic graphs, mirroring the threaded suite:

  * blocked matmul  — ``C[i,j] += A[i,k] @ B[k,j]``: an inout chain
    over k per C block, independent across (i, j);
  * sparse LU       — lu0/fwd/bdiv/bmod over a deterministic sparse
    block pattern: the paper's irregular-dependence workhorse;
  * N-Body (flat)   — force rows (in: all positions) then integrate
    rows (inout per row): wide fork-join.
"""
from __future__ import annotations

from multiprocessing import shared_memory
from typing import Dict, List, Tuple

# per-process attachment cache: workers touch the same blocks for every
# task (and every replay iteration); re-attaching per task would cost a
# syscall per body
_ATTACHED: Dict[str, shared_memory.SharedMemory] = {}


def _attach(name: str):
    # attachers are always multiprocessing children of the creator, so
    # the shared resource_tracker makes the attach-side re-register a
    # no-op (see procs.rings.attach_shm); the creator alone unlinks
    shm = _ATTACHED.get(name)
    if shm is None:
        shm = _ATTACHED[name] = shared_memory.SharedMemory(name=name)
    return shm.buf.cast("d")


class ShmArray:
    """Owner-side named float64 array. Create in the parent, pass
    ``.name`` (a string — picklable) into task args; kernels attach
    lazily wherever they run."""

    def __init__(self, n: int) -> None:
        self.n = n
        self.shm = shared_memory.SharedMemory(create=True, size=8 * n)
        self.name = self.shm.name
        self.view = self.shm.buf.cast("d")
        for i in range(n):
            self.view[i] = 0.0

    def __getitem__(self, i: int) -> float:
        return self.view[i]

    def __setitem__(self, i: int, v: float) -> None:
        self.view[i] = v

    def tolist(self) -> List[float]:
        return [self.view[i] for i in range(self.n)]

    def close_unlink(self) -> None:
        self.view.release()
        self.shm.close()
        try:
            self.shm.unlink()
        except FileNotFoundError:        # pragma: no cover - teardown
            pass


def fill_deterministic(arr: ShmArray, seed: int) -> None:
    """Reproducible non-trivial contents without numpy: an LCG stream."""
    x = (seed * 2654435761 + 1) & 0xFFFFFFFF
    for i in range(arr.n):
        x = (1103515245 * x + 12345) & 0x7FFFFFFF
        arr[i] = (x / 0x7FFFFFFF) - 0.5


def spin(us: float) -> None:
    """A CPU-bound body of roughly ``us`` microseconds: pure arithmetic,
    no syscalls, never releases the GIL — the workload class where the
    threaded driver flatlines and the process backend does not."""
    t = 0.6180339887
    # ~45ns/iter on this class of host; close enough for benchmarking
    for _ in range(max(1, int(us * 22))):
        t = t * t - 0.25 if t < 1.0 else t - 1.0


# ---------------------------------------------------------------------------
# blocked matmul: C[i,j] += A[i,k] . B[k,j], bs x bs blocks in an N x N
# block grid; all three matrices live in one shm array each, row-major
# (N*bs) x (N*bs)

def gemm_block(an: str, bn: str, cn: str, N: int, bs: int,
               i: int, j: int, k: int, spin_us: float = 0.0) -> None:
    A, B, C = _attach(an), _attach(bn), _attach(cn)
    dim = N * bs
    if spin_us:
        spin(spin_us)
    for r in range(bs):
        ar = (i * bs + r) * dim + k * bs
        cr = (i * bs + r) * dim + j * bs
        for c in range(bs):
            acc = 0.0
            bc = j * bs + c
            for t in range(bs):
                acc += A[ar + t] * B[(k * bs + t) * dim + bc]
            # multiply-accumulate: k-order matters bit-for-bit
            C[cr + c] = C[cr + c] * 0.999 + acc


def submit_matmul(rt, an: str, bn: str, cn: str, N: int, bs: int,
                  spin_us: float = 0.0) -> List[tuple]:
    """Submit the blocked matmul; returns the (func, args, deps, label)
    tuples it submitted so a serial oracle can re-run them in order."""
    calls = []
    for i in range(N):
        for j in range(N):
            for k in range(N):
                args = (an, bn, cn, N, bs, i, j, k, spin_us)
                deps = [(("A", i, k), "in"), (("B", k, j), "in"),
                        (("C", i, j), "inout")]
                calls.append((gemm_block, args, deps,
                              f"gemm[{i},{j},{k}]"))
                rt.task(gemm_block, *args, deps=deps,
                        label=f"gemm[{i},{j},{k}]")
    return calls


# ---------------------------------------------------------------------------
# sparse LU over an nb x nb block pattern (bs x bs dense blocks stored
# contiguously per block slot: block (i,j) occupies [(i*nb+j)*bs*bs, ...))

def sparse_pattern(nb: int) -> List[Tuple[int, int]]:
    """Deterministic sparse block structure: diagonal always present,
    off-diagonals from a fixed pseudo-random rule (~40% fill)."""
    pat = []
    for i in range(nb):
        for j in range(nb):
            if i == j or ((i * 7 + j * 13 + (i * j) % 5) % 10) < 4:
                pat.append((i, j))
    return pat


def _boff(nb: int, bs: int, i: int, j: int) -> int:
    return (i * nb + j) * bs * bs


def lu0(mn: str, nb: int, bs: int, k: int) -> None:
    M = _attach(mn)
    o = _boff(nb, bs, k, k)
    for d in range(bs):
        piv = M[o + d * bs + d]
        if -1e-12 < piv < 1e-12:
            piv = 1.0 if piv >= 0 else -1.0
        for r in range(d + 1, bs):
            M[o + r * bs + d] = M[o + r * bs + d] / piv
            f = M[o + r * bs + d]
            for c in range(d + 1, bs):
                M[o + r * bs + c] = M[o + r * bs + c] - f * M[o + d * bs + c]


def fwd(mn: str, nb: int, bs: int, k: int, j: int) -> None:
    M = _attach(mn)
    ok, oj = _boff(nb, bs, k, k), _boff(nb, bs, k, j)
    for d in range(bs):
        for r in range(d + 1, bs):
            f = M[ok + r * bs + d]
            for c in range(bs):
                M[oj + r * bs + c] = M[oj + r * bs + c] - f * M[oj + d * bs + c]


def bdiv(mn: str, nb: int, bs: int, k: int, i: int) -> None:
    M = _attach(mn)
    ok, oi = _boff(nb, bs, k, k), _boff(nb, bs, i, k)
    for d in range(bs):
        piv = M[ok + d * bs + d]
        if -1e-12 < piv < 1e-12:
            piv = 1.0 if piv >= 0 else -1.0
        for r in range(bs):
            M[oi + r * bs + d] = M[oi + r * bs + d] / piv
            f = M[oi + r * bs + d]
            for c in range(d + 1, bs):
                M[oi + r * bs + c] = M[oi + r * bs + c] - f * M[ok + d * bs + c]


def bmod(mn: str, nb: int, bs: int, k: int, i: int, j: int) -> None:
    M = _attach(mn)
    oi, oj, ot = (_boff(nb, bs, i, k), _boff(nb, bs, k, j),
                  _boff(nb, bs, i, j))
    for r in range(bs):
        for c in range(bs):
            acc = 0.0
            for t in range(bs):
                acc += M[oi + r * bs + t] * M[oj + t * bs + c]
            M[ot + r * bs + c] = M[ot + r * bs + c] - acc


def submit_sparselu(rt, mn: str, nb: int, bs: int) -> List[tuple]:
    pat = set(sparse_pattern(nb))
    calls = []

    def sub(func, args, deps, label):
        calls.append((func, args, deps, label))
        rt.task(func, *args, deps=deps, label=label)

    for k in range(nb):
        sub(lu0, (mn, nb, bs, k), [(("M", k, k), "inout")], f"lu0[{k}]")
        for j in range(k + 1, nb):
            if (k, j) in pat:
                sub(fwd, (mn, nb, bs, k, j),
                    [(("M", k, k), "in"), (("M", k, j), "inout")],
                    f"fwd[{k},{j}]")
        for i in range(k + 1, nb):
            if (i, k) in pat:
                sub(bdiv, (mn, nb, bs, k, i),
                    [(("M", k, k), "in"), (("M", i, k), "inout")],
                    f"bdiv[{k},{i}]")
        for i in range(k + 1, nb):
            if (i, k) not in pat:
                continue
            for j in range(k + 1, nb):
                if (k, j) in pat and (i, j) in pat:
                    sub(bmod, (mn, nb, bs, k, i, j),
                        [(("M", i, k), "in"), (("M", k, j), "in"),
                         (("M", i, j), "inout")],
                        f"bmod[{k},{i},{j}]")
    return calls


# ---------------------------------------------------------------------------
# flat N-Body: pos/vel/acc are n-element shm arrays (1-D bodies keep the
# arithmetic cheap; the dependence shape is what's under test)

def nbody_force(pn: str, an_: str, n: int, i: int) -> None:
    P, A = _attach(pn), _attach(an_)
    acc = 0.0
    xi = P[i]
    for j in range(n):
        if j != i:
            d = P[j] - xi
            d2 = d * d + 1e-3
            acc += d / (d2 * d2)
    A[i] = acc


def nbody_update(pn: str, vn: str, an_: str, i: int,
                 dt: float = 1e-3) -> None:
    P, V, A = _attach(pn), _attach(vn), _attach(an_)
    V[i] = V[i] * 0.999 + A[i] * dt
    P[i] = P[i] + V[i] * dt


def submit_nbody(rt, pn: str, vn: str, an_: str, n: int,
                 steps: int = 1) -> List[tuple]:
    calls = []

    def sub(func, args, deps, label):
        calls.append((func, args, deps, label))
        rt.task(func, *args, deps=deps, label=label)

    all_pos = [(("P", j), "in") for j in range(n)]
    for s in range(steps):
        for i in range(n):
            sub(nbody_force, (pn, an_, n, i),
                all_pos + [(("A", i), "out")], f"force[{s},{i}]")
        for i in range(n):
            sub(nbody_update, (pn, vn, an_, i),
                [(("A", i), "in"), (("V", i), "inout"),
                 (("P", i), "inout")], f"update[{s},{i}]")
    return calls


def run_serial(calls: List[tuple]) -> None:
    """The oracle: the exact same kernels, submission order, in-process.
    Any dependence-ordering violation by a parallel backend shows up as
    float inequality against this."""
    for func, args, _deps, _label in calls:
        func(*args)
