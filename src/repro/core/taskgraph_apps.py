"""The paper's evaluation applications (§4.2) on the task runtime.

Each app exists in two forms:
  * ``sim_*_specs``  — a SimTaskSpec graph with virtual durations, consumed
    by core.simulator (reproduces Figs 5-11 scalability/tuning results);
  * ``run_*``        — a real execution on core.runtime.TaskRuntime where
    each task body is a jitted JAX block kernel (validates runtime
    correctness against dense oracles).

Dependence patterns follow the paper exactly:
  Matmul    — regular, independent chains per output block (§4.2.1)
  N-Body    — regular chains + NESTED tasks (§4.2.2): one top-level task
              per timestep creates the per-block children
  Sparse LU — complex irregular pattern (§4.2.3)

Each app additionally has a ``run_*_epochs`` variant that re-submits the
SAME task graph once per epoch with a root taskwait between epochs (the
paper's iterative usage: matmul epochs, N-Body timesteps, repeated
sparse-LU factorizations) — the shape the record-and-replay subsystem
(``engine/replay.py``, ``replay=True`` on both drivers) turns into
analysis-free steady-state iterations.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .simulator import SimTaskSpec
from .wd import DepMode

IN, OUT, INOUT = DepMode.IN, DepMode.OUT, DepMode.INOUT


def sim_app_specs(app: str, scale: Optional[int] = None) -> List[SimTaskSpec]:
    """Named access to the three paper app graphs at a given scale —
    the sweep axis used by benchmarks/bench_shards.py and the CI smoke
    run. ``scale`` is nb for matmul/sparselu and nblocks for nbody."""
    if app == "matmul":
        return sim_matmul_specs(scale or 8, dur_us=100.0)
    if app == "nbody":
        return sim_nbody_specs(scale or 8, timesteps=2)
    if app == "sparselu":
        return sim_sparselu_specs(scale or 10)
    raise ValueError(f"unknown app {app!r} (matmul|nbody|sparselu)")


# ===========================================================================
# Matmul (§4.2.1): C[i,j] += A[i,k] @ B[k,j]
# ===========================================================================

def sim_matmul_specs(nb: int, dur_us: float = 100.0) -> List[SimTaskSpec]:
    """nb x nb blocked matmul task graph; nb**3 tasks; per-output-block
    chains of length nb (the paper's 'several independent chains')."""
    specs = []
    for i in range(nb):
        for j in range(nb):
            for k in range(nb):
                specs.append(SimTaskSpec(
                    dur=dur_us,
                    deps=[(("A", i, k), IN), (("B", k, j), IN),
                          (("C", i, j), INOUT)],
                    label=f"gemm{i}.{j}.{k}"))
    return specs


@functools.partial(jax.jit, donate_argnums=(2,))
def _gemm_block(a: jax.Array, b: jax.Array, c: jax.Array) -> jax.Array:
    return c + a @ b


def run_matmul(rt, a: np.ndarray, b: np.ndarray, bs: int) -> np.ndarray:
    """Blocked matmul on the task runtime. Returns C = A @ B."""
    ms = a.shape[0]
    assert ms % bs == 0
    nb = ms // bs
    ab = {(i, k): jnp.asarray(a[i * bs:(i + 1) * bs, k * bs:(k + 1) * bs])
          for i in range(nb) for k in range(nb)}
    bb = {(k, j): jnp.asarray(b[k * bs:(k + 1) * bs, j * bs:(j + 1) * bs])
          for k in range(nb) for j in range(nb)}
    cb: Dict[Tuple[int, int], jax.Array] = {
        (i, j): jnp.zeros((bs, bs), a.dtype) for i in range(nb)
        for j in range(nb)}

    def gemm(i: int, j: int, k: int) -> None:
        cb[(i, j)] = _gemm_block(ab[(i, k)], bb[(k, j)], cb[(i, j)])

    for i in range(nb):
        for j in range(nb):
            for k in range(nb):
                rt.task(gemm, i, j, k,
                        deps=[(("A", i, k), IN), (("B", k, j), IN),
                              (("C", i, j), INOUT)],
                        label=f"gemm{i}.{j}.{k}")
    rt.taskwait()
    out = np.empty_like(a)
    for (i, j), blk in cb.items():
        out[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs] = np.asarray(blk)
    return out


def run_matmul_epochs(rt, a: np.ndarray, b: np.ndarray, bs: int,
                      epochs: int) -> np.ndarray:
    """Iterative blocked matmul: the same nb³ gemm graph submitted
    ``epochs`` times into the accumulating C blocks (one root taskwait
    per epoch). Returns C = epochs * (A @ B) — structurally identical
    iterations, the record-and-replay steady-state case."""
    ms = a.shape[0]
    assert ms % bs == 0
    nb = ms // bs
    ab = {(i, k): jnp.asarray(a[i * bs:(i + 1) * bs, k * bs:(k + 1) * bs])
          for i in range(nb) for k in range(nb)}
    bb = {(k, j): jnp.asarray(b[k * bs:(k + 1) * bs, j * bs:(j + 1) * bs])
          for k in range(nb) for j in range(nb)}
    cb: Dict[Tuple[int, int], jax.Array] = {
        (i, j): jnp.zeros((bs, bs), a.dtype) for i in range(nb)
        for j in range(nb)}

    def gemm(i: int, j: int, k: int) -> None:
        cb[(i, j)] = _gemm_block(ab[(i, k)], bb[(k, j)], cb[(i, j)])

    for _ in range(epochs):
        for i in range(nb):
            for j in range(nb):
                for k in range(nb):
                    rt.task(gemm, i, j, k,
                            deps=[(("A", i, k), IN), (("B", k, j), IN),
                                  (("C", i, j), INOUT)],
                            label=f"gemm{i}.{j}.{k}")
        rt.taskwait()
    out = np.empty_like(a)
    for (i, j), blk in cb.items():
        out[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs] = np.asarray(blk)
    return out


# ===========================================================================
# Sparse LU (§4.2.3): blocked LU over a sparse block pattern
# ===========================================================================

def sparse_pattern(nb: int) -> List[List[bool]]:
    """BSC SparseLU-style initial block occupancy: diagonal + an irregular
    subset (creates the paper's 'much more complex and irregular' graph)."""
    return [[i == j or (i + j) % 3 != 1 or j == 0 or i == 0
             for j in range(nb)] for i in range(nb)]


def sim_sparselu_specs(nb: int, dur_lu0: float = 120.0,
                       dur_fwd: float = 100.0, dur_bdiv: float = 100.0,
                       dur_bmod: float = 110.0) -> List[SimTaskSpec]:
    present = sparse_pattern(nb)
    specs = []
    for k in range(nb):
        specs.append(SimTaskSpec(dur=dur_lu0, deps=[(("M", k, k), INOUT)],
                                 label=f"lu0.{k}"))
        for j in range(k + 1, nb):
            if present[k][j]:
                specs.append(SimTaskSpec(
                    dur=dur_fwd,
                    deps=[(("M", k, k), IN), (("M", k, j), INOUT)],
                    label=f"fwd.{k}.{j}"))
        for i in range(k + 1, nb):
            if present[i][k]:
                specs.append(SimTaskSpec(
                    dur=dur_bdiv,
                    deps=[(("M", k, k), IN), (("M", i, k), INOUT)],
                    label=f"bdiv.{i}.{k}"))
        for i in range(k + 1, nb):
            if not present[i][k]:
                continue
            for j in range(k + 1, nb):
                if not present[k][j]:
                    continue
                present[i][j] = True  # fill-in
                specs.append(SimTaskSpec(
                    dur=dur_bmod,
                    deps=[(("M", i, k), IN), (("M", k, j), IN),
                          (("M", i, j), INOUT)],
                    label=f"bmod.{i}.{j}.{k}"))
    return specs


@jax.jit
def _lu0(d: jax.Array) -> jax.Array:
    """Unpivoted in-block LU (reference kernel of the BSC benchmark)."""
    n = d.shape[0]

    def body(k, m):
        col = m[:, k] / m[k, k]
        col = jnp.where(jnp.arange(n) > k, col, m[:, k])
        m = m.at[:, k].set(col)
        upd = jnp.outer(col, m[k, :])
        mask = (jnp.arange(n)[:, None] > k) & (jnp.arange(n)[None, :] > k)
        return m - jnp.where(mask, upd, 0.0)

    return jax.lax.fori_loop(0, n, body, d)


@jax.jit
def _fwd(diag: jax.Array, c: jax.Array) -> jax.Array:
    """Solve L x = c where L is the (unit-diag) lower part of `diag`."""
    l = jnp.tril(diag, -1) + jnp.eye(diag.shape[0], dtype=diag.dtype)
    return jax.scipy.linalg.solve_triangular(l, c, lower=True)


@jax.jit
def _bdiv(diag: jax.Array, r: jax.Array) -> jax.Array:
    """Solve x U = r where U is the upper part of `diag`."""
    u = jnp.triu(diag)
    return jax.scipy.linalg.solve_triangular(u.T, r.T, lower=True).T


@jax.jit
def _bmod(row: jax.Array, col: jax.Array, inner: jax.Array) -> jax.Array:
    return inner - row @ col


def run_sparselu(rt, m: np.ndarray, bs: int) -> np.ndarray:
    """Blocked sparse LU on the runtime; returns packed LU factors."""
    ms = m.shape[0]
    nb = ms // bs
    present = sparse_pattern(nb)
    blocks: Dict[Tuple[int, int], Optional[jax.Array]] = {}
    for i in range(nb):
        for j in range(nb):
            blocks[(i, j)] = (jnp.asarray(m[i * bs:(i + 1) * bs,
                                            j * bs:(j + 1) * bs])
                              if present[i][j] else None)

    def lu0(k):
        blocks[(k, k)] = _lu0(blocks[(k, k)])

    def fwd(k, j):
        blocks[(k, j)] = _fwd(blocks[(k, k)], blocks[(k, j)])

    def bdiv(i, k):
        blocks[(i, k)] = _bdiv(blocks[(k, k)], blocks[(i, k)])

    def bmod(i, j, k):
        inner = blocks[(i, j)]
        if inner is None:
            inner = jnp.zeros((bs, bs), dtype=jnp.float32)
        blocks[(i, j)] = _bmod(blocks[(i, k)], blocks[(k, j)], inner)

    for k in range(nb):
        rt.task(lu0, k, deps=[(("M", k, k), INOUT)], label=f"lu0.{k}")
        for j in range(k + 1, nb):
            if present[k][j]:
                rt.task(fwd, k, j,
                        deps=[(("M", k, k), IN), (("M", k, j), INOUT)],
                        label=f"fwd.{k}.{j}")
        for i in range(k + 1, nb):
            if present[i][k]:
                rt.task(bdiv, i, k,
                        deps=[(("M", k, k), IN), (("M", i, k), INOUT)],
                        label=f"bdiv.{i}.{k}")
        for i in range(k + 1, nb):
            if not present[i][k]:
                continue
            for j in range(k + 1, nb):
                if not present[k][j]:
                    continue
                present[i][j] = True
                rt.task(bmod, i, j, k,
                        deps=[(("M", i, k), IN), (("M", k, j), IN),
                              (("M", i, j), INOUT)],
                        label=f"bmod.{i}.{j}.{k}")
    rt.taskwait()
    out = np.zeros_like(m)
    for (i, j), blk in blocks.items():
        if blk is not None:
            out[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs] = np.asarray(blk)
    return out


def run_sparselu_epochs(rt, mats: List[np.ndarray],
                        bs: int) -> List[np.ndarray]:
    """Repeated sparse-LU factorizations: one epoch per input matrix,
    each submitting the identical task graph (the sparsity pattern —
    and with it the fill-in and the dependence structure — is fixed by
    ``sparse_pattern``, not by the values)."""
    return [run_sparselu(rt, m, bs) for m in mats]


def sparselu_oracle(m: np.ndarray, bs: int) -> np.ndarray:
    """Sequential reference of the same blocked algorithm (numpy)."""
    ms = m.shape[0]
    nb = ms // bs
    present = sparse_pattern(nb)
    blocks = {}
    for i in range(nb):
        for j in range(nb):
            blocks[(i, j)] = (m[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs]
                              .astype(np.float64).copy()
                              if present[i][j] else None)

    def lu0(d):
        d = d.copy()
        n = d.shape[0]
        for k in range(n):
            d[k + 1:, k] /= d[k, k]
            d[k + 1:, k + 1:] -= np.outer(d[k + 1:, k], d[k, k + 1:])
        return d

    for k in range(nb):
        blocks[(k, k)] = lu0(blocks[(k, k)])
        dk = blocks[(k, k)]
        l = np.tril(dk, -1) + np.eye(bs)
        u = np.triu(dk)
        for j in range(k + 1, nb):
            if present[k][j]:
                blocks[(k, j)] = np.linalg.solve(l, blocks[(k, j)])
        for i in range(k + 1, nb):
            if present[i][k]:
                blocks[(i, k)] = np.linalg.solve(u.T, blocks[(i, k)].T).T
        for i in range(k + 1, nb):
            if not present[i][k]:
                continue
            for j in range(k + 1, nb):
                if not present[k][j]:
                    continue
                present[i][j] = True
                inner = blocks[(i, j)]
                if inner is None:
                    inner = np.zeros((bs, bs))
                blocks[(i, j)] = inner - blocks[(i, k)] @ blocks[(k, j)]
    out = np.zeros_like(m)
    for (i, j), blk in blocks.items():
        if blk is not None:
            out[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs] = blk
    return out


# ===========================================================================
# N-Body (§4.2.2): blocked particles, NESTED tasks per timestep
# ===========================================================================

def sim_nbody_specs(nblocks: int, timesteps: int, dur_force: float = 150.0,
                    dur_update: float = 30.0, dur_parent: float = 5.0,
                    nested: bool = True) -> List[SimTaskSpec]:
    """Per timestep: pairwise force(i,j) tasks chained on F(i) (the
    paper's 'regular chained pattern similar to the Matmul one', §4.2.2 —
    nblocks² force tasks per step matches the paper's task counts), then
    update(i). With `nested`, each timestep is one top-level task whose
    body creates the children (the paper notes this nesting makes the
    Submit requests latency-critical because they block parallelism)."""
    specs: List[SimTaskSpec] = []
    for ts in range(timesteps):
        children = []
        for i in range(nblocks):
            for j in range(nblocks):
                children.append(SimTaskSpec(
                    dur=dur_force,
                    deps=[(("P", i), IN), (("P", j), IN), (("F", i), INOUT)],
                    label=f"force.{ts}.{i}.{j}"))
        for i in range(nblocks):
            children.append(SimTaskSpec(
                dur=dur_update,
                deps=[(("F", i), IN), (("P", i), INOUT)],
                label=f"update.{ts}.{i}"))
        if nested:
            specs.append(SimTaskSpec(dur=dur_parent, deps=[(("TS",), INOUT)],
                                     children=children,
                                     label=f"step.{ts}"))
        else:
            specs.extend(children)
    return specs


@jax.jit
def _forces_block(pi: jax.Array, pall: jax.Array, mall: jax.Array):
    """Gravity forces on block-i particles from all particles (softened)."""
    d = pall[None, :, :] - pi[:, None, :]
    r2 = jnp.sum(d * d, axis=-1) + 1e-6
    inv_r3 = jnp.where(r2 > 1e-5, r2 ** -1.5, 0.0)
    return jnp.sum(d * (mall[None, :] * inv_r3)[..., None], axis=1)


@jax.jit
def _update_block(p: jax.Array, v: jax.Array, f: jax.Array, dt: float):
    v = v + f * dt
    return p + v * dt, v


def run_nbody(rt, pos: np.ndarray, vel: np.ndarray, mass: np.ndarray,
              bs: int, timesteps: int, dt: float = 0.01):
    """Blocked n-body with nested tasks: one parent task per timestep."""
    n = pos.shape[0]
    nb = n // bs
    p = [jnp.asarray(pos[i * bs:(i + 1) * bs]) for i in range(nb)]
    v = [jnp.asarray(vel[i * bs:(i + 1) * bs]) for i in range(nb)]
    mall = jnp.asarray(mass)
    f: List[Optional[jax.Array]] = [None] * nb

    def force(i):
        pall = jnp.concatenate(p, axis=0)
        f[i] = _forces_block(p[i], pall, mall)

    def update(i):
        p[i], v[i] = _update_block(p[i], v[i], f[i], dt)

    def step(ts):
        for i in range(nb):
            rt.task(force, i,
                    deps=[(("P", j), IN) for j in range(nb)] + [(("F", i), OUT)],
                    label=f"force.{ts}.{i}")
        for i in range(nb):
            rt.task(update, i, deps=[(("F", i), IN), (("P", i), INOUT)],
                    label=f"update.{ts}.{i}")
        rt.taskwait()

    for ts in range(timesteps):
        rt.task(step, ts, deps=[(("TS",), INOUT)], label=f"step.{ts}")
    rt.taskwait()
    return (np.concatenate([np.asarray(x) for x in p]),
            np.concatenate([np.asarray(x) for x in v]))


def run_nbody_epochs(rt, pos: np.ndarray, vel: np.ndarray, mass: np.ndarray,
                     bs: int, timesteps: int, dt: float = 0.01):
    """Iterative n-body: ONE nested step task per epoch with a root
    taskwait after each (``run_nbody`` submits all steps up front; this
    variant is the steady-state timestep loop the paper describes and
    record-and-replay elides — every epoch is the same one-parent
    nested structure)."""
    n = pos.shape[0]
    nb = n // bs
    p = [jnp.asarray(pos[i * bs:(i + 1) * bs]) for i in range(nb)]
    v = [jnp.asarray(vel[i * bs:(i + 1) * bs]) for i in range(nb)]
    mall = jnp.asarray(mass)
    f: List[Optional[jax.Array]] = [None] * nb

    def force(i):
        pall = jnp.concatenate(p, axis=0)
        f[i] = _forces_block(p[i], pall, mall)

    def update(i):
        p[i], v[i] = _update_block(p[i], v[i], f[i], dt)

    def step(ts):
        for i in range(nb):
            rt.task(force, i,
                    deps=[(("P", j), IN) for j in range(nb)]
                    + [(("F", i), OUT)],
                    label=f"force.{ts}.{i}")
        for i in range(nb):
            rt.task(update, i, deps=[(("F", i), IN), (("P", i), INOUT)],
                    label=f"update.{ts}.{i}")
        rt.taskwait()

    for ts in range(timesteps):
        rt.task(step, ts, deps=[(("TS",), INOUT)], label=f"step.{ts}")
        rt.taskwait()
    return (np.concatenate([np.asarray(x) for x in p]),
            np.concatenate([np.asarray(x) for x in v]))


def nbody_oracle(pos: np.ndarray, vel: np.ndarray, mass: np.ndarray,
                 timesteps: int, dt: float = 0.01):
    p = pos.astype(np.float32).copy()
    v = vel.astype(np.float32).copy()
    for _ in range(timesteps):
        d = p[None, :, :] - p[:, None, :]
        r2 = np.sum(d * d, axis=-1) + 1e-6
        inv_r3 = np.where(r2 > 1e-5, r2 ** -1.5, 0.0)
        f = np.sum(d * (mass[None, :] * inv_r3)[..., None], axis=1)
        v = v + f * dt
        p = p + v * dt
    return p, v
