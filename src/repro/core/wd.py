"""Work Descriptor (WD) — task representation, mirroring Nanos++ (paper §2.2.1).

Each task is one WD carrying everything needed across its life cycle:
creation -> submission -> ready -> (blocked) -> finished -> completed -> deleted.

The paper replaces a third "delete" message with an extra task state
(§3.1): a WD whose Done Task Message has not yet been handled is in state
FINISHED; once a manager processes the message it moves to COMPLETED and
only then may be deleted (DELETED).
"""
from __future__ import annotations

import enum
import itertools
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence, Tuple

_wd_ids = itertools.count()


class DepMode(enum.Enum):
    IN = "in"
    OUT = "out"
    INOUT = "inout"

    @property
    def reads(self) -> bool:
        return self in (DepMode.IN, DepMode.INOUT)

    @property
    def writes(self) -> bool:
        return self in (DepMode.OUT, DepMode.INOUT)


class TaskState(enum.Enum):
    CREATED = 0      # WD allocated, args captured
    SUBMITTED = 1    # handed to the runtime, in (or queued for) the dep graph
    READY = 2        # all predecessors satisfied, in the ready pool
    RUNNING = 3      # executing on a worker
    BLOCKED = 4      # taskwait: waiting for children
    FINISHED = 5     # body done; Done Task Message not yet handled
    COMPLETED = 6    # Done message handled; graph updated; safe to delete
    DELETED = 7


@dataclass(eq=False)
class WorkDescriptor:
    """One task. `deps` is a sequence of (region, mode); regions are any
    hashable key (the block-id analogue of an OmpSs memory region)."""

    func: Optional[Callable[..., Any]]
    args: Tuple[Any, ...] = ()
    deps: Sequence[Tuple[Any, DepMode]] = ()
    label: str = "task"
    parent: Optional["WorkDescriptor"] = None
    duration: Optional[float] = None  # virtual duration for the simulator
    # Measured body execution time (seconds), stamped by the threaded
    # driver — feeds the replay scheduler's per-task cost EMA (the
    # simulator uses `duration` for the same purpose).
    exec_dur: Optional[float] = None
    # Multi-tenant job-scope id (core.scopes): None outside any scope;
    # inherited from the parent at creation so every descendant of a
    # scope root routes through that scope's policy slot and admission
    # ring without per-submit lookups.
    scope: Optional[int] = None
    # Fault tolerance (core.errors): how many times the runtime may
    # re-dispatch this task after a worker loss / timeout / body error
    # before poisoning it (0 = fail fast, today's semantics). Retries
    # are at-least-once: a body may have partially run before the
    # retry, so retryable bodies must be idempotent.
    retries: int = 0
    # Dispatch-to-done deadline in seconds, enforced by the process
    # backend's supervisor (the stuck worker is killed + respawned and
    # the task retried or poisoned). Advisory under threads: a Python
    # thread cannot be preempted mid-body.
    timeout: Optional[float] = None
    # Remaining retry budget (counts down from `retries`) and the
    # attempt history: one {"worker", "reason", "t"} dict per failed
    # attempt, surfaced in TaskFailed when the budget runs out.
    retries_left: int = 0
    attempts: list = field(default_factory=list)
    # Set when the owning scope expired before this task ran: the body
    # is skipped (drain-and-fail) and the scope's taskwait raises
    # ScopeExpired.
    cancelled: bool = False

    wd_id: int = field(default_factory=lambda: next(_wd_ids))
    state: TaskState = TaskState.CREATED
    # Dependence bookkeeping (owned by the manager / graph lock holder).
    num_predecessors: int = 0
    successors: list = field(default_factory=list)
    # Children bookkeeping for taskwait + lifetime (paper: parent WD holds
    # the graph of its children and may not be deleted while referenced).
    num_children_alive: int = 0
    children_done_event: Optional[threading.Event] = None
    result: Any = None
    # Sharded-mode bookkeeping (core.shards), set by the ShardRouter at
    # submit time; None in every other mode.
    #   shard_pending — submit latch + unsatisfied predecessor edges;
    #                   the unique decrement to 0 marks the task ready.
    #   shard_done    — per-shard Done portions outstanding; the unique
    #                   decrement to 0 completes the WD.
    #   shard_parts   — {shard_index: [(map_key, mode), ...]} dep
    #                   partition, hashed once so shards never re-hash.
    shard_pending: Any = None
    shard_done: Any = None
    shard_parts: Any = None
    # Guards num_children_alive: in dast/ddast/sharded modes sibling
    # completions are processed by concurrent managers, so the +1/-1
    # pair below must be atomic with respect to each other.
    _children_lock: threading.Lock = field(
        default_factory=threading.Lock, repr=False)

    def __post_init__(self) -> None:
        self.retries_left = self.retries
        if self.parent is not None:
            if self.scope is None:
                self.scope = self.parent.scope
            with self.parent._children_lock:
                self.parent.num_children_alive += 1

    # ---- life-cycle transitions -------------------------------------
    def mark_ready(self) -> None:
        self.state = TaskState.READY

    def mark_running(self) -> None:
        self.state = TaskState.RUNNING

    def mark_finished(self) -> None:
        self.state = TaskState.FINISHED

    def mark_completed(self) -> None:
        """Done Task Message fully handled (graph updated, successors
        notified). After this the WD may be reclaimed unless children
        still reference it."""
        self.state = TaskState.COMPLETED
        if self.parent is not None:
            self.parent._child_completed()

    def _child_completed(self) -> None:
        with self._children_lock:
            self.num_children_alive -= 1
            alive = self.num_children_alive
        if alive == 0 and self.children_done_event is not None:
            self.children_done_event.set()

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"WD({self.wd_id}:{self.label}:{self.state.name})"
