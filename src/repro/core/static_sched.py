"""Back-compat shim: the static DDAST scheduler moved into the unified
scheduling subsystem (:mod:`repro.core.sched`), where it shares its DAG
core (successor arrays, list-schedule event loop, bottom levels) with
the runtime's critical-path replay placement. Import from
``repro.core.sched`` in new code."""
from .sched.dag import DagNode
from .sched.static import ddast_schedule, overlap_collectives

__all__ = ["DagNode", "ddast_schedule", "overlap_collectives"]
