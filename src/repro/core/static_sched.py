"""DDAST as a *static* scheduler for device-side task DAGs.

On TPU, the compiled program cannot mutate a dependence graph at run time —
XLA fixes the schedule at compile time. The transferable part of the
paper's idea is the *order* the DDAST manager discovers tasks in: ready
tasks are released incrementally, keeping the working set ("in-graph"
tasks) minimal and interleaving producer completion with consumer release.

`ddast_schedule` replays the DDAST manager's release discipline in virtual
time over an arbitrary task DAG and returns a total order. The framework
uses it to:
  * order microbatch/collective nodes in the gradient-accumulation train
    step so the reduce-scatter of µbatch i overlaps compute of µbatch i+1
    (train/train_step.py);
  * order request admission in the serving engine's continuous batcher
    (serve/engine.py) — requests are tasks, prefill->decode are edges.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

from .ddast import DDASTParams


@dataclass
class DagNode:
    """A node in an abstract device task DAG."""
    name: Hashable
    cost: float = 1.0                      # relative cost (virtual µs)
    deps: Sequence[Hashable] = ()          # names of predecessor nodes
    kind: str = "compute"                  # compute | collective | io


def ddast_schedule(nodes: Sequence[DagNode], num_units: int = 2,
                   params: Optional[DDASTParams] = None) -> List[Hashable]:
    """Deterministic list schedule with the DDAST manager's release
    discipline: ready nodes are popped LIFO (chain/depth-first locality —
    the MAX_OPS_THREAD same-queue affinity) onto the earliest-free unit,
    and successor release happens at producer *finish* events, i.e. tasks
    are discovered incrementally like the manager draining Done messages,
    never all at once. Returns a valid topological order (asserted)."""
    params = params or DDASTParams()
    by_name = {n.name: n for n in nodes}
    indeg: Dict[Hashable, int] = {n.name: 0 for n in nodes}
    succs: Dict[Hashable, List[Hashable]] = {n.name: [] for n in nodes}
    for n in nodes:
        for p in n.deps:
            if p in by_name:
                indeg[n.name] += 1
                succs[p].append(n.name)

    ready: List[Hashable] = [nm for nm in (n.name for n in nodes)
                             if indeg[nm] == 0]
    unit_free = [0.0] * num_units
    pending = dict(indeg)
    order: List[Hashable] = []
    events: List[Tuple[float, int, Hashable]] = []
    seqc = 0
    tcur = 0.0
    while ready or events:
        while ready:
            u = min(range(num_units), key=lambda i: unit_free[i])
            nm = ready.pop()                     # LIFO: chain locality
            start = max(unit_free[u], tcur)
            end = start + max(by_name[nm].cost, 1e-3)
            unit_free[u] = end
            heapq.heappush(events, (end, seqc, nm))
            seqc += 1
            order.append(nm)
        if events:
            tcur, _, nm = heapq.heappop(events)
            for s in succs[nm]:
                pending[s] -= 1
                if pending[s] == 0:
                    ready.append(s)

    pos = {nm: i for i, nm in enumerate(order)}
    for n in nodes:
        for p in n.deps:
            if p in pos:
                assert pos[p] < pos[n.name], "ddast_schedule violated a dep"
    assert len(order) == len(nodes), "DAG has a cycle or unknown dep"
    return order


def overlap_collectives(nodes: Sequence[DagNode],
                        order: List[Hashable]) -> List[Hashable]:
    """Post-pass: hoist every collective node to the earliest position the
    DAG allows (right after its latest-scheduled predecessor), maximizing
    the slack XLA's latency-hiding scheduler can use to overlap it with
    compute. Dependence-safe: a node never moves before a predecessor."""
    deps = {n.name: set(n.deps) for n in nodes}
    kinds = {n.name: n.kind for n in nodes}
    out = list(order)
    for nm in [n.name for n in nodes if n.kind == "collective"]:
        i = out.index(nm)
        # earliest legal slot: after the last predecessor in `out`
        pred_pos = [out.index(p) for p in deps[nm] if p in out[:i]]
        lo = (max(pred_pos) + 1) if pred_pos else 0
        if lo < i:
            out.pop(i)
            out.insert(lo, nm)
    # sanity: still topological
    pos = {nm: i for i, nm in enumerate(out)}
    for n in nodes:
        for p in n.deps:
            if p in pos:
                assert pos[p] < pos[n.name]
    _ = kinds
    return out
