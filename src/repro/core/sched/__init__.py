"""Unified scheduling subsystem.

One DAG core (:mod:`~repro.core.sched.dag`: successor arrays, bottom
levels, the DDAST-discipline list-schedule event loop) shared by the two
scheduling layers that previously duplicated it:

  * **static** (:mod:`~repro.core.sched.static`) — ``ddast_schedule`` /
    ``overlap_collectives`` order device-side DAGs for the train and
    serve consumers (XLA fixes the schedule at compile time, so only the
    *order* transfers);
  * **dynamic** (:mod:`~repro.core.sched.placement`) — the
    ``PlacementPolicy`` family owning the per-worker two-lane
    ``StealDeque`` ready pools, including ``CriticalPathPlacement``,
    which schedules frozen replay graphs along their critical paths
    (bottom levels computed once at freeze time from the recorded
    successor arrays and per-task cost EMAs).

        record ──▶ freeze ──▶ prioritize ──▶ replay
        (live      (resolve    (bottom        (priority-lane push,
        analysis)   deps once)  levels/bands)  two-lane pops)
"""
from .dag import (DagNode, bottom_levels, build_arrays, list_schedule,
                  quantize_bands)
from .placement import (PLACEMENT_NAMES, CriticalPathPlacement,
                        PlacementPolicy, RoundRobinPlacement,
                        ShardAffinePlacement, make_placement)
from .static import ddast_schedule, overlap_collectives

__all__ = [
    "DagNode", "bottom_levels", "build_arrays", "list_schedule",
    "quantize_bands",
    "PLACEMENT_NAMES", "PlacementPolicy", "RoundRobinPlacement",
    "ShardAffinePlacement", "CriticalPathPlacement", "make_placement",
    "ddast_schedule", "overlap_collectives",
]
