"""Placement policies: who gets a newly-ready task, and in what lane.

The Distributed Breadth-First ready pool (paper §4, point 4) is one
lock-free :class:`~repro.core.shards.StealDeque` per worker slot: the
owner pops LIFO from the hot end, thieves steal FIFO from the cold end.
The :class:`PlacementPolicy` owns those deques and decides which deque a
ready task lands on; it is mode-agnostic — every
:class:`~repro.core.engine.policy.DependencePolicy` pushes through it and
both drivers (threads and simulator) pop through it.

Three implementations:

  * :class:`RoundRobinPlacement` — the historical default: spread ready
    tasks evenly; the unguarded cursor update is a benign race (any value
    it yields is a valid target index).
  * :class:`ShardAffinePlacement` — push a ready task onto the deque of
    the worker that last *executed* a task touching one of its regions
    (cache locality: the region's blocks are warm in that core's cache).
    Falls back to round-robin when no affinity is known yet, and skips
    affinity when the preferred deque is far above the ring-average load
    (a hot region must not pile the whole graph onto one slot). The
    affinity map is updated by the driver via :meth:`note_executed`.
  * :class:`CriticalPathPlacement` — the replay-aware scheduler: while a
    frozen :class:`~repro.core.engine.replay.ReplayGraph` is active, the
    :class:`~repro.core.engine.replay.ReplayPolicy` publishes per-task
    bottom levels (critical-path priorities computed ONCE at freeze time
    from the frozen successor arrays and the recorded per-task cost
    EMAs, :func:`~repro.core.sched.dag.bottom_levels`) through
    :meth:`set_replay_priorities`; ready tasks are then pushed into the
    priority lane of the two-lane deques so the longest remaining chain
    is always started first. Outside replay (live iterations, divergence
    suffixes, non-replay runtimes) it degrades to the inherited
    shard-affine/round-robin behavior. The priority lane is banded
    GIL-atomic deques (see :class:`~repro.core.shards.StealDeque`), so
    it reintroduces no lock, global or otherwise.

Placements charge their priority-lane traffic through ``self.charge`` —
a no-op for the threaded driver; the simulator's
:class:`~repro.core.engine.charge.SimCharger` prices each priority push
and each pop-side band scan in virtual time.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, Hashable, List, Optional, Sequence

from ..shards import AtomicCounter, StealDeque, stable_region_hash
from ..trace import EV_READY, EV_STEAL, NULL_TRACER
from ..wd import WorkDescriptor
from .dag import quantize_bands


class _NullCharger:
    """Stand-in until a DependencePolicy wires its real CostCharger in
    (placements must not import the engine package: the engine imports
    this module)."""

    __slots__ = ()

    def prio_push(self) -> None:
        pass

    def prio_pop(self) -> None:
        pass


_NO_CHARGE = _NullCharger()


class PlacementPolicy:
    """Owns the per-slot ready deques; subclasses choose the target."""

    #: True when the placement consumes replay-time priorities — the
    #: replay wrapper only computes bottom levels for placements that
    #: want them.
    wants_replay_priorities = False

    def __init__(self, num_slots: int) -> None:
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        self.deques: List[StealDeque] = [StealDeque()
                                         for _ in range(num_slots)]
        self.charge = _NO_CHARGE
        # wired by the DependencePolicy ctor, like `charge`; `ready`
        # events are stamped HERE because every ready path of every
        # policy funnels through a placement push, and only the
        # placement knows the target slot
        self.tracer = NULL_TRACER
        # per-scope steal tallies for the multi-tenant rollups
        # (dict.setdefault is GIL-atomic; AtomicCounter guards the +=)
        self.scope_steals: Dict[Hashable, AtomicCounter] = {}

    # -- protocol -------------------------------------------------------
    def push(self, wd: WorkDescriptor) -> None:
        raise NotImplementedError

    def push_replay(self, wd: WorkDescriptor, sid: int) -> None:
        """A replayed task became ready; ``sid`` is its structural id in
        the active :class:`~repro.core.engine.replay.ReplayGraph`.
        Default: ignore the id, place like any other task."""
        self.push(wd)

    def pop(self, slot: int) -> Optional[WorkDescriptor]:
        """Own deque first (priority bands, then the LIFO end), then
        steal around the ring (FIFO end, O(1) per attempt)."""
        wd = self.deques[slot].pop()
        if wd is not None:
            return wd
        n = len(self.deques)
        for off in range(1, n):
            victim = (slot + off) % n
            wd = self.deques[victim].steal()
            if wd is not None:
                self._note_steal(wd, slot, victim)
                return wd
        return None

    def _note_steal(self, wd: WorkDescriptor, slot: int,
                    victim: int) -> None:
        """A ready task left ``victim``'s deque for thief ``slot``."""
        if wd.scope is not None:
            self.scope_steals.setdefault(
                wd.scope, AtomicCounter(0)).add(1)
        if self.tracer.enabled:
            self.tracer.task_event(EV_STEAL, wd, slot, data=victim)

    def ready_count(self) -> int:
        return sum(len(d) for d in self.deques)

    def note_executed(self, wd: WorkDescriptor, slot: int) -> None:
        """Driver hook after a task body ran on ``slot``. Default: no
        bookkeeping."""

    # -- replay-priority hooks (no-ops outside CriticalPathPlacement) ---
    def set_replay_priorities(self, levels: Sequence[float],
                              scope: Optional[Hashable] = None) -> None:
        """Freeze-time hook: per-sid bottom levels of the active replay
        graph. ``scope`` (multi-tenant) publishes a per-scope band table
        instead of the exclusive single-tenant one."""

    def clear_replay_priorities(self,
                                scope: Optional[Hashable] = None) -> None:
        """The active recording was retired; drop priority state (for
        one tenant when ``scope`` is given)."""

    def stats(self) -> Dict[str, int]:
        return {
            "pushed": sum(d.pushed for d in self.deques),
            "popped": sum(d.popped for d in self.deques),
            "stolen": sum(d.stolen for d in self.deques),
        }


class RoundRobinPlacement(PlacementPolicy):
    """Spread ready tasks evenly across the slots (historical default)."""

    def __init__(self, num_slots: int) -> None:
        super().__init__(num_slots)
        self._rr = 0

    def push(self, wd: WorkDescriptor) -> None:
        slot = self._rr
        self.deques[slot].push(wd)
        self._rr = (slot + 1) % len(self.deques)
        if self.tracer.enabled:
            self.tracer.task_event(EV_READY, wd, slot)


class ShardAffinePlacement(RoundRobinPlacement):
    """Prefer the deque of the worker that last touched the task's
    regions; falls back to the inherited round-robin push when no
    affinity is recorded.

    With ``num_shards`` set (the drivers pass their shard count), the
    map is keyed by SHARD ID — ``stable_region_hash(region) %
    num_shards``, the same partition function the sharded graph uses —
    instead of the exact region. That hard-bounds the map at
    ``num_shards`` entries on region-churning workloads (a streaming app
    touches unbounded regions but a fixed set of shards) and matches the
    locality the sharded manager creates anyway: tasks whose regions
    share a shard already share manager/lock cache lines. Without
    ``num_shards`` (direct construction) the exact-region keying and the
    bounded LRU (``max_regions`` entries, default 4096) remain.

    Affinity additionally yields to load: when the preferred deque's
    normal lane is already more than twice the average of the other
    slots' lanes (and non-trivially long — see ``_LOAD_CAP_MIN``), the
    push falls back to round-robin.
    Without the cap a single hot region (e.g. the sparse-LU diagonal
    block) funnels every dependent task onto one slot while the other
    workers burn cycles stealing one task at a time from its cold end.

    Reads and writes of the affinity map take a small lock — eviction
    mutates the ordered map, so the GIL alone is not enough — which is
    acceptable because this placement is opt-in and the critical section
    is two dict operations."""

    #: below this target-deque length the load cap never triggers (a cap
    #: on near-empty deques would just add noise to the affinity win)
    _LOAD_CAP_MIN = 4

    def __init__(self, num_slots: int, max_regions: int = 4096,
                 num_shards: Optional[int] = None) -> None:
        super().__init__(num_slots)
        self._affinity: "OrderedDict[Hashable, int]" = OrderedDict()
        self._max_regions = max(1, max_regions)
        self._num_shards = num_shards
        self._aff_lock = threading.Lock()
        self.affine_pushes = 0
        self.fallback_pushes = 0
        self.load_cap_skips = 0

    def _key(self, region: Hashable) -> Hashable:
        if self._num_shards:
            return stable_region_hash(region) % self._num_shards
        return region

    def set_num_shards(self, num_shards: int) -> None:
        """Re-key after an online shard-count retune
        (``ShardedPolicy.resize``): old buckets are meaningless under
        the new modulus, so the hint map is cleared — affinity rebuilds
        from the next executions, which is the same cold start a resize
        imposes on the shards themselves."""
        with self._aff_lock:
            # exact-region keying (None) is a deliberate construction
            # choice — a resize must not convert it to shard keying
            if self._num_shards is not None \
                    and num_shards != self._num_shards:
                self._num_shards = num_shards
                self._affinity.clear()

    def preferred_slot(self, wd: WorkDescriptor) -> Optional[int]:
        n = len(self.deques)
        slot = None
        with self._aff_lock:
            for region, _mode in wd.deps:
                s = self._affinity.get(self._key(region))
                if s is not None and s < n:
                    slot = s
                    break
        if slot is None:
            return None
        # Load cap over the NORMAL lanes only (lane_len is O(1); banded
        # priority work is drained from any deque highest-first, so it
        # never pins to the owner): yield affinity when the target lane
        # is more than twice the average of the OTHER slots' lanes.
        qlen = self.deques[slot].lane_len
        if qlen >= self._LOAD_CAP_MIN and n > 1:
            rest = sum(d.lane_len for d in self.deques) - qlen
            if qlen * (n - 1) > 2 * rest:
                self.load_cap_skips += 1
                return None
        return slot

    def push(self, wd: WorkDescriptor) -> None:
        slot = self.preferred_slot(wd)
        if slot is None:
            self.fallback_pushes += 1
            super().push(wd)            # inherited round-robin spread
            return
        self.affine_pushes += 1
        self.deques[slot].push(wd)
        if self.tracer.enabled:
            # the "affine" payload marks locality-pinned placements for
            # the affinity-miss detector
            self.tracer.task_event(EV_READY, wd, slot, data="affine")

    def note_executed(self, wd: WorkDescriptor, slot: int) -> None:
        with self._aff_lock:
            for region, _mode in wd.deps:
                key = self._key(region)
                self._affinity[key] = slot
                self._affinity.move_to_end(key)
            while len(self._affinity) > self._max_regions:
                self._affinity.popitem(last=False)

    def stats(self) -> Dict[str, int]:
        st = super().stats()
        st["affine_pushes"] = self.affine_pushes
        st["fallback_pushes"] = self.fallback_pushes
        st["load_cap_skips"] = self.load_cap_skips
        return st


class CriticalPathPlacement(ShardAffinePlacement):
    """Critical-path-aware placement over frozen replay graphs.

    While the record-and-replay wrapper has an active frozen recording it
    publishes each task's bottom level (critical-path priority) here,
    quantized once into discrete bands; :meth:`push_replay` then pushes
    each newly-ready task into the priority lane of the chosen deque at
    its precomputed band, so every owner pop and every steal starts the
    longest remaining chain first. Everything else — live iterations,
    divergence suffixes, non-replay runtimes — flows through the
    inherited shard-affine/round-robin path unchanged.
    """

    wants_replay_priorities = True

    def __init__(self, num_slots: int, max_regions: int = 4096,
                 num_shards: Optional[int] = None,
                 max_bands: int = 32) -> None:
        super().__init__(num_slots, max_regions, num_shards)
        self.max_bands = max(1, max_bands)
        self._bands_of: Optional[List[int]] = None
        # band-indexed global occupancy counters shared by all deques
        # (GIL-atomic hint — see StealDeque): lets pop find the best
        # band across the WHOLE ring, making the longest-remaining-chain
        # guarantee global instead of per-deque
        self._band_counts: Optional[List[int]] = None
        # Multi-tenant: per-scope band tables, every value pre-scaled
        # into one FIXED universe of ``max_bands`` bands so all tenants
        # share the same band array and the same occupancy counters —
        # pop's global best-band choice then ranks every tenant's
        # critical work on one axis (longest-chain-first is global
        # again, not per-tenant). The fixed universe is configured once
        # (first scoped publication, priority lanes still empty) and
        # never reallocated: a tenant freezing or retiring while others
        # have banded work in flight must not orphan their entries.
        self._scope_bands: Dict[Hashable, List[int]] = {}
        # guards band-array (re)configuration: two tenants' first scoped
        # publications run on their own worker threads, and an unguarded
        # check-then-act could bind half the deques to one counts list
        # and half to another, desyncing occupancy from band contents
        self._universe_lock = threading.Lock()
        self.priority_pushes = 0
        self.global_band_steals = 0

    @property
    def replay_priorities_active(self) -> bool:
        return self._bands_of is not None or bool(self._scope_bands)

    def _ensure_scope_universe(self) -> bool:
        """Configure the fixed ``max_bands`` band array shared by all
        scoped tables (under ``_universe_lock``: concurrent first
        publications must not interleave the per-deque rebinding loop).
        Returns False when a single-tenant table already holds the
        deques at a different width — reconfiguring would orphan its
        in-flight banded tasks, so the scoped publication is declined
        and that tenant degrades to the normal lane."""
        with self._universe_lock:
            if self._band_counts is not None:
                return len(self._band_counts) == self.max_bands
            counts = [0] * self.max_bands
            for d in self.deques:
                d.set_num_bands(self.max_bands, counts)
            self._band_counts = counts
            return True

    def set_replay_priorities(self, levels: Sequence[float],
                              scope: Optional[Hashable] = None) -> None:
        """Publish per-sid bottom levels (called at freeze time and
        refreshed from the cost EMAs at replay iteration boundaries —
        root-quiescent for the publishing tenant, so its own banded
        entries are drained and the table swap races with nothing)."""
        if scope is not None:
            if not self._ensure_scope_universe():
                return
            bands, nbands = quantize_bands(levels, self.max_bands)
            scale = self.max_bands
            self._scope_bands[scope] = [b * scale // nbands
                                        for b in bands]
            return
        with self._universe_lock:
            if not self._scope_bands:
                # exclusive single-tenant publication: size the band
                # array to exactly what this table needs (reallocation
                # is safe — publication is root-quiescent, so the only
                # banded in-flight tasks were this tenant's, now drained)
                bands, nbands = quantize_bands(levels, self.max_bands)
                counts = [0] * nbands
                for d in self.deques:
                    d.set_num_bands(nbands, counts)
                self._band_counts = counts
                self._bands_of = bands
                return
        # Scoped tables are live (or a band array already exists):
        # reallocating would empty every band deque and orphan other
        # tenants' banded in-flight tasks — the same hazard
        # _ensure_scope_universe guards against in the opposite
        # direction. Publish the root table into the fixed max_bands
        # universe instead, exactly like a scoped publication.
        if not self._ensure_scope_universe():
            return
        bands, nbands = quantize_bands(levels, self.max_bands)
        scale = self.max_bands
        self._bands_of = [b * scale // nbands for b in bands]

    def clear_replay_priorities(self,
                                scope: Optional[Hashable] = None) -> None:
        if scope is not None:
            # drop only this tenant's table; the fixed band array stays
            # so other tenants' banded in-flight work keeps draining
            self._scope_bands.pop(scope, None)
            return
        self._bands_of = None
        with self._universe_lock:
            if not self._scope_bands and self._band_counts is not None:
                self._band_counts = None
                for d in self.deques:
                    d.set_num_bands(0)

    def _band_for(self, wd: WorkDescriptor, sid: int) -> int:
        """The band of a ready replayed task: its tenant's table when
        one is published, else the single-tenant table; -1 = no band."""
        if wd.scope is not None:
            bands = self._scope_bands.get(wd.scope)
            if bands is not None and 0 <= sid < len(bands):
                return bands[sid]
            return -1
        bands = self._bands_of
        if bands is not None and 0 <= sid < len(bands):
            return bands[sid]
        return -1

    def push_replay(self, wd: WorkDescriptor, sid: int) -> None:
        band = self._band_for(wd, sid)
        if band < 0:
            self.push(wd)
            return
        self.charge.prio_push()
        slot = self.preferred_slot(wd)
        if slot is None:
            self.fallback_pushes += 1
            slot = self._rr
            self._rr = (self._rr + 1) % len(self.deques)
        else:
            self.affine_pushes += 1
        self.priority_pushes += 1
        self.deques[slot].push_priority(wd, band)
        if self.tracer.enabled:
            # published-band payload: the priority-inversion detector
            # only speaks where bands exist
            self.tracer.task_event(EV_READY, wd, slot,
                                   data=("band", band))

    def pop(self, slot: int) -> Optional[WorkDescriptor]:
        # Global priority pop: when the shared band counters say a
        # better band exists somewhere in the ring than anything in the
        # own deque, steal from THAT band first — the
        # longest-remaining-chain guarantee becomes global, not
        # per-deque. The counters are a hint (see StealDeque): a stale
        # entry just falls through to the normal own-pop/steal path.
        counts = self._band_counts
        if counts is not None:
            gb = -1
            for b in range(len(counts) - 1, -1, -1):
                if counts[b] > 0:
                    gb = b
                    break
            if gb >= 0 and self.deques[slot].best_band() < gb:
                n = len(self.deques)
                for off in range(1, n):
                    victim = (slot + off) % n
                    wd = self.deques[victim].steal_band(gb)
                    if wd is not None:
                        self.global_band_steals += 1
                        self._note_steal(wd, slot, victim)
                        self.charge.prio_pop()
                        return wd
        wd = super().pop(slot)
        if wd is not None and self.replay_priorities_active:
            self.charge.prio_pop()      # the pop-side band scan
        return wd

    def stats(self) -> Dict[str, int]:
        st = super().stats()
        st["priority_pushes"] = self.priority_pushes
        st["global_band_steals"] = self.global_band_steals
        return st


_PLACEMENTS = {
    "round_robin": RoundRobinPlacement,
    "shard_affine": ShardAffinePlacement,
    "critical_path": CriticalPathPlacement,
}

PLACEMENT_NAMES = tuple(_PLACEMENTS)


def make_placement(kind, num_slots: int,
                   num_shards: Optional[int] = None) -> PlacementPolicy:
    """``kind`` is a name from ``_PLACEMENTS``, an already-built
    :class:`PlacementPolicy` (returned as-is), or a class to
    instantiate. ``num_shards`` (from the driver) switches
    shard-affine placements to bounded shard-id affinity keying."""
    if isinstance(kind, PlacementPolicy):
        if len(kind.deques) != num_slots:
            raise ValueError(
                f"placement instance has {len(kind.deques)} deques, "
                f"driver needs {num_slots}")
        return kind
    if isinstance(kind, type) and issubclass(kind, PlacementPolicy):
        cls = kind
    else:
        try:
            cls = _PLACEMENTS[kind]
        except KeyError:
            raise ValueError(
                f"placement must be one of {sorted(_PLACEMENTS)}, "
                f"got {kind!r}")
    if num_shards and issubclass(cls, ShardAffinePlacement):
        return cls(num_slots, num_shards=num_shards)
    return cls(num_slots)
