"""DDAST as a *static* scheduler for device-side task DAGs.

On TPU, the compiled program cannot mutate a dependence graph at run time —
XLA fixes the schedule at compile time. The transferable part of the
paper's idea is the *order* the DDAST manager discovers tasks in: ready
tasks are released incrementally, keeping the working set ("in-graph"
tasks) minimal and interleaving producer completion with consumer release.

`ddast_schedule` replays the DDAST manager's release discipline in virtual
time over an arbitrary task DAG and returns a total order. The framework
uses it to:
  * order microbatch/collective nodes in the gradient-accumulation train
    step so the reduce-scatter of µbatch i overlaps compute of µbatch i+1
    (train/train_step.py);
  * order request admission in the serving engine's continuous batcher
    (serve/engine.py) — requests are tasks, prefill->decode are edges.

The topology machinery (successor arrays, the list-schedule event loop,
bottom levels) lives in :mod:`repro.core.sched.dag`, shared with the
runtime's critical-path replay placement — this module only maps
names <-> int ids and keeps the historical API.
"""
from __future__ import annotations

from typing import Hashable, List, Optional, Sequence

from ..ddast import DDASTParams
from .dag import DagNode, build_arrays, list_schedule


def ddast_schedule(nodes: Sequence[DagNode], num_units: int = 2,
                   params: Optional[DDASTParams] = None) -> List[Hashable]:
    """Deterministic list schedule with the DDAST manager's release
    discipline (see :func:`~repro.core.sched.dag.list_schedule`).
    Returns a valid topological order (asserted)."""
    params = params or DDASTParams()
    del params                          # tunables reserved, as historically
    _, succs, npreds = build_arrays(nodes)
    ids = list_schedule([n.cost for n in nodes], succs, npreds, num_units)
    order = [nodes[i].name for i in ids]

    pos = {nm: i for i, nm in enumerate(order)}
    for n in nodes:
        for p in n.deps:
            if p in pos:
                assert pos[p] < pos[n.name], "ddast_schedule violated a dep"
    assert len(order) == len(nodes), "DAG has a cycle or unknown dep"
    return order


def overlap_collectives(nodes: Sequence[DagNode],
                        order: List[Hashable]) -> List[Hashable]:
    """Post-pass: hoist every collective node to the earliest position the
    DAG allows (right after its latest-scheduled predecessor), maximizing
    the slack XLA's latency-hiding scheduler can use to overlap it with
    compute. Dependence-safe: a node never moves before a predecessor.

    A position map is maintained across moves (only the slice a move
    shifts is re-indexed), replacing the historical ``out.index(...)``
    scans that made this pass O(n²) in the collective count × DAG size."""
    deps = {n.name: set(n.deps) for n in nodes}
    out = list(order)
    pos = {nm: i for i, nm in enumerate(out)}
    for nm in [n.name for n in nodes if n.kind == "collective"]:
        i = pos[nm]
        # earliest legal slot: after the last predecessor in `out`
        pred_pos = [pos[p] for p in deps[nm]
                    if pos.get(p, len(out)) < i]
        lo = (max(pred_pos) + 1) if pred_pos else 0
        if lo < i:
            out.pop(i)
            out.insert(lo, nm)
            for k in range(lo, i + 1):
                pos[out[k]] = k
    # sanity: still topological
    for n in nodes:
        for p in n.deps:
            if p in pos:
                assert pos[p] < pos[n.name]
    return out
