"""Shared DAG core of the scheduling subsystem.

Every scheduling layer in this runtime reasons about the same object — a
task DAG flattened to int-indexed successor arrays:

  * the *static* scheduler (``sched.static``) replays the DDAST manager's
    release discipline over a :class:`DagNode` list to order device-side
    work (train microbatches, serve admission);
  * the *dynamic* replay scheduler (``engine/replay.py`` +
    :class:`~repro.core.sched.placement.CriticalPathPlacement`) computes
    bottom levels over a frozen :class:`~repro.core.engine.replay.ReplayGraph`'s
    successor arrays to prioritize the longest remaining chain.

Before this module existed both layers duplicated the topology code
(name→index maps, successor lists, topological event loops); now the
successor-array construction, the bottom-level / critical-path
computation, and the list-schedule event loop exist exactly once.

All functions here operate on plain lists indexed by task id so they are
agnostic to where the DAG came from (``DagNode`` lists, frozen replay
graphs, anything with successor arrays).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Dict, Hashable, List, Optional, Sequence, Tuple


@dataclass
class DagNode:
    """A node in an abstract device task DAG."""
    name: Hashable
    cost: float = 1.0                      # relative cost (virtual µs)
    deps: Sequence[Hashable] = ()          # names of predecessor nodes
    kind: str = "compute"                  # compute | collective | io


def build_arrays(nodes: Sequence[DagNode]
                 ) -> Tuple[Dict[Hashable, int], List[List[int]], List[int]]:
    """Flatten a ``DagNode`` list to (name→index map, successor arrays,
    predecessor counts). Dependences on names outside ``nodes`` are
    ignored, matching the historical ``ddast_schedule`` behavior."""
    idx = {n.name: i for i, n in enumerate(nodes)}
    succs: List[List[int]] = [[] for _ in nodes]
    npreds = [0] * len(nodes)
    for i, n in enumerate(nodes):
        for p in n.deps:
            j = idx.get(p)
            if j is not None:
                succs[j].append(i)
                npreds[i] += 1
    return idx, succs, npreds


def bottom_levels(succs: Sequence[Sequence[int]],
                  costs: Optional[Sequence[float]] = None) -> List[float]:
    """Per-task bottom level: the task's cost plus the longest-cost path
    to any sink through ``succs`` — the classic critical-path priority
    (a task's bottom level is the minimum remaining makespan once it
    starts). Computed in one reverse-topological pass over the flat
    successor arrays; raises ``ValueError`` on a cycle.

    ``costs`` defaults to 1.0 per task (bottom level = longest remaining
    chain length), the fallback the replay scheduler uses before any
    execution times have been recorded."""
    n = len(succs)
    bl = ([max(float(c), 1e-9) for c in costs] if costs is not None
          else [1.0] * n)
    preds_of: List[List[int]] = [[] for _ in range(n)]
    outdeg = [0] * n
    for i, ss in enumerate(succs):
        outdeg[i] = len(ss)
        for s in ss:
            preds_of[s].append(i)
    stack = [i for i in range(n) if outdeg[i] == 0]
    seen = 0
    while stack:
        v = stack.pop()
        seen += 1
        for p in preds_of[v]:
            base = (max(float(costs[p]), 1e-9) if costs is not None
                    else 1.0)
            if base + bl[v] > bl[p]:
                bl[p] = base + bl[v]
            outdeg[p] -= 1
            if outdeg[p] == 0:
                stack.append(p)
    if seen != n:
        raise ValueError("bottom_levels: successor arrays contain a cycle")
    return bl


def quantize_bands(levels: Sequence[float],
                   max_bands: int) -> Tuple[List[int], int]:
    """Map bottom levels to discrete priority bands (0 = lowest). Bands
    are what make the two-lane ready deques lock-free: a band is one
    GIL-atomic ``deque``, so pushes never need a heap or a lock. With at
    most ``max_bands`` distinct levels the mapping is exact (the longest
    remaining chain is *always* started first); beyond that, levels are
    rank-quantized so adjacent priorities may share a band."""
    distinct = sorted(set(levels))
    nd = len(distinct)
    if nd == 0:
        return [], 0
    if nd <= max_bands:
        rank = {v: i for i, v in enumerate(distinct)}
        return [rank[v] for v in levels], nd
    rank = {v: (i * max_bands) // nd for i, v in enumerate(distinct)}
    return [rank[v] for v in levels], max_bands


def list_schedule(costs: Sequence[float], succs: Sequence[Sequence[int]],
                  npreds: Sequence[int], num_units: int) -> List[int]:
    """Deterministic list schedule with the DDAST manager's release
    discipline, over int task ids: ready tasks are popped LIFO
    (chain/depth-first locality — the MAX_OPS_THREAD same-queue
    affinity) onto the earliest-free unit, and successor release happens
    at producer *finish* events, i.e. tasks are discovered incrementally
    like the manager draining Done messages, never all at once. Returns
    the start order (a valid topological order of the reachable DAG)."""
    n = len(costs)
    ready: List[int] = [i for i in range(n) if npreds[i] == 0]
    unit_free = [0.0] * num_units
    pending = list(npreds)
    order: List[int] = []
    events: List[Tuple[float, int, int]] = []
    seqc = 0
    tcur = 0.0
    while ready or events:
        while ready:
            u = min(range(num_units), key=lambda i: unit_free[i])
            nm = ready.pop()                     # LIFO: chain locality
            start = max(unit_free[u], tcur)
            end = start + max(costs[nm], 1e-3)
            unit_free[u] = end
            heapq.heappush(events, (end, seqc, nm))
            seqc += 1
            order.append(nm)
        if events:
            tcur, _, nm = heapq.heappop(events)
            for s in succs[nm]:
                pending[s] -= 1
                if pending[s] == 0:
                    ready.append(s)
    return order
