"""Core of the reproduction: the paper's asynchronous runtime organization
with a distributed manager (DDAST), plus its simulator and the static
scheduling adaptation for device DAGs."""
from .autotune import DynamicTuner, TunerConfig
from .ddast import DDASTManager, DDASTParams
from .depgraph import DependenceGraph
from .dispatcher import FunctionalityDispatcher
from .messages import DoneTaskMessage, SubmitTaskMessage
from .queues import SPSCQueue, WorkerQueues
from .runtime import RuntimeStats, TaskRuntime
from .simulator import RuntimeSimulator, SimCosts, SimResult, SimTaskSpec
from .static_sched import DagNode, ddast_schedule, overlap_collectives
from .wd import DepMode, TaskState, WorkDescriptor

__all__ = [
    "DynamicTuner", "TunerConfig",
    "DDASTManager", "DDASTParams", "DependenceGraph",
    "FunctionalityDispatcher", "DoneTaskMessage", "SubmitTaskMessage",
    "SPSCQueue", "WorkerQueues", "RuntimeStats", "TaskRuntime",
    "RuntimeSimulator", "SimCosts", "SimResult", "SimTaskSpec",
    "DagNode", "ddast_schedule", "overlap_collectives",
    "DepMode", "TaskState", "WorkDescriptor",
]
