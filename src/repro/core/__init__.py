"""Core of the reproduction: the paper's asynchronous runtime organization
with a distributed manager (DDAST), the sharded dependence-manager
extension (region-hash-partitioned graphs, per-shard mailboxes,
lock-free ready deques), plus its simulator and the static scheduling
adaptation for device DAGs."""
from .autotune import DynamicTuner, TunerConfig
from .ddast import DDASTManager, DDASTParams
from .depgraph import DependenceGraph
from .dispatcher import FunctionalityDispatcher
from .messages import DoneTaskMessage, SubmitTaskMessage
from .queues import InstrumentedLock, SPSCQueue, WorkerQueues
from .runtime import RuntimeStats, TaskRuntime
from .shards import (AtomicCounter, GraphShard, ShardMailbox, ShardRouter,
                     ShardedDependenceGraph, StealDeque, stable_region_hash)
from .simulator import RuntimeSimulator, SimCosts, SimResult, SimTaskSpec
from .static_sched import DagNode, ddast_schedule, overlap_collectives
from .wd import DepMode, TaskState, WorkDescriptor

__all__ = [
    "DynamicTuner", "TunerConfig",
    "DDASTManager", "DDASTParams", "DependenceGraph",
    "FunctionalityDispatcher", "DoneTaskMessage", "SubmitTaskMessage",
    "InstrumentedLock", "SPSCQueue", "WorkerQueues",
    "RuntimeStats", "TaskRuntime",
    "AtomicCounter", "GraphShard", "ShardMailbox", "ShardRouter",
    "ShardedDependenceGraph", "StealDeque", "stable_region_hash",
    "RuntimeSimulator", "SimCosts", "SimResult", "SimTaskSpec",
    "DagNode", "ddast_schedule", "overlap_collectives",
    "DepMode", "TaskState", "WorkDescriptor",
]
