"""Core of the reproduction: the paper's asynchronous runtime organization
with a distributed manager (DDAST), unified behind the mode-agnostic
dependence-policy engine (``core.engine``: one ``DependencePolicy`` per
organization, shared verbatim by the threaded ``TaskRuntime`` and the
virtual-time ``RuntimeSimulator``), the sharded dependence-manager
extension (region-hash-partitioned graphs, per-shard mailboxes with
batched Submits, lock-free ready deques), plus the static scheduling
adaptation for device DAGs."""
from .autotune import DynamicTuner, TunerConfig
from .ddast import DDASTManager, DDASTParams
from .depgraph import DependenceGraph
from .dispatcher import FunctionalityDispatcher
from .engine import (CostCharger, CriticalPathPlacement, DastPolicy,
                     DdastPolicy, DependencePolicy, PlacementPolicy,
                     ReplayGraph, ReplayPolicy, RoundRobinPlacement,
                     ShardAffinePlacement, ShardedPolicy, SimCharger,
                     SyncPolicy, make_placement, make_policy)
from .sched import bottom_levels, list_schedule, quantize_bands
from .messages import (DoneBatchMessage, DoneTaskMessage,
                       SubmitBatchMessage, SubmitTaskMessage)
from .errors import RingCorruption, ScopeExpired, TaskFailed, WorkerLost
from .procs import FaultPlan, ProcessRuntime, ShmRing
from .queues import InstrumentedLock, SPSCQueue, WorkerQueues
from .runtime import RuntimeStats, TaskRuntime
from .scopes import (FairAdmission, JobScope, ScopedPolicy, ScopedRegion,
                     scoped_deps)
from .shards import (AtomicCounter, GraphShard, ShardMailbox, ShardRouter,
                     ShardedDependenceGraph, StealDeque, stable_region_hash)
from .simulator import RuntimeSimulator, SimCosts, SimResult, SimTaskSpec
from .static_sched import DagNode, ddast_schedule, overlap_collectives
from .trace import (Finding, TraceEvent, TraceRecorder, detect_all,
                    load_trace, save_trace)
from .wd import DepMode, TaskState, WorkDescriptor

__all__ = [
    "DynamicTuner", "TunerConfig",
    "DDASTManager", "DDASTParams", "DependenceGraph",
    "FunctionalityDispatcher",
    "CostCharger", "SimCharger",
    "DependencePolicy", "SyncPolicy", "DastPolicy", "DdastPolicy",
    "ShardedPolicy", "ReplayPolicy", "ReplayGraph", "make_policy",
    "PlacementPolicy", "RoundRobinPlacement", "ShardAffinePlacement",
    "CriticalPathPlacement", "make_placement",
    "bottom_levels", "list_schedule", "quantize_bands",
    "DoneBatchMessage", "DoneTaskMessage", "SubmitBatchMessage",
    "SubmitTaskMessage",
    "InstrumentedLock", "SPSCQueue", "WorkerQueues",
    "ProcessRuntime", "ShmRing", "TaskFailed", "WorkerLost",
    "FaultPlan", "RingCorruption", "ScopeExpired",
    "RuntimeStats", "TaskRuntime",
    "FairAdmission", "JobScope", "ScopedPolicy", "ScopedRegion",
    "scoped_deps",
    "AtomicCounter", "GraphShard", "ShardMailbox", "ShardRouter",
    "ShardedDependenceGraph", "StealDeque", "stable_region_hash",
    "RuntimeSimulator", "SimCosts", "SimResult", "SimTaskSpec",
    "DagNode", "ddast_schedule", "overlap_collectives",
    "Finding", "TraceEvent", "TraceRecorder", "detect_all",
    "load_trace", "save_trace",
    "DepMode", "TaskState", "WorkDescriptor",
]
