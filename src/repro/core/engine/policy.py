"""The mode-agnostic dependence-policy engine.

The paper's §6 comparison set (plus the sharded extension) differs only
in *how* dependence-graph actions get applied — directly under a lock,
or requested asynchronously and drained by managers. That "how" is a
policy over one set of runtime structures, captured here as the
:class:`DependencePolicy` protocol:

    submit(wd, slot)        a worker created a task
    complete(wd, slot)      a worker finished a task's body
    idle_callback(slot)     an idle worker offers cycles (Listing 2)
    drain_all()             drain every queue to empty (taskwait edges)
    flush(slot)             make the slot's buffered submits visible
    pending() / in_graph()  backlog and occupancy probes
    stats()                 the counters the paper plots

Four concrete policies:

  * :class:`SyncPolicy`    — Nanos++ baseline: mutate directly under ONE
    global graph lock at submit & finish.
  * :class:`DastPolicy`    — the authors' earlier centralized design [7]:
    one dedicated manager thread drains all queues.
  * :class:`DdastPolicy`   — this paper: no dedicated resources; idle
    workers become managers (Listing 2 with the four Table-5 tunables).
  * :class:`ShardedPolicy` — beyond the paper: region-hash-partitioned
    graph shards with per-shard mailboxes; idle workers claim whole
    shards; optional Submit batching (one mailbox entry per task batch).

Policies are driver-agnostic: ``TaskRuntime`` runs them on real threads
with a no-op :class:`~repro.core.engine.charge.CostCharger`;
``RuntimeSimulator`` runs the *same objects* single-threaded under a
:class:`~repro.core.engine.charge.SimCharger` that prices every protocol
step in virtual time. The dependence protocol therefore exists exactly
once.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Set

from ..ddast import DDASTParams
from ..depgraph import DependenceGraph
from ..messages import DoneTaskMessage, SubmitTaskMessage
from ..queues import InstrumentedLock, WorkerQueues
from ..shards import ShardRouter, ShardedDependenceGraph
from ..trace import EV_DEPS, EV_MSG_DRAIN, EV_MSG_ENQ, NULL_TRACER
from ..wd import WorkDescriptor
from .charge import CostCharger
from .placement import PlacementPolicy, RoundRobinPlacement


class DependencePolicy:
    """Protocol base. Also serves as the compat surface the runtime used
    to expose as ``rt.ddast`` (callback / messages_processed /
    callback_entries / drain_all work on every policy)."""

    name = "abstract"
    #: one dedicated manager thread drains continuously (dast)
    needs_manager_thread = False
    #: idle workers should run ``idle_callback`` (ddast / sharded)
    uses_idle_managers = False
    #: driver hint: how long an idle thread sleeps between polls
    idle_sleep_s = 0.0

    def __init__(self, num_slots: int, num_workers: Optional[int] = None,
                 params: Optional[DDASTParams] = None,
                 placement: Optional[PlacementPolicy] = None,
                 charge: Optional[CostCharger] = None,
                 manager_eligible: Optional[Set[int]] = None,
                 main_slot: Optional[int] = None,
                 tracer=None) -> None:
        self.num_slots = num_slots
        self.num_workers = num_workers if num_workers is not None \
            else num_slots
        self.params = params or DDASTParams()
        self.placement = placement or RoundRobinPlacement(num_slots)
        self.charge = charge or CostCharger()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # placements charge their priority-lane traffic through the same
        # adapter the policy uses (no-op on threads, priced in the sim)
        # — and stamp their ready/steal events through the same tracer
        self.placement.charge = self.charge
        self.placement.tracer = self.tracer
        # big.LITTLE support (paper §8): restrict which workers may become
        # manager threads (None = any). The main slot is always eligible
        # so taskwait drains.
        self.manager_eligible = manager_eligible
        self.main_slot = main_slot if main_slot is not None \
            else num_slots - 1
        self.messages_processed = 0
        self.callback_entries = 0

    # -- protocol -------------------------------------------------------
    def submit(self, wd: WorkDescriptor, slot: int) -> None:
        raise NotImplementedError

    def complete(self, wd: WorkDescriptor, slot: int) -> None:
        raise NotImplementedError

    def idle_callback(self, worker_id: int) -> int:
        """An idle worker offers itself; returns messages processed."""
        return 0

    def callback(self, worker_id: int) -> int:
        """Dispatcher-facing name (historically DDASTManager.callback) —
        delegates so subclasses only ever override ``idle_callback``."""
        return self.idle_callback(worker_id)

    def drain_all(self) -> int:
        return 0

    def flush(self, slot: int) -> None:
        """Make the slot's buffered submits visible (batching policies)."""

    def notify_quiescent(self, root: bool = True,
                         scope_id: Optional[int] = None) -> None:
        """A taskwait on this policy reached quiescence; ``root`` marks
        the driver's top-level (root-task) taskwait — the boundary the
        record-and-replay wrapper freezes and validates recordings at.
        ``scope_id`` names the job scope whose root quiesced (None = the
        driver's own root context) — only the scope multiplexer
        (``core.scopes.ScopedPolicy``) routes on it; plain policies have
        no iteration state: no-op."""

    def pending(self) -> int:
        return 0

    def in_graph(self) -> int:
        raise NotImplementedError

    def stats(self) -> Dict[str, object]:
        raise NotImplementedError


def _blank_stats() -> Dict[str, object]:
    return {
        "messages_processed": 0,
        "lock_acquisitions": 0,
        "lock_wait_s": 0.0,
        "max_in_graph": 0,
        "total_edges": 0,
        "shard_messages": [],
        "shard_lock_wait_s": [],
        # delegation/combining (zero/empty outside the sharded policy)
        "delegated_portions": 0,
        "combined_drains": 0,
        "shard_lock_handoffs": [],
        "scope_portions": {},
    }


def _merge_shard_lists(carried, current):
    """Element-wise sum of two per-shard counter lists whose lengths may
    differ across a ``resize`` (shard i's meaning changes with the
    partition, but the element-wise sum keeps totals exact and per-slot
    attribution as close as the resize allows)."""
    if not carried:
        return list(current)
    n = max(len(carried), len(current))
    return [(carried[i] if i < len(carried) else 0)
            + (current[i] if i < len(current) else 0) for i in range(n)]


class _GlobalGraphMixin:
    """Per-parent ``DependenceGraph``s behind one global lock — shared by
    the three non-sharded policies."""

    def _init_graphs(self) -> None:
        self.graph_lock = InstrumentedLock()
        self._graphs: Dict[int, DependenceGraph] = {}

    def _graph_for(self, parent: WorkDescriptor) -> DependenceGraph:
        g = self._graphs.get(parent.wd_id)
        if g is None:
            g = self._graphs[parent.wd_id] = DependenceGraph()
        return g

    def _apply_submit(self, wd: WorkDescriptor) -> None:
        self.charge.submit_cs("graph", len(wd.deps))
        with self.graph_lock:
            ready = self._graph_for(wd.parent).submit(wd)
        if self.tracer.enabled:
            self.tracer.task_event(EV_DEPS, wd, -1)
        if ready:
            self.placement.push(wd)

    def _apply_done(self, wd: WorkDescriptor) -> None:
        self.charge.done_cs("graph", len(wd.deps))
        with self.graph_lock:
            newly = self._graph_for(wd.parent).complete(wd)
        for s in newly:
            self.placement.push(s)

    def in_graph(self) -> int:
        # list() snapshots atomically under the GIL; iterating the live
        # dict would race _graph_for's insert of a new parent's graph.
        return sum(g.in_graph for g in list(self._graphs.values()))

    def _graph_stats(self) -> Dict[str, object]:
        st = _blank_stats()
        st["lock_acquisitions"] = self.graph_lock.acquisitions
        st["lock_wait_s"] = self.graph_lock.wait_s
        for g in list(self._graphs.values()):
            st["max_in_graph"] = max(st["max_in_graph"], g.max_in_graph)
            st["total_edges"] += g.total_edges
        return st


class SyncPolicy(_GlobalGraphMixin, DependencePolicy):
    """Nanos++ baseline: every worker mutates the dependence graph
    directly under the global graph lock at submit & finish."""

    name = "sync"

    def __init__(self, *args, **kw) -> None:
        super().__init__(*args, **kw)
        self._init_graphs()

    def submit(self, wd: WorkDescriptor, slot: int) -> None:
        self._apply_submit(wd)

    def complete(self, wd: WorkDescriptor, slot: int) -> None:
        self._apply_done(wd)

    def stats(self) -> Dict[str, object]:
        return self._graph_stats()


class _ManagedPolicy(DependencePolicy):
    """Shared Listing-2 manager machinery: the spin / MIN_READY_TASKS /
    MAX_OPS_THREAD drain loop and the MAX_DDAST_THREADS admission gate.
    Subclasses provide ``_drain_once`` (one pass over their queues or
    shards) and ``drain_all``."""

    def __init__(self, *args, **kw) -> None:
        super().__init__(*args, **kw)
        self._active = 0
        self._active_lock = threading.Lock()

    def _drain_once(self, worker_id: int) -> int:
        raise NotImplementedError

    def idle_callback(self, worker_id: int) -> int:
        p = self.params
        eligible = self.manager_eligible
        if eligible is not None and worker_id != self.main_slot \
                and worker_id not in eligible:
            return 0                    # big.LITTLE: not a manager core
        max_threads = p.resolved_max_threads(self.num_workers)
        with self._active_lock:
            if self._active >= max_threads:
                return 0
            self._active += 1
        self.callback_entries += 1
        total = 0
        try:
            spins = p.max_spins
            while True:
                cnt = self._drain_once(worker_id)
                self.messages_processed += cnt
                total += cnt
                spins = (spins - 1) if cnt == 0 else p.max_spins
                if spins == 0 or \
                        self.placement.ready_count() >= p.min_ready_tasks:
                    break
        finally:
            with self._active_lock:
                self._active -= 1
        return total


class DdastPolicy(_GlobalGraphMixin, _ManagedPolicy):
    """This paper's organization: Submit/Done requests go to per-worker
    message queues; idle workers entering the callback become managers
    and drain them (Listing 2), updating the graph under the global
    lock with per-worker Submit-queue exclusivity (§3.1)."""

    name = "ddast"
    uses_idle_managers = True

    def __init__(self, *args, **kw) -> None:
        super().__init__(*args, **kw)
        self._init_graphs()
        self.worker_queues: List[WorkerQueues] = [
            WorkerQueues(i) for i in range(self.num_slots)]
        # cumulative per-scope drained-message tally (combiner-free
        # analogue of the sharded router's scope_portions); int += under
        # the GIL, informational — folded into scope_rollup
        self.scope_drained: Dict[object, int] = {}
        # rotating first-served queue for _drain_once: a pass that stops
        # early (MIN_READY satisfied) must not always have served queue 0
        # first, or the tenant producing there owns readiness production
        # (unguarded += is a benign race — any start index is valid)
        self._drain_rr = 0

    # -- producer side --------------------------------------------------
    def submit(self, wd: WorkDescriptor, slot: int) -> None:
        self.charge.push()
        self.worker_queues[slot].submit.push(SubmitTaskMessage(wd))
        if self.tracer.enabled:
            self.tracer.task_event(EV_MSG_ENQ, wd, slot,
                                   data=("submit", slot, 1))

    def complete(self, wd: WorkDescriptor, slot: int) -> None:
        self.charge.push()
        self.worker_queues[slot].done.push(DoneTaskMessage(wd))
        if self.tracer.enabled:
            self.tracer.task_event(EV_MSG_ENQ, wd, slot,
                                   data=("done", slot, 1))

    # -- manager side ---------------------------------------------------
    def _drain_once(self, worker_id: int) -> int:
        """One pass over the per-worker queues (Listing 2 lines 6-15),
        with per-scope round-robin quanta: each scope gets at most
        ``params.drain_quantum`` messages analyzed per pass, so one
        tenant's submission flood cannot monopolize dependence analysis —
        its queue stops being drained for the rest of the pass while the
        other tenants' queues still get their turn. Per-queue FIFO is
        preserved: an over-quantum head is left *queued* (peeked, not
        popped), never skipped over. The pass starts at the queue where
        the previous pass stopped: MIN_READY stops most passes after one
        queue, so a fixed (or naively rotating) start lets the producer
        of a favored queue own readiness production — the continuation
        cursor makes first service a true round-robin over queues."""
        del worker_id
        p = self.params
        quantum = p.drain_quantum
        consumed: Dict[object, int] = {}
        total_cnt = 0
        qs = self.worker_queues
        nq = len(qs)
        start = self._drain_rr % nq
        self._drain_rr = start + 1      # full pass: rotate one anyway
        for k in range(nq):
            wq = qs[(start + k) % nq]
            if self.placement.ready_count() >= p.min_ready_tasks:
                # resume HERE next pass — this queue was not served
                self._drain_rr = start + k
                break
            cnt = 0
            if wq.acquire_submit():
                try:
                    while cnt < p.max_ops_thread:
                        nxt = wq.submit.peek()
                        if nxt is None:
                            break
                        if quantum and consumed.get(nxt.wd.scope,
                                                    0) >= quantum:
                            break       # scope exhausted its quantum:
                        #                 rotate to the next queue
                        msg = wq.submit.pop()
                        if msg is None:
                            break
                        sc = msg.wd.scope
                        consumed[sc] = consumed.get(sc, 0) + 1
                        self.scope_drained[sc] = \
                            self.scope_drained.get(sc, 0) + 1
                        self.charge.message()
                        if self.tracer.enabled:
                            self.tracer.task_event(
                                EV_MSG_DRAIN, msg.wd, -1,
                                data=("submit", wq.worker_id, 1))
                        self._apply_submit(msg.wd)
                        cnt += 1
                finally:
                    wq.release_submit()
            while cnt < p.max_ops_thread:
                # Done pops race across managers, so the peeked head may
                # not be the popped message — quantum accounting uses the
                # actual popped scope; the peek only decides rotation.
                nxt = wq.done.peek()
                if nxt is None:
                    break
                if quantum and consumed.get(nxt.wd.scope, 0) >= quantum:
                    break
                msg = wq.done.pop()
                if msg is None:
                    break
                sc = msg.wd.scope
                consumed[sc] = consumed.get(sc, 0) + 1
                self.scope_drained[sc] = self.scope_drained.get(sc, 0) + 1
                self.charge.message()
                if self.tracer.enabled:
                    self.tracer.task_event(EV_MSG_DRAIN, msg.wd, -1,
                                           data=("done", wq.worker_id, 1))
                self._apply_done(msg.wd)
                cnt += 1
            total_cnt += cnt
        return total_cnt

    def drain_all(self) -> int:
        """Drain every queue to empty (dast loop, taskwait/shutdown)."""
        n = 0
        progress = True
        while progress:
            progress = False
            for wq in self.worker_queues:
                if wq.acquire_submit():
                    try:
                        while True:
                            msg = wq.submit.pop()
                            if msg is None:
                                break
                            self.charge.message()
                            if self.tracer.enabled:
                                self.tracer.task_event(
                                    EV_MSG_DRAIN, msg.wd, -1,
                                    data=("submit", wq.worker_id, 1))
                            self._apply_submit(msg.wd)
                            n += 1
                            progress = True
                    finally:
                        wq.release_submit()
                while True:
                    msg = wq.done.pop()
                    if msg is None:
                        break
                    self.charge.message()
                    if self.tracer.enabled:
                        self.tracer.task_event(
                            EV_MSG_DRAIN, msg.wd, -1,
                            data=("done", wq.worker_id, 1))
                    self._apply_done(msg.wd)
                    n += 1
                    progress = True
        self.messages_processed += n
        return n

    def pending(self) -> int:
        return sum(wq.pending() for wq in self.worker_queues)

    def stats(self) -> Dict[str, object]:
        st = self._graph_stats()
        st["messages_processed"] = self.messages_processed
        return st

    def scope_drain_share(self, scope_id) -> int:
        """Cumulative messages drained on this tenant's behalf (see
        ``scope_drained``); surfaced through ``scope_rollup``."""
        return self.scope_drained.get(scope_id, 0)


class DastPolicy(DdastPolicy):
    """The authors' earlier centralized design [7]: same queues, but ONE
    dedicated manager thread (spawned by the driver) drains them; workers
    never manage."""

    name = "dast"
    needs_manager_thread = True
    uses_idle_managers = False
    idle_sleep_s = 1e-5


class ShardedPolicy(_ManagedPolicy):
    """Region-hash-partitioned manager (see ``core.shards``): per-shard
    graphs + mailboxes, idle workers claim whole shards. With
    ``batch_size`` set, a slot's Submits are buffered and shipped as
    :class:`~repro.core.messages.SubmitBatchMessage`s — one mailbox entry
    (one ``msg_overhead``) per batch per shard — and its Dones are
    buffered symmetrically into per-slot done buffers shipped as
    :class:`~repro.core.messages.DoneBatchMessage`s, flushed at the same
    points the submit buffers flush (capacity, taskwait ``flush``,
    ``drain_all``) plus whenever the owning slot goes idle (Dones, unlike
    Submits, gate successors' progress, so an idle owner must not sit on
    them)."""

    name = "sharded"
    uses_idle_managers = True

    def __init__(self, *args, num_shards: int = 4,
                 batch_size: Optional[int] = None,
                 delegation: bool = True, **kw) -> None:
        super().__init__(*args, **kw)
        if num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        if batch_size is not None and batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        self.num_shards = num_shards
        self.batch_size = batch_size
        self.delegation = delegation
        self.graph = ShardedDependenceGraph(num_shards)
        self.router = ShardRouter(self.graph,
                                  on_ready=self.placement.push,
                                  charge=self.charge,
                                  tracer=self.tracer,
                                  delegation=delegation,
                                  drain_quantum=self.params.drain_quantum)
        # Per-slot submit + done buffers. The owning slot appends; flush
        # may additionally be invoked by OTHER threads (drain_all at
        # taskwait/shutdown edges), so each buffer's read-swap and the
        # subsequent push_batch are serialized by a per-slot lock —
        # otherwise an append could land on an orphaned list and the WD
        # would never ship (its latches are already counted, so taskwait
        # would hang). push_batch stays inside the lock so two flushes
        # of one slot cannot interleave their mailbox entries, which
        # would break per-region FIFO order.
        self._buffers: List[List[WorkDescriptor]] = [
            [] for _ in range(self.num_slots)]
        self._done_buffers: List[List[WorkDescriptor]] = [
            [] for _ in range(self.num_slots)]
        self._buf_locks = [threading.Lock() for _ in range(self.num_slots)]
        # counters carried across resize() so stats stay cumulative
        self._carried = _blank_stats()

    # -- producer side --------------------------------------------------
    def submit(self, wd: WorkDescriptor, slot: int) -> None:
        if self.batch_size is None or self.batch_size <= 1:
            self.charge.push()
            self.router.route_submit(wd)
            return
        if self.router.prepare_submit(wd):
            self.charge.push()          # dependence-free: already ready;
            return                      # same producer cost as unbatched
        with self._buf_locks[slot]:
            buf = self._buffers[slot]
            buf.append(wd)
            if len(buf) >= self.batch_size:
                self._flush_submits_locked(slot)

    def flush(self, slot: int) -> None:
        with self._buf_locks[slot]:
            self._flush_submits_locked(slot)
            self._flush_dones_locked(slot)

    def _flush_submits_locked(self, slot: int) -> None:
        buf = self._buffers[slot]
        if not buf:
            return
        self._buffers[slot] = []
        self.charge.push()
        self.router.push_batch(buf)

    def _flush_dones_locked(self, slot: int) -> None:
        buf = self._done_buffers[slot]
        if not buf:
            return
        self._done_buffers[slot] = []
        self.charge.push()
        self.router.push_done_batch(buf)

    def complete(self, wd: WorkDescriptor, slot: int) -> None:
        # (Unbatched mode never buffers, so skip the per-completion lock
        # acquire entirely.)
        if self.batch_size is not None and self.batch_size > 1:
            with self._buf_locks[slot]:
                # A finished body can no longer extend its buffered
                # creations: flush them before the Done so
                # successors-by-batch can't be stranded behind an idle
                # worker.
                self._flush_submits_locked(slot)
                if wd.shard_parts:
                    # Done entries dominate high-shard-count mailbox
                    # traffic once Submits batch: buffer them the same
                    # way. Order vs. Submits is free either way — a
                    # Done processed before a later Submit just means
                    # the region was already scrubbed (the task IS
                    # completed), exactly the unbatched race.
                    buf = self._done_buffers[slot]
                    buf.append(wd)
                    if len(buf) >= self.batch_size:
                        self._flush_dones_locked(slot)
                    return
        # dependence-free tasks never entered any shard: route_done
        # completes them inline (no mailbox entry to batch)
        self.charge.push()
        self.router.route_done(wd)

    # -- manager side ---------------------------------------------------
    def idle_callback(self, worker_id: int) -> int:
        # An idle slot ships its own buffered Dones (and any buffered
        # Submits) when the ready pool has starved: a buffered Done
        # gates successor readiness, and nobody else flushes this slot
        # until a taskwait edge. While ready work remains anywhere the
        # buffer keeps filling toward a capacity flush (bigger batches);
        # the moment nothing is runnable, every idle worker flushes, so
        # progress can never stall on a buffered entry. Deliberately
        # BEFORE the manager admission gate — liveness must not depend
        # on winning a manager slot.
        if self.batch_size is not None and self.batch_size > 1 \
                and 0 <= worker_id < self.num_slots \
                and self.placement.ready_count() == 0:
            self.flush(worker_id)
        return super().idle_callback(worker_id)

    def _drain_once(self, worker_id: int) -> int:
        """One pass over the shard mailboxes: claim each free shard in
        turn (offset by worker id so concurrent managers spread out) and
        drain up to MAX_OPS_THREAD messages from it."""
        p = self.params
        router = self.router
        n = len(router.mailboxes)
        total_cnt = 0
        for off in range(n):
            if self.placement.ready_count() >= p.min_ready_tasks:
                break
            idx = (worker_id + off) % n
            # cheap peek before claiming: under delegation, published
            # portions live on the shard's request list, not the mailbox
            if router.mailboxes[idx].pending() == 0 \
                    and not self.graph.shards[idx].requests:
                continue
            total_cnt += router.drain_shard(idx, p.max_ops_thread)
        return total_cnt

    def drain_all(self) -> int:
        for slot in range(self.num_slots):
            self.flush(slot)
        n = self.router.drain_all()
        self.messages_processed += n
        return n

    def pending(self) -> int:
        return (self.router.pending()
                + sum(len(b) for b in self._buffers)
                + sum(len(b) for b in self._done_buffers))

    def in_graph(self) -> int:
        return self.graph.in_graph

    # -- online shard-count retuning ------------------------------------
    def resize(self, num_shards: int) -> bool:
        """Swap in a fresh ``num_shards``-way partition. Only legal at a
        quiescent point: nothing in any mailbox or buffer and nothing in
        the graph (``in_graph`` counts a task from Submit routing until
        its last Done portion, so zero also means nothing is running and
        nobody holds stale ``shard_parts``). Returns False when unsafe or
        a no-op; the caller (DynamicTuner) invokes this from the
        taskwait-quiescence hook on the main thread, the only thread that
        can start new work at that moment."""
        if num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        if num_shards == self.num_shards:
            return False
        if self.pending() or self.graph.in_graph:
            return False
        old = self.stats()
        for k in ("messages_processed", "lock_acquisitions", "lock_wait_s",
                  "total_edges", "delegated_portions", "combined_drains"):
            self._carried[k] = old[k]
        self._carried["max_in_graph"] = old["max_in_graph"]
        # per-shard counter lists survive the swap too — stats() already
        # merged any previously-carried lists into `old`, so carrying the
        # merged lists keeps them cumulative across repeated resizes
        self._carried["shard_messages"] = old["shard_messages"]
        self._carried["shard_lock_wait_s"] = old["shard_lock_wait_s"]
        self._carried["shard_lock_handoffs"] = old["shard_lock_handoffs"]
        self._carried["scope_portions"] = old["scope_portions"]
        self.num_shards = num_shards
        self.graph = ShardedDependenceGraph(num_shards)
        self.router = ShardRouter(self.graph,
                                  on_ready=self.placement.push,
                                  charge=self.charge,
                                  tracer=self.tracer,
                                  delegation=self.delegation,
                                  drain_quantum=self.params.drain_quantum)
        # shard-id-keyed affinity must follow the new partition function
        rekey = getattr(self.placement, "set_num_shards", None)
        if rekey is not None:
            rekey(num_shards)
        return True

    def stats(self) -> Dict[str, object]:
        c = self._carried
        st = _blank_stats()
        cur_msgs = [mb.messages_processed for mb in self.router.mailboxes]
        cur_waits = [s.lock.wait_s for s in self.graph.shards]
        st["shard_messages"] = _merge_shard_lists(c["shard_messages"],
                                                  cur_msgs)
        st["shard_lock_wait_s"] = _merge_shard_lists(c["shard_lock_wait_s"],
                                                     cur_waits)
        st["messages_processed"] = c["messages_processed"] + sum(cur_msgs)
        st["lock_acquisitions"] = c["lock_acquisitions"] + sum(
            s.lock.acquisitions for s in self.graph.shards)
        st["lock_wait_s"] = c["lock_wait_s"] + sum(cur_waits)
        st["max_in_graph"] = max(c["max_in_graph"],
                                 self.graph.max_in_graph)
        st["total_edges"] = c["total_edges"] + self.graph.total_edges
        # delegation/combining counters (zero in blocking-mailbox mode)
        st["delegated_portions"] = (c["delegated_portions"]
                                    + self.router.delegated_portions)
        st["combined_drains"] = (c["combined_drains"]
                                 + self.router.combined_drains)
        st["shard_lock_handoffs"] = _merge_shard_lists(
            c["shard_lock_handoffs"], self.router.lock_handoffs)
        merged: Dict[object, int] = dict(c["scope_portions"])
        for sc, k in self.router.scope_portions().items():
            merged[sc] = merged.get(sc, 0) + k
        st["scope_portions"] = merged
        return st

    def scope_drain_share(self, scope_id) -> int:
        """Cumulative dependence-analysis portions this tenant consumed
        through the combiners — folded into ``scope_rollup`` so per-tenant
        drain shares are visible alongside admission stats."""
        return self.stats()["scope_portions"].get(scope_id, 0)


_POLICIES = {
    "sync": SyncPolicy,
    "dast": DastPolicy,
    "ddast": DdastPolicy,
    "sharded": ShardedPolicy,
}

POLICY_NAMES = tuple(_POLICIES)


def mode_uses_shards(mode: str) -> bool:
    """True when ``mode`` resolves to a shard-partitioned policy — the
    only case a driver should switch shard-affine placement to shard-id
    affinity keying (outside it there is no shard partition to key by).
    Keeps that branching in the registry, not in the drivers."""
    if mode.startswith("replay:"):
        mode = mode[len("replay:"):]
    cls = _POLICIES.get(mode)
    return cls is not None and issubclass(cls, ShardedPolicy)


def mode_needs_manager_thread(mode: str) -> bool:
    """True when ``mode`` resolves to a policy that requires a dedicated
    manager (dast) — drivers use this for constructor-time validation
    (e.g. the simulator needs >= 2 cores for it) without per-mode
    branching of their own."""
    if mode.startswith("replay:"):
        mode = mode[len("replay:"):]
    try:
        cls = _POLICIES[mode]
    except KeyError:
        raise ValueError(f"mode must be one of {POLICY_NAMES}")
    return cls.needs_manager_thread


def make_policy(mode: str, num_slots: int, replay: bool = False,
                **kw) -> DependencePolicy:
    """Build the policy for ``mode``. ``num_shards``/``batch_size``/
    ``delegation`` are accepted for every mode and silently dropped where
    meaningless, so drivers stay free of per-mode branching. With ``replay=True`` (or a
    ``"replay:<mode>"`` mode string) the policy is wrapped in a
    :class:`~repro.core.engine.replay.ReplayPolicy`, which records the
    first iteration's task structure through the live policy and elides
    dependence analysis on structurally identical re-submissions."""
    if mode.startswith("replay:"):
        replay = True
        mode = mode[len("replay:"):]
    try:
        cls = _POLICIES[mode]
    except KeyError:
        raise ValueError(f"mode must be one of {POLICY_NAMES}")
    if not issubclass(cls, ShardedPolicy):
        kw.pop("num_shards", None)
        kw.pop("batch_size", None)
        kw.pop("delegation", None)
    pol = cls(num_slots, **kw)
    if replay:
        from .replay import ReplayPolicy
        pol = ReplayPolicy(pol)
    return pol
