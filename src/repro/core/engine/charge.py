"""Cost-charging adapter: how one policy implementation runs under two
drivers.

Every :class:`~repro.core.engine.policy.DependencePolicy` mutates *real*
data structures (``DependenceGraph``, ``ShardedDependenceGraph``, shard
mailboxes, ``StealDeque``s) and, around each protocol step, calls a hook
on its :class:`CostCharger`. The two drivers differ only in which charger
they install:

  * ``TaskRuntime`` (real threads) passes the no-op base class — real
    time simply passes, and the ``InstrumentedLock``s inside the
    structures record real contention;
  * ``RuntimeSimulator`` passes :class:`SimCharger`, which advances a
    virtual clock, serializes critical sections on :class:`VirtualLock`s
    (one per lock key), and records the §6.1 cache-pollution flag for
    the acting core.

This is what makes sim-vs-real divergence structurally impossible: the
dependence protocol runs exactly once, in the policy; the charger only
decides what the protocol *costs*.
"""
from __future__ import annotations

from typing import Dict, Hashable, Sequence, Set, Tuple


class CostCharger:
    """No-op charger used by the threaded driver. Method-per-event so the
    simulator can price each protocol step; all bodies are empty here."""

    __slots__ = ()

    def begin(self, slot: int, now: float) -> None:
        """Driver hook: the acting core/worker and its local clock."""

    def create(self) -> None:
        """WD allocation + argument capture."""

    def push(self) -> None:
        """One queue/mailbox push by the producing worker."""

    def message(self) -> None:
        """Manager pop+dispatch of one mailbox/queue entry."""

    def submit_cs(self, key: Hashable, ndeps: int) -> None:
        """Whole-graph Submit critical section under lock ``key``."""

    def done_cs(self, key: Hashable, ndeps: int) -> None:
        """Whole-graph Done critical section under lock ``key``."""

    def submit_portion_cs(self, key: Hashable, nlocal: int,
                          nparts: int) -> None:
        """One shard's portion of a Submit spanning ``nparts`` shards."""

    def done_portion_cs(self, key: Hashable, nlocal: int,
                        nparts: int) -> None:
        """One shard's portion of a Done spanning ``nparts`` shards."""

    def submit_batch_cs(self, key: Hashable,
                        portions: Sequence[Tuple[int, int]]) -> None:
        """A batched Submit: ``portions`` is one (nlocal, nparts) pair per
        task portion applied under a single lock acquisition."""

    def done_batch_cs(self, key: Hashable,
                      portions: Sequence[Tuple[int, int]]) -> None:
        """A batched Done: ``portions`` as in :meth:`submit_batch_cs`."""

    def replay_submit(self) -> None:
        """One record-and-replay Submit: an O(1) structural-key check +
        join-latch decrement — no lock, no message."""

    def replay_done(self, nsuccs: int) -> None:
        """One record-and-replay Done: ``nsuccs`` successor latch
        decrements — no lock, no message."""

    def prio_push(self) -> None:
        """One push into a ready deque's priority lane (critical-path
        replay placement) — a single banded deque append, no lock."""

    def prio_pop(self) -> None:
        """The pop-side band scan while replay priorities are active —
        no lock."""

    def trace_event(self) -> None:
        """One tracing ring-buffer append (core.trace). Free on real
        threads (the append IS the cost); priced in the simulator so
        the traced-vs-untraced overhead gate measures something real."""

    def ipc_submit(self) -> None:
        """One Submit batch encoded + pushed across a process boundary
        (the process backend's exec rings). Free on the real drivers —
        the ring push IS the cost; priced in the simulator so it can
        model ``backend="processes"`` before buying cores. Calibrate
        with ``bench_contention.py --calibrate``."""

    def ipc_done(self) -> None:
        """One Done batch decoded off a process-boundary ring (the
        process backend's done rings); see :meth:`ipc_submit`."""

    def delegate(self) -> None:
        """One Submit/Done portion published to a shard's MPSC request
        list (delegation/combining): a GIL-atomic deque append + a
        trylock attempt — never a blocking wait. Free on real threads;
        priced as ``SimCosts.delegate_us`` in the simulator."""

    def combine(self) -> None:
        """One combine session: the lock holder stages the published
        requests into per-scope buckets and applies them all in a single
        combined critical section. The per-message CS work is still
        charged through the ``*_cs`` hooks; this prices only the session
        setup (``SimCosts.combine_us``)."""

    def metric_event(self) -> None:
        """One live-metrics instrument write (core.metrics): a per-slot
        counter bump / histogram bucket increment. Free on real threads
        — the write IS the cost; priced in the simulator so the
        metrics-overhead gate measures something real."""

    def metric_sample(self) -> None:
        """One sampler pass (core.metrics.MetricsSampler): the idle
        thread that took the tick walks every registered probe and
        appends to the series rings. Amortized — at most one per
        sampling interval, never on the task hot path."""


class VirtualLock:
    """Serializes critical sections in virtual time (FIFO-handover
    approximation: an acquirer at local time t waits until ``free_at``)."""

    __slots__ = ("free_at", "wait_us", "acquisitions")

    def __init__(self) -> None:
        self.free_at = 0.0
        self.wait_us = 0.0
        self.acquisitions = 0

    def acquire(self, t: float, hold: float, overhead: float) -> float:
        start = max(t, self.free_at)
        self.wait_us += start - t
        self.acquisitions += 1
        end = start + hold + overhead
        self.free_at = end
        return end

    def delegated(self, t: float, hold: float, overhead: float) -> None:
        """Wait-free occupancy (delegation/combining): the shard still
        serializes the critical-section work — ``free_at`` advances past
        any in-progress holder — but the acting core never queues on it,
        so no wait accrues. This is the simulator's model of the trylock
        + publication-list protocol."""
        start = max(t, self.free_at)
        self.acquisitions += 1
        self.free_at = start + hold + overhead


class SimCharger(CostCharger):
    """Virtual-time charger: prices every protocol step with
    :class:`~repro.core.simulator.SimCosts` and keeps one
    :class:`VirtualLock` per lock key (``"graph"`` for the global-lock
    policies, ``("shard", i)`` per shard for the sharded one)."""

    __slots__ = ("costs", "now", "slot", "vlocks", "polluted",
                 "delegation")

    def __init__(self, costs, delegation: bool = False) -> None:
        self.costs = costs
        self.now = 0.0
        self.slot = -1
        self.vlocks: Dict[Hashable, VirtualLock] = {}
        # cores whose next task body runs ``costs.pollution`` slower
        # because they touched runtime structures (paper §6.1)
        self.polluted: Set[int] = set()
        # delegation/combining on: shard critical sections are applied
        # through the publication-list protocol, so they occupy the
        # shard's VirtualLock without making the acting core wait.
        self.delegation = delegation

    # -- driver side ----------------------------------------------------
    def begin(self, slot: int, now: float) -> None:
        self.slot = slot
        self.now = now

    # -- priced protocol steps ------------------------------------------
    def create(self) -> None:
        self.now += self.costs.create

    def push(self) -> None:
        self.now += self.costs.push

    def message(self) -> None:
        self.now += self.costs.msg_overhead

    def _acquire(self, key: Hashable, hold: float) -> None:
        vl = self.vlocks.get(key)
        if vl is None:
            vl = self.vlocks[key] = VirtualLock()
        if self.delegation and type(key) is tuple and key[0] == "shard":
            # wait-free: the combiner pays the CS work on its own clock
            # (someone must do it) but never queues behind the shard —
            # the published portion would simply be applied later.
            vl.delegated(self.now, hold, self.costs.lock_overhead)
            self.now += hold + self.costs.lock_overhead
        else:
            self.now = vl.acquire(self.now, hold,
                                  self.costs.lock_overhead)
        self.polluted.add(self.slot)

    def submit_cs(self, key: Hashable, ndeps: int) -> None:
        c = self.costs
        self._acquire(key, c.submit_cs + c.submit_cs_dep * ndeps)

    def done_cs(self, key: Hashable, ndeps: int) -> None:
        c = self.costs
        self._acquire(key, c.done_cs + c.done_cs_dep * ndeps)

    def _portion_hold(self, base: float, per_dep: float, nlocal: int,
                      nparts: int) -> float:
        # base cost split across the k shard portions, plus the measured
        # fixed per-portion overhead (latch arithmetic, mailbox dispatch)
        # and the per-dependence cost charged where the dep lives.
        return (base / max(nparts, 1) + self.costs.portion_overhead
                + per_dep * nlocal)

    def submit_portion_cs(self, key: Hashable, nlocal: int,
                          nparts: int) -> None:
        c = self.costs
        self._acquire(key, self._portion_hold(c.submit_cs, c.submit_cs_dep,
                                              nlocal, nparts))

    def done_portion_cs(self, key: Hashable, nlocal: int,
                        nparts: int) -> None:
        c = self.costs
        self._acquire(key, self._portion_hold(c.done_cs, c.done_cs_dep,
                                              nlocal, nparts))

    def submit_batch_cs(self, key: Hashable,
                        portions: Sequence[Tuple[int, int]]) -> None:
        c = self.costs
        hold = sum(self._portion_hold(c.submit_cs, c.submit_cs_dep, nl, np)
                   for nl, np in portions)
        self._acquire(key, hold)

    def done_batch_cs(self, key: Hashable,
                      portions: Sequence[Tuple[int, int]]) -> None:
        c = self.costs
        hold = sum(self._portion_hold(c.done_cs, c.done_cs_dep, nl, np)
                   for nl, np in portions)
        self._acquire(key, hold)

    # Replay steps touch no shared structure: pure local-time cost, no
    # VirtualLock and — deliberately — no pollution flag, which is how
    # the simulator models the §6.1 cache win compounding with replay.
    def replay_submit(self) -> None:
        self.now += self.costs.replay_submit

    def replay_done(self, nsuccs: int) -> None:
        self.now += (self.costs.replay_done
                     + self.costs.replay_dec * nsuccs)

    # Priority-lane traffic (critical-path placement): banded deque
    # appends and the pop-side band scan — local-time only, no
    # VirtualLock, no pollution flag (the lane is lock-free by design).
    def prio_push(self) -> None:
        self.now += self.costs.prio_push

    def prio_pop(self) -> None:
        self.now += self.costs.prio_pop

    # Tracing stamps are lock-free appends: local-time cost only, no
    # VirtualLock, no pollution flag.
    def trace_event(self) -> None:
        self.now += self.costs.trace_event

    # Live-metrics writes follow the tracing model exactly: per-slot
    # GIL-atomic stores, so local-time cost only — no VirtualLock, no
    # pollution flag. Sampling is the rate-limited read-side pass.
    def metric_event(self) -> None:
        self.now += self.costs.metric_event

    def metric_sample(self) -> None:
        self.now += self.costs.metric_sample

    # Cross-process ring traffic (modeling backend="processes"): the
    # rings are SPSC, so there is no lock to serialize on — pure
    # serialization + copy time on the acting side.
    def ipc_submit(self) -> None:
        self.now += self.costs.ipc_submit_us

    def ipc_done(self) -> None:
        self.now += self.costs.ipc_done_us

    # Delegation/combining: the publication append is lock-free
    # (local-time cost only); the combine-session setup is paid by the
    # lock holder, whose CS occupancy flows through _acquire above.
    def delegate(self) -> None:
        self.now += self.costs.delegate_us

    def combine(self) -> None:
        self.now += self.costs.combine_us

    # -- result aggregation ---------------------------------------------
    def lock_wait_us(self) -> float:
        return sum(v.wait_us for v in self.vlocks.values())

    def lock_acquisitions(self) -> int:
        return sum(v.acquisitions for v in self.vlocks.values())

    def max_free_at(self) -> float:
        return max((v.free_at for v in self.vlocks.values()), default=0.0)
