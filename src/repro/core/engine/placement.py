"""Placement policies: who gets a newly-ready task.

The Distributed Breadth-First ready pool (paper §4, point 4) is one
lock-free :class:`~repro.core.shards.StealDeque` per worker slot: the
owner pops LIFO from the hot end, thieves steal FIFO from the cold end.
The :class:`PlacementPolicy` owns those deques and decides which deque a
ready task lands on; it is mode-agnostic — every
:class:`~repro.core.engine.policy.DependencePolicy` pushes through it and
both drivers (threads and simulator) pop through it.

Two implementations:

  * :class:`RoundRobinPlacement` — the historical default: spread ready
    tasks evenly; the unguarded cursor update is a benign race (any value
    it yields is a valid target index).
  * :class:`ShardAffinePlacement` — the ROADMAP follow-up: push a ready
    task onto the deque of the worker that last *executed* a task
    touching one of its regions (cache locality: the region's blocks are
    warm in that core's cache). Falls back to round-robin when no
    affinity is known yet. The affinity map is updated by the driver via
    :meth:`note_executed`; dict reads/writes are atomic under the GIL and
    a stale entry only costs locality, never correctness.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, Hashable, List, Optional

from ..shards import StealDeque, stable_region_hash
from ..wd import WorkDescriptor


class PlacementPolicy:
    """Owns the per-slot ready deques; subclasses choose the target."""

    def __init__(self, num_slots: int) -> None:
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        self.deques: List[StealDeque] = [StealDeque()
                                         for _ in range(num_slots)]

    # -- protocol -------------------------------------------------------
    def push(self, wd: WorkDescriptor) -> None:
        raise NotImplementedError

    def pop(self, slot: int) -> Optional[WorkDescriptor]:
        """Own deque first (LIFO end), then steal around the ring
        (FIFO end, O(1) per attempt)."""
        wd = self.deques[slot].pop()
        if wd is not None:
            return wd
        n = len(self.deques)
        for off in range(1, n):
            wd = self.deques[(slot + off) % n].steal()
            if wd is not None:
                return wd
        return None

    def ready_count(self) -> int:
        return sum(len(d) for d in self.deques)

    def note_executed(self, wd: WorkDescriptor, slot: int) -> None:
        """Driver hook after a task body ran on ``slot``. Default: no
        bookkeeping."""

    def stats(self) -> Dict[str, int]:
        return {
            "pushed": sum(d.pushed for d in self.deques),
            "popped": sum(d.popped for d in self.deques),
            "stolen": sum(d.stolen for d in self.deques),
        }


class RoundRobinPlacement(PlacementPolicy):
    """Spread ready tasks evenly across the slots (historical default)."""

    def __init__(self, num_slots: int) -> None:
        super().__init__(num_slots)
        self._rr = 0

    def push(self, wd: WorkDescriptor) -> None:
        self.deques[self._rr].push(wd)
        self._rr = (self._rr + 1) % len(self.deques)


class ShardAffinePlacement(RoundRobinPlacement):
    """Prefer the deque of the worker that last touched the task's
    regions; falls back to the inherited round-robin push when no
    affinity is recorded.

    With ``num_shards`` set (the drivers pass their shard count), the
    map is keyed by SHARD ID — ``stable_region_hash(region) %
    num_shards``, the same partition function the sharded graph uses —
    instead of the exact region. That hard-bounds the map at
    ``num_shards`` entries on region-churning workloads (a streaming app
    touches unbounded regions but a fixed set of shards) and matches the
    locality the sharded manager creates anyway: tasks whose regions
    share a shard already share manager/lock cache lines. Without
    ``num_shards`` (direct construction) the exact-region keying and the
    bounded LRU (``max_regions`` entries, default 4096) remain.

    Reads and writes take a small lock — eviction mutates the ordered
    map, so the GIL alone is not enough — which is acceptable because
    this placement is opt-in and the critical section is two dict
    operations."""

    def __init__(self, num_slots: int, max_regions: int = 4096,
                 num_shards: Optional[int] = None) -> None:
        super().__init__(num_slots)
        self._affinity: "OrderedDict[Hashable, int]" = OrderedDict()
        self._max_regions = max(1, max_regions)
        self._num_shards = num_shards
        self._aff_lock = threading.Lock()
        self.affine_pushes = 0
        self.fallback_pushes = 0

    def _key(self, region: Hashable) -> Hashable:
        if self._num_shards:
            return stable_region_hash(region) % self._num_shards
        return region

    def set_num_shards(self, num_shards: int) -> None:
        """Re-key after an online shard-count retune
        (``ShardedPolicy.resize``): old buckets are meaningless under
        the new modulus, so the hint map is cleared — affinity rebuilds
        from the next executions, which is the same cold start a resize
        imposes on the shards themselves."""
        with self._aff_lock:
            # exact-region keying (None) is a deliberate construction
            # choice — a resize must not convert it to shard keying
            if self._num_shards is not None \
                    and num_shards != self._num_shards:
                self._num_shards = num_shards
                self._affinity.clear()

    def preferred_slot(self, wd: WorkDescriptor) -> Optional[int]:
        n = len(self.deques)
        with self._aff_lock:
            for region, _mode in wd.deps:
                slot = self._affinity.get(self._key(region))
                if slot is not None and slot < n:
                    return slot
        return None

    def push(self, wd: WorkDescriptor) -> None:
        slot = self.preferred_slot(wd)
        if slot is None:
            self.fallback_pushes += 1
            super().push(wd)            # inherited round-robin spread
            return
        self.affine_pushes += 1
        self.deques[slot].push(wd)

    def note_executed(self, wd: WorkDescriptor, slot: int) -> None:
        with self._aff_lock:
            for region, _mode in wd.deps:
                key = self._key(region)
                self._affinity[key] = slot
                self._affinity.move_to_end(key)
            while len(self._affinity) > self._max_regions:
                self._affinity.popitem(last=False)


_PLACEMENTS = {
    "round_robin": RoundRobinPlacement,
    "shard_affine": ShardAffinePlacement,
}


def make_placement(kind, num_slots: int,
                   num_shards: Optional[int] = None) -> PlacementPolicy:
    """``kind`` is a name from ``_PLACEMENTS``, an already-built
    :class:`PlacementPolicy` (returned as-is), or a class to
    instantiate. ``num_shards`` (from the driver) switches
    shard-affine placements to bounded shard-id affinity keying."""
    if isinstance(kind, PlacementPolicy):
        if len(kind.deques) != num_slots:
            raise ValueError(
                f"placement instance has {len(kind.deques)} deques, "
                f"driver needs {num_slots}")
        return kind
    if isinstance(kind, type) and issubclass(kind, PlacementPolicy):
        cls = kind
    else:
        try:
            cls = _PLACEMENTS[kind]
        except KeyError:
            raise ValueError(
                f"placement must be one of {sorted(_PLACEMENTS)}, "
                f"got {kind!r}")
    if num_shards and issubclass(cls, ShardAffinePlacement):
        return cls(num_slots, num_shards=num_shards)
    return cls(num_slots)
