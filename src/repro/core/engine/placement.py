"""Back-compat shim: placement policies moved into the unified
scheduling subsystem (:mod:`repro.core.sched.placement`), next to the
DAG core that powers ``CriticalPathPlacement``. Import from
``repro.core.sched`` in new code."""
from ..sched.placement import (PLACEMENT_NAMES, CriticalPathPlacement,
                               PlacementPolicy, RoundRobinPlacement,
                               ShardAffinePlacement, make_placement)

__all__ = [
    "PLACEMENT_NAMES", "PlacementPolicy", "RoundRobinPlacement",
    "ShardAffinePlacement", "CriticalPathPlacement", "make_placement",
]
