"""Unified dependence-policy engine.

One mode-agnostic core shared by the threaded ``TaskRuntime`` and the
virtual-time ``RuntimeSimulator``:

    ┌──────────────────────────┐   ┌──────────────────────────────┐
    │ TaskRuntime (threads)    │   │ RuntimeSimulator (virtual t) │
    │   CostCharger (no-op)    │   │   SimCharger (VirtualLocks)  │
    └────────────┬─────────────┘   └──────────────┬───────────────┘
                 └───────────── drives ───────────┘
                   ┌────────────────▼────────────────┐
                   │        DependencePolicy         │
                   │ Sync · Dast · Ddast · Sharded   │
                   └──┬───────────────────────────┬──┘
                      ▼                           ▼
             PlacementPolicy               graph structures
        (RoundRobin / ShardAffine       (DependenceGraph · shards:
         over per-slot StealDeques)      ShardedDependenceGraph,
                                         ShardRouter mailboxes)
"""
from .charge import CostCharger, SimCharger, VirtualLock
from .placement import (PLACEMENT_NAMES, CriticalPathPlacement,
                        PlacementPolicy, RoundRobinPlacement,
                        ShardAffinePlacement, make_placement)
from .policy import (POLICY_NAMES, DastPolicy, DdastPolicy,
                     DependencePolicy, ShardedPolicy, SyncPolicy,
                     make_policy, mode_needs_manager_thread,
                     mode_uses_shards)
from .replay import ReplayGraph, ReplayPolicy

__all__ = [
    "CostCharger", "SimCharger", "VirtualLock",
    "PLACEMENT_NAMES", "PlacementPolicy", "RoundRobinPlacement",
    "ShardAffinePlacement", "CriticalPathPlacement", "make_placement",
    "POLICY_NAMES", "DependencePolicy", "SyncPolicy", "DastPolicy",
    "DdastPolicy", "ShardedPolicy", "make_policy", "mode_uses_shards",
    "mode_needs_manager_thread",
    "ReplayGraph", "ReplayPolicy",
]
