"""Unified dependence-policy engine.

One mode-agnostic core shared by the threaded ``TaskRuntime`` and the
virtual-time ``RuntimeSimulator``:

    ┌──────────────────────────┐   ┌──────────────────────────────┐
    │ TaskRuntime (threads)    │   │ RuntimeSimulator (virtual t) │
    │   CostCharger (no-op)    │   │   SimCharger (VirtualLocks)  │
    └────────────┬─────────────┘   └──────────────┬───────────────┘
                 └───────────── drives ───────────┘
                   ┌────────────────▼────────────────┐
                   │        DependencePolicy         │
                   │ Sync · Dast · Ddast · Sharded   │
                   └──┬───────────────────────────┬──┘
                      ▼                           ▼
             PlacementPolicy               graph structures
        (RoundRobin / ShardAffine       (DependenceGraph · shards:
         over per-slot StealDeques)      ShardedDependenceGraph,
                                         ShardRouter mailboxes)
"""
from .charge import CostCharger, SimCharger, VirtualLock
from .placement import (PlacementPolicy, RoundRobinPlacement,
                        ShardAffinePlacement, make_placement)
from .policy import (POLICY_NAMES, DastPolicy, DdastPolicy,
                     DependencePolicy, ShardedPolicy, SyncPolicy,
                     make_policy, mode_uses_shards)
from .replay import ReplayGraph, ReplayPolicy

__all__ = [
    "CostCharger", "SimCharger", "VirtualLock",
    "PlacementPolicy", "RoundRobinPlacement", "ShardAffinePlacement",
    "make_placement",
    "POLICY_NAMES", "DependencePolicy", "SyncPolicy", "DastPolicy",
    "DdastPolicy", "ShardedPolicy", "make_policy", "mode_uses_shards",
    "ReplayGraph", "ReplayPolicy",
]
