"""Taskgraph record-and-replay: elide dependence analysis on repeated
graph submissions.

The iterative workloads of the paper's §4.2 (matmul epochs, N-Body
timesteps, repeated sparse-LU factorizations) submit a *structurally
identical* dependence graph every iteration, yet every Submit/Done pays
full dependence analysis, mailbox traffic, and lock acquisitions each
time. Taskgraph (Yu et al., 2212.04771) records the task graph once and
replays it; Álvarez et al. (2105.07902) replace per-task graph locking
with precomputed wait-free structures. :class:`ReplayPolicy` brings that
to every :class:`~repro.core.engine.policy.DependencePolicy`:

  * **record** — iteration 1 runs through the wrapped live policy
    unchanged while the wrapper records, per structural key (parent
    nesting position + the task's (region, mode) dependence sequence),
    the order of submissions within each parent's namespace.
  * **freeze** — at the first *root* taskwait quiescence the recording
    is resolved ONCE with the shared dependence rules
    (:func:`~repro.core.depgraph.collect_preds_and_register` — the same
    helper every live graph uses, so replay semantics cannot diverge)
    into an immutable :class:`ReplayGraph`: flat int-indexed successor
    arrays plus one :class:`_GenLatch` join latch per task, reset by a
    generation counter instead of re-allocation.
  * **replay** — subsequent submissions of a structurally identical
    graph bypass graph mutation, mailboxes, and locks entirely:
    ``submit`` is an O(1) key check + latch decrement, ``complete``
    decrements the recorded successors' latches and pushes newly-ready
    tasks straight into the ``PlacementPolicy``. Zero messages, zero
    graph-lock acquisitions on the steady-state path.
  * **prioritize** — at freeze time the wrapper also publishes
    scheduling knowledge to the
    :class:`~repro.core.sched.placement.PlacementPolicy`: per-task
    bottom levels (:func:`~repro.core.sched.dag.bottom_levels` over the
    frozen successor arrays, weighted by the per-task execution-time
    EMAs recorded through the drivers, default 1.0), so a
    critical-path-aware placement can start the longest remaining chain
    first. The EMAs keep updating during replay and the priorities are
    refreshed at each successful iteration boundary (a root-quiescent
    point). Placements that don't want priorities
    (``wants_replay_priorities`` False) skip the computation entirely.
  * **invalidate** — the moment a submission diverges from the
    recording (changed region, changed dep mode, extra task, unknown
    parent) the wrapper falls back: the already-replayed prefix is
    self-contained (dependence analysis only looks backwards, so a
    matching prefix's predecessor edges all lie within the prefix) and
    is left to finish under replay; diverging tasks are buffered per
    parent namespace and handed to the live policy for fresh analysis
    as soon as that namespace's replayed siblings have all completed
    (at which point an empty region map is exactly the correct state).
    The stale recording is *retired into the recording cache* (below),
    not dropped, and the next full iteration re-records. An iteration
    that submits *fewer* tasks than recorded executes correctly
    (two-phase latches: a never-submitted task's latch can never reach
    zero) and invalidates at its quiescence.
  * **multi-recording cache** — frozen graphs are kept in a small LRU
    cache (default 4) keyed by an order-canonical signature of the
    per-parent structural key sequences. Two paths consult it: (a) a
    fresh recording whose signature matches a cached graph reuses it at
    freeze time (no re-resolution, cost EMAs retained); (b) when the
    FIRST submission of an iteration fails to open the active recording
    — nothing replayed yet, so switching is trivially safe — the
    wrapper redispatches to a cached recording whose root namespace
    starts with that key. A/B alternating iteration patterns therefore
    replay both structures instead of re-recording on every switch;
    only structures that diverge mid-iteration still pay a live
    re-record per switch (their shared prefix makes a cold dispatch
    impossible).

The join latch is two-phase: it starts at ``predecessors + 1`` each
generation; the Submit contributes one decrement (after the WD is
registered) and each predecessor completion one more, so a completion
racing ahead of its successor's submission — legal, since different
parents submit from different threads — can never publish an
unregistered task.

Per-parent matching (rather than one global submission sequence) is what
makes replay sound under real threads: a parent's children are created
by the single thread executing the parent (§3.1), so each namespace's
submission order is deterministic, while the interleaving *across*
namespaces is not — and does not matter, because dependences only exist
between siblings (per-parent graphs everywhere in this runtime).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Set, Tuple

from ..depgraph import collect_preds_and_register
from ..sched.dag import bottom_levels
from ..shards.steal_deque import AtomicCounter
from ..wd import TaskState, WorkDescriptor
from .policy import DependencePolicy

_ROOT = -1

#: EMA factor for per-task execution-time tracking during replay.
_COST_EMA = 0.25

#: ReplayPolicy states (``replay_state`` property).
RECORDING = "recording"
REPLAYING = "replaying"


class _GenLatch:
    """Join latch reset by generation counter instead of re-allocation.

    ``dec(gen)`` lazily reinstates ``init`` the first time a new
    generation touches the latch, then decrements — so one allocation at
    freeze time serves every replay iteration, and a latch left dirty by
    a partial iteration (never-submitted task, post-divergence
    decrements) self-heals on its next use."""

    __slots__ = ("init", "_gen", "_value", "_lock")

    def __init__(self, init: int) -> None:
        self.init = init
        self._gen = -1
        self._value = init
        self._lock = threading.Lock()

    def dec(self, gen: int) -> int:
        with self._lock:
            if self._gen != gen:
                self._gen = gen
                self._value = self.init
            self._value -= 1
            return self._value


class _RecNode:
    """Identity-only stand-in for a WD during freeze-time analysis."""

    __slots__ = ("sid",)

    def __init__(self, sid: int) -> None:
        self.sid = sid


_DepsKey = Tuple[Tuple[Any, Any], ...]


def _deps_key(wd: WorkDescriptor) -> _DepsKey:
    """Canonical structural key of a task: its (region, mode) sequence.
    Region objects compare by value (they are dict keys everywhere), so
    a changed region, changed mode, or reordered dependence list all
    produce a different key."""
    return tuple((region, mode) for region, mode in wd.deps)


def _task_cost(wd: WorkDescriptor) -> Optional[float]:
    """The task's measured cost: real body time (threaded driver's
    ``exec_dur``, seconds) or virtual duration (simulator, µs) — only
    relative magnitude matters and the two never mix within a run.
    ``None`` when no measurement exists (the bottom-level fallback is a
    unit cost, i.e. chain length)."""
    c = getattr(wd, "exec_dur", None)
    if c is None:
        c = wd.duration
    return c


def _canonical_signature(
        children: Dict[int, List[Tuple[_DepsKey, int]]]) -> Tuple:
    """Order-canonical signature of a recording: each namespace's key
    sequence, tagged by the canonical index of the task heading it,
    enumerated in BFS order from the root namespace. Canonical indices
    are assigned in that same traversal, so the signature is invariant
    to the cross-namespace submission interleaving (which varies run to
    run under real threads) while distinguishing any structural change —
    exactly the equality the multi-recording cache needs."""
    canon: Dict[int, int] = {}
    items: List[Tuple[int, Tuple[_DepsKey, ...]]] = []
    queue: List[int] = [_ROOT]
    qi = 0
    while qi < len(queue):
        psid = queue[qi]
        qi += 1
        kids = children.get(psid)
        if not kids:
            continue
        for _key, sid in kids:
            canon[sid] = len(canon)
            queue.append(sid)
        items.append((_ROOT if psid == _ROOT else canon[psid],
                      tuple(k for k, _ in kids)))
    return tuple(items)


class ReplayGraph:
    """Immutable resolution of one recorded iteration.

    Flat, int-indexed arrays over structural ids (sids) assigned in
    recording order: ``succs[sid]`` — successor sids, ``preds[sid]`` —
    predecessor count, ``parent_sid[sid]`` — the parent's sid (or -1
    for a root-level task), ``latches[sid]`` — the two-phase join latch
    (initial value ``preds[sid] + 1``), ``children[psid]`` — the ordered
    ``(deps_key, sid)`` expectation list replay matches against."""

    __slots__ = ("n", "children", "parent_sid", "succs", "preds",
                 "latches", "root_ids", "total_edges", "costs",
                 "signature")

    def __init__(self, children: Dict[int, List[Tuple[_DepsKey, int]]],
                 parent_sid: List[int], root_ids: Set[int],
                 costs: Optional[Dict[int, float]] = None) -> None:
        n = len(parent_sid)
        self.n = n
        self.children = children
        self.parent_sid = parent_sid
        self.root_ids = root_ids
        # Per-task cost estimates (EMA-updated during replay) feeding the
        # critical-path placement's bottom levels; 1.0 (chain length)
        # until a measurement exists.
        self.costs: List[float] = [
            float((costs or {}).get(sid, 1.0)) for sid in range(n)]
        self.signature: Optional[Tuple] = None
        self.succs: List[List[int]] = [[] for _ in range(n)]
        self.preds: List[int] = [0] * n
        self.total_edges = 0
        # Resolve each namespace once with the SAME region rules the
        # live graphs use — the unified engine's single source of
        # dependence semantics.
        for kids in children.values():
            regions: Dict[Any, Any] = {}
            for key, sid in kids:
                pset = collect_preds_and_register(regions, _RecNode(sid),
                                                  key)
                self.preds[sid] = len(pset)
                self.total_edges += len(pset)
                for p in pset:
                    self.succs[p.sid].append(sid)
        self.latches = [_GenLatch(self.preds[sid] + 1) for sid in range(n)]

    def child_counts(self) -> List[int]:
        """Recorded children per namespace, indexed by psid + 1."""
        counts = [0] * (self.n + 1)
        for psid, kids in self.children.items():
            counts[psid + 1] = len(kids)
        return counts


class ReplayPolicy(DependencePolicy):
    """Record-and-replay wrapper over any live ``DependencePolicy``.

    Protocol calls delegate to the wrapped policy until a recording is
    frozen; from then on structurally matching submissions run on the
    :class:`ReplayGraph` alone. See the module docstring for the state
    machine. Unknown attributes delegate to the wrapped policy, so
    driver conveniences (``router``, ``worker_queues``, ``resize``, …)
    keep working."""

    def __init__(self, inner: DependencePolicy,
                 publish_priorities: bool = True,
                 scope: Optional[int] = None) -> None:
        # deliberately NOT calling super().__init__: the wrapped policy
        # owns slots/params/placement/charge; we delegate.
        self.inner = inner
        self.name = f"replay({inner.name})"
        # Whether this wrapper may drive the placement's banded priority
        # lane. Multi-tenant scope wrappers (core.scopes) set ``scope``
        # so their bottom levels land in a per-scope band table merged
        # into the placement's shared band-occupancy counters (see
        # CriticalPathPlacement) — several independent replay graphs
        # then rank their critical work on one global axis instead of
        # degrading to the normal lane.
        self.publish_priorities = publish_priorities
        self._scope = scope
        self._state = RECORDING
        # -- recording side (guarded by _rec_lock; slow path) ----------
        self._rec_lock = threading.Lock()
        self._rec_keys: List[_DepsKey] = []
        self._rec_parent: List[int] = []
        self._rec_children: Dict[int, List[Tuple[_DepsKey, int]]] = {}
        self._rec_sid_of: Dict[int, int] = {}
        self._rec_roots: Set[int] = set()
        self._rec_costs: Dict[int, float] = {}
        # -- frozen side (allocated once at freeze) --------------------
        self.replay_graph: Optional[ReplayGraph] = None
        self._gen = 0
        self._iter_wds: List[Optional[WorkDescriptor]] = []
        self._iter_sid_of: Dict[int, int] = {}
        self._iter_counts: List[int] = []       # children seen, by psid+1
        self._rec_counts: List[int] = []        # children recorded, ditto
        self._iter_started = False              # any task matched yet?
        # replay tasks in flight per namespace (psid + 1) and in total
        self._outstanding: List[AtomicCounter] = []
        self._live = AtomicCounter(0)
        # -- multi-recording cache (signature -> frozen graph, LRU) ----
        self.cache_size = 4
        self._cache: "OrderedDict[Tuple, ReplayGraph]" = OrderedDict()
        # -- divergence fallback ---------------------------------------
        self._diverged = False
        self._div_lock = threading.Lock()
        self._div_buffers: Dict[int, List[Tuple[WorkDescriptor, int]]] = {}
        self._div_buffered = 0
        # -- stats -----------------------------------------------------
        self.replay_iterations = 0
        self.replayed_tasks = 0
        self.invalidations = 0
        self.recordings = 0
        self.replay_cache_hits = 0

    # ------------------------------------------------------------------
    # delegation plumbing
    def __getattr__(self, item: str):
        return getattr(object.__getattribute__(self, "inner"), item)

    @property
    def needs_manager_thread(self) -> bool:
        return self.inner.needs_manager_thread

    @property
    def uses_idle_managers(self) -> bool:
        return self.inner.uses_idle_managers

    @property
    def idle_sleep_s(self) -> float:
        return self.inner.idle_sleep_s

    @property
    def callback_entries(self) -> int:
        return self.inner.callback_entries

    @property
    def messages_processed(self) -> int:
        return self.inner.messages_processed

    @property
    def replay_state(self) -> str:
        return self._state

    @property
    def recording_live(self) -> bool:
        """True while the current iteration is being recorded — global
        reconfiguration (e.g. ``ShardedPolicy.resize``) must wait, or
        the recording would freeze against structures that no longer
        exist."""
        return self._state == RECORDING and bool(self._rec_keys)

    def steady_iteration_complete(self) -> bool:
        """True when the in-progress iteration has submitted exactly the
        recorded structure — the whole frozen graph is accounted for and
        ``notify_quiescent`` is guaranteed to count it as a replay
        iteration. The process backend keys its replay plane on this:
        only then may the captured roots run worker-side off the shared
        arrays instead of through the mailboxes."""
        return (self._state == REPLAYING and not self._diverged
                and self._iter_started
                and self._iter_counts == self._rec_counts)

    # ------------------------------------------------------------------
    # protocol: submit
    def submit(self, wd: WorkDescriptor, slot: int) -> None:
        if self._state == RECORDING:
            self._record_submit(wd, slot)
        else:
            self._replay_submit(wd, slot)

    def _record_submit(self, wd: WorkDescriptor, slot: int) -> None:
        key = _deps_key(wd)
        pid = wd.parent.wd_id if wd.parent is not None else None
        with self._rec_lock:
            sid = len(self._rec_keys)
            if pid is None:
                psid = _ROOT
            else:
                psid = self._rec_sid_of.get(pid, _ROOT)
                if psid == _ROOT:
                    # an unrecorded parent at recording time is the
                    # driver's root task (everything else quiesced at
                    # the iteration boundary)
                    self._rec_roots.add(pid)
            self._rec_keys.append(key)
            self._rec_parent.append(psid)
            self._rec_children.setdefault(psid, []).append((key, sid))
            self._rec_sid_of[wd.wd_id] = sid
        self.inner.submit(wd, slot)

    def _replay_submit(self, wd: WorkDescriptor, slot: int) -> None:
        if self._diverged:
            self._fallback_submit(wd, slot)
            return
        g = self.replay_graph
        psid = self._parent_sid(wd)
        if psid is None:                # unknown live parent: structural
            self._invalidate(wd, slot)  # divergence by definition
            return
        idx = self._iter_counts[psid + 1]
        kids = g.children.get(psid)
        if kids is None or idx >= len(kids) \
                or kids[idx][0] != _deps_key(wd):
            if not self._iter_started and psid == _ROOT \
                    and self._redispatch(wd, slot):
                return                  # switched recording / re-recording
            self._invalidate(wd, slot)
            return
        self._iter_started = True
        sid = kids[idx][1]
        self._iter_counts[psid + 1] = idx + 1
        self._iter_wds[sid] = wd
        self._iter_sid_of[wd.wd_id] = sid
        self._outstanding[psid + 1].add(1)
        wd.state = TaskState.SUBMITTED
        self._live.add(1)
        self.replayed_tasks += 1
        self.charge.replay_submit()
        self._dec(sid)                  # the submit-phase latch unit

    def _redispatch(self, wd: WorkDescriptor, slot: int) -> bool:
        """The iteration's FIRST submission does not open the active
        recording. Nothing has been replayed yet, so two safe moves
        exist: switch to a cached recording this submission does open
        (the A/B alternating pattern), or start recording a brand-new
        structure from scratch. Runs race-free: the first root-level
        submission comes from the only thread with runnable work."""
        key = _deps_key(wd)
        for sig in reversed(self._cache):       # MRU first
            g = self._cache[sig]
            if g is self.replay_graph:
                continue
            kids = g.children.get(_ROOT)
            if kids and kids[0][0] == key:
                if wd.parent is not None:
                    # proven to be the driver root by the active graph's
                    # match of psid == _ROOT above
                    g.root_ids.add(wd.parent.wd_id)
                self.replay_cache_hits += 1
                self._activate_graph(g)
                self._iter_started = True
                self._replay_submit(wd, slot)   # re-match: idx 0 fits
                return True
        # no cached structure starts with this task: re-record. The
        # active graph stays cached (the old structure may come back).
        self.invalidations += 1
        self._retire_active()
        self._record_submit(wd, slot)
        return True

    def _parent_sid(self, wd: WorkDescriptor) -> Optional[int]:
        """The parent's structural id this iteration: its sid if it is a
        replayed task, -1 if it is the driver root, None if it is a live
        (non-replayed) task — which cannot happen before divergence."""
        if wd.parent is None:
            return _ROOT
        pid = wd.parent.wd_id
        sid = self._iter_sid_of.get(pid)
        if sid is not None:
            return sid
        if pid in self.replay_graph.root_ids:
            return _ROOT
        return None

    def _dec(self, sid: int) -> None:
        if self.replay_graph.latches[sid].dec(self._gen) == 0:
            wd = self._iter_wds[sid]
            wd.mark_ready()
            if self.publish_priorities:
                self.placement.push_replay(wd, sid)
            else:
                self.placement.push(wd)

    # ------------------------------------------------------------------
    # protocol: complete
    def complete(self, wd: WorkDescriptor, slot: int) -> None:
        sid = self._iter_sid_of.get(wd.wd_id)
        if sid is None:
            if self._state == RECORDING:
                rsid = self._rec_sid_of.get(wd.wd_id)
                if rsid is not None:
                    c = _task_cost(wd)
                    if c is not None:
                        self._rec_costs[rsid] = c
            self.inner.complete(wd, slot)
            return
        g = self.replay_graph
        c = _task_cost(wd)
        if c is not None:               # cost EMA feeds the priorities
            g.costs[sid] += _COST_EMA * (c - g.costs[sid])
        succs = g.succs[sid]
        self.charge.replay_done(len(succs))
        for t in succs:
            self._dec(t)
        psid = g.parent_sid[sid]
        if self._outstanding[psid + 1].add(-1) == 0 and self._diverged:
            self._flush_bucket(psid)
        self._live.add(-1)
        # parent bookkeeping LAST: once the waiter observes zero live
        # children it may reset iteration state, so all of this task's
        # replay bookkeeping must already be done.
        wd.mark_completed()

    # ------------------------------------------------------------------
    # divergence fallback
    def _invalidate(self, wd: WorkDescriptor, slot: int) -> None:
        self.invalidations += 1
        self._diverged = True
        self._fallback_submit(wd, slot)

    def _fallback_submit(self, wd: WorkDescriptor, slot: int) -> None:
        psid = self._parent_sid(wd)
        if psid is None:
            # live parent: none of its children were replay-managed, so
            # its namespace has no replayed predecessors to wait for —
            # straight to live analysis (still under _div_lock so
            # per-parent submission order is preserved vs. any flush
            # running on a completion thread).
            with self._div_lock:
                self.inner.submit(wd, slot)
            return
        with self._div_lock:
            if self._outstanding[psid + 1].value == 0 and \
                    not self._div_buffers.get(psid):
                # every replayed sibling completed (its region records
                # are gone from every live structure), so fresh analysis
                # is correct — submit in creation order, inline.
                self.inner.submit(wd, slot)
                return
            self._div_buffers.setdefault(psid, []).append((wd, slot))
            self._div_buffered += 1

    def _flush_bucket(self, psid: int) -> None:
        with self._div_lock:
            buf = self._div_buffers.pop(psid, None)
            if not buf:
                return
            self._div_buffered -= len(buf)
            for wd, slot in buf:
                self.inner.submit(wd, slot)

    # ------------------------------------------------------------------
    # iteration boundaries
    def notify_quiescent(self, root: bool = True,
                         scope_id: Optional[int] = None) -> None:
        del scope_id                    # routing happens one layer up
        if not root:
            return
        if self._state == RECORDING:
            if self._rec_keys:
                self._freeze()
            return
        # replaying: decide whether the finished iteration kept faith
        if not self._diverged and not self._iter_started:
            return                      # empty boundary (e.g. shutdown)
        if not self._diverged and self._iter_counts == self._rec_counts:
            self.replay_iterations += 1
            self._reset_iteration()
            self._publish_priorities()  # refresh bands from the EMAs
            return
        # structural divergence (mid-iteration fallback, or fewer tasks
        # than recorded): retire the recording into the cache and
        # re-record next iteration (freeze will reuse a cached graph if
        # the new structure has been seen before).
        self.invalidations += 0 if self._diverged else 1
        self._retire_active()

    def _freeze(self) -> None:
        sig = _canonical_signature(self._rec_children)
        g = self._cache.get(sig)
        if g is not None:
            # structurally identical to a cached recording: reuse its
            # resolved graph (and its warmer cost EMAs) outright
            self.replay_cache_hits += 1
            g.root_ids |= self._rec_roots
        else:
            g = ReplayGraph(self._rec_children, self._rec_parent,
                            self._rec_roots, self._rec_costs)
            g.signature = sig
            self.recordings += 1
            self._cache[sig] = g
            while len(self._cache) > self.cache_size:
                self._cache.popitem(last=False)
        self._activate_graph(g)
        self._reset_recording()

    def _activate_graph(self, g: ReplayGraph) -> None:
        """Make ``g`` the active frozen recording (from a fresh freeze, a
        freeze-time cache hit, or a first-submission redispatch — all
        root-quiescent points). The shared generation counter keeps
        monotonically increasing across activations so a graph's latches
        always see a fresh generation when it comes back."""
        self.replay_graph = g
        self._rec_counts = g.child_counts()
        self._iter_counts = [0] * (g.n + 1)
        self._iter_wds = [None] * g.n
        self._outstanding = [AtomicCounter(0) for _ in range(g.n + 1)]
        self._iter_sid_of = {}
        self._gen += 1
        self._iter_started = False
        self._state = REPLAYING
        if g.signature in self._cache:
            self._cache.move_to_end(g.signature)
        self._publish_priorities()

    def _publish_priorities(self) -> None:
        """Hand the active graph's bottom levels (over the recorded
        successor arrays, weighted by the cost EMAs) to the placement —
        skipped entirely unless the placement asks for them."""
        if not self.publish_priorities:
            return
        if not getattr(self.placement, "wants_replay_priorities", False):
            return
        g = self.replay_graph
        if g is None:
            return
        self.placement.set_replay_priorities(
            bottom_levels(g.succs, g.costs), scope=self._scope)

    def _reset_iteration(self) -> None:
        self._gen += 1
        self._iter_sid_of.clear()
        self._iter_started = False
        counts = self._iter_counts
        for i in range(len(counts)):
            counts[i] = 0
        # _iter_wds entries are overwritten before any latch can reach
        # zero next generation (two-phase latch), so no clear needed.

    def _reset_recording(self) -> None:
        self._rec_keys = []
        self._rec_parent = []
        self._rec_children = {}
        self._rec_sid_of = {}
        self._rec_roots = set()
        self._rec_costs = {}

    def _retire_active(self) -> None:
        """The active recording failed this iteration's structure: keep
        it in the cache (alternating patterns come back to it), clear
        the live replay state, and return to RECORDING."""
        if self.publish_priorities and \
                getattr(self.placement, "wants_replay_priorities", False):
            self.placement.clear_replay_priorities(scope=self._scope)
        self.replay_graph = None
        self._diverged = False
        self._div_buffers = {}
        self._div_buffered = 0
        self._iter_sid_of = {}
        self._iter_counts = []
        self._rec_counts = []
        self._iter_wds = []
        self._outstanding = []
        self._iter_started = False
        self._state = RECORDING
        self._reset_recording()

    # ------------------------------------------------------------------
    # remaining protocol: delegate, folding in replay-side state
    def idle_callback(self, worker_id: int) -> int:
        return self.inner.idle_callback(worker_id)

    def drain_all(self) -> int:
        return self.inner.drain_all()

    def flush(self, slot: int) -> None:
        self.inner.flush(slot)

    def pending(self) -> int:
        return self.inner.pending() + self._div_buffered

    def in_graph(self) -> int:
        return self.inner.in_graph() + self._live.value

    def stats(self) -> Dict[str, object]:
        st = dict(self.inner.stats())
        st["replay"] = {
            "state": self._state,
            "recordings": self.recordings,
            "replay_iterations": self.replay_iterations,
            "replayed_tasks": self.replayed_tasks,
            "invalidations": self.invalidations,
            "cache_hits": self.replay_cache_hits,
            "cached_recordings": len(self._cache),
            "recorded_tasks": (self.replay_graph.n
                               if self.replay_graph is not None else 0),
            "recorded_edges": (self.replay_graph.total_edges
                               if self.replay_graph is not None else 0),
        }
        return st
