"""Taskgraph record-and-replay: elide dependence analysis on repeated
graph submissions.

The iterative workloads of the paper's §4.2 (matmul epochs, N-Body
timesteps, repeated sparse-LU factorizations) submit a *structurally
identical* dependence graph every iteration, yet every Submit/Done pays
full dependence analysis, mailbox traffic, and lock acquisitions each
time. Taskgraph (Yu et al., 2212.04771) records the task graph once and
replays it; Álvarez et al. (2105.07902) replace per-task graph locking
with precomputed wait-free structures. :class:`ReplayPolicy` brings that
to every :class:`~repro.core.engine.policy.DependencePolicy`:

  * **record** — iteration 1 runs through the wrapped live policy
    unchanged while the wrapper records, per structural key (parent
    nesting position + the task's (region, mode) dependence sequence),
    the order of submissions within each parent's namespace.
  * **freeze** — at the first *root* taskwait quiescence the recording
    is resolved ONCE with the shared dependence rules
    (:func:`~repro.core.depgraph.collect_preds_and_register` — the same
    helper every live graph uses, so replay semantics cannot diverge)
    into an immutable :class:`ReplayGraph`: flat int-indexed successor
    arrays plus one :class:`_GenLatch` join latch per task, reset by a
    generation counter instead of re-allocation.
  * **replay** — subsequent submissions of a structurally identical
    graph bypass graph mutation, mailboxes, and locks entirely:
    ``submit`` is an O(1) key check + latch decrement, ``complete``
    decrements the recorded successors' latches and pushes newly-ready
    tasks straight into the ``PlacementPolicy``. Zero messages, zero
    graph-lock acquisitions on the steady-state path.
  * **invalidate** — the moment a submission diverges from the
    recording (changed region, changed dep mode, extra task, unknown
    parent) the wrapper falls back: the already-replayed prefix is
    self-contained (dependence analysis only looks backwards, so a
    matching prefix's predecessor edges all lie within the prefix) and
    is left to finish under replay; diverging tasks are buffered per
    parent namespace and handed to the live policy for fresh analysis
    as soon as that namespace's replayed siblings have all completed
    (at which point an empty region map is exactly the correct state).
    The stale recording is dropped and the next full iteration
    re-records. An iteration that submits *fewer* tasks than recorded
    executes correctly (two-phase latches: a never-submitted task's
    latch can never reach zero) and invalidates at its quiescence.

The join latch is two-phase: it starts at ``predecessors + 1`` each
generation; the Submit contributes one decrement (after the WD is
registered) and each predecessor completion one more, so a completion
racing ahead of its successor's submission — legal, since different
parents submit from different threads — can never publish an
unregistered task.

Per-parent matching (rather than one global submission sequence) is what
makes replay sound under real threads: a parent's children are created
by the single thread executing the parent (§3.1), so each namespace's
submission order is deterministic, while the interleaving *across*
namespaces is not — and does not matter, because dependences only exist
between siblings (per-parent graphs everywhere in this runtime).
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Set, Tuple

from ..depgraph import collect_preds_and_register
from ..shards.steal_deque import AtomicCounter
from ..wd import TaskState, WorkDescriptor
from .policy import DependencePolicy

_ROOT = -1

#: ReplayPolicy states (``replay_state`` property).
RECORDING = "recording"
REPLAYING = "replaying"


class _GenLatch:
    """Join latch reset by generation counter instead of re-allocation.

    ``dec(gen)`` lazily reinstates ``init`` the first time a new
    generation touches the latch, then decrements — so one allocation at
    freeze time serves every replay iteration, and a latch left dirty by
    a partial iteration (never-submitted task, post-divergence
    decrements) self-heals on its next use."""

    __slots__ = ("init", "_gen", "_value", "_lock")

    def __init__(self, init: int) -> None:
        self.init = init
        self._gen = -1
        self._value = init
        self._lock = threading.Lock()

    def dec(self, gen: int) -> int:
        with self._lock:
            if self._gen != gen:
                self._gen = gen
                self._value = self.init
            self._value -= 1
            return self._value


class _RecNode:
    """Identity-only stand-in for a WD during freeze-time analysis."""

    __slots__ = ("sid",)

    def __init__(self, sid: int) -> None:
        self.sid = sid


_DepsKey = Tuple[Tuple[Any, Any], ...]


def _deps_key(wd: WorkDescriptor) -> _DepsKey:
    """Canonical structural key of a task: its (region, mode) sequence.
    Region objects compare by value (they are dict keys everywhere), so
    a changed region, changed mode, or reordered dependence list all
    produce a different key."""
    return tuple((region, mode) for region, mode in wd.deps)


class ReplayGraph:
    """Immutable resolution of one recorded iteration.

    Flat, int-indexed arrays over structural ids (sids) assigned in
    recording order: ``succs[sid]`` — successor sids, ``preds[sid]`` —
    predecessor count, ``parent_sid[sid]`` — the parent's sid (or -1
    for a root-level task), ``latches[sid]`` — the two-phase join latch
    (initial value ``preds[sid] + 1``), ``children[psid]`` — the ordered
    ``(deps_key, sid)`` expectation list replay matches against."""

    __slots__ = ("n", "children", "parent_sid", "succs", "preds",
                 "latches", "root_ids", "total_edges")

    def __init__(self, children: Dict[int, List[Tuple[_DepsKey, int]]],
                 parent_sid: List[int], root_ids: Set[int]) -> None:
        n = len(parent_sid)
        self.n = n
        self.children = children
        self.parent_sid = parent_sid
        self.root_ids = root_ids
        self.succs: List[List[int]] = [[] for _ in range(n)]
        self.preds: List[int] = [0] * n
        self.total_edges = 0
        # Resolve each namespace once with the SAME region rules the
        # live graphs use — the unified engine's single source of
        # dependence semantics.
        for kids in children.values():
            regions: Dict[Any, Any] = {}
            for key, sid in kids:
                pset = collect_preds_and_register(regions, _RecNode(sid),
                                                  key)
                self.preds[sid] = len(pset)
                self.total_edges += len(pset)
                for p in pset:
                    self.succs[p.sid].append(sid)
        self.latches = [_GenLatch(self.preds[sid] + 1) for sid in range(n)]

    def child_counts(self) -> List[int]:
        """Recorded children per namespace, indexed by psid + 1."""
        counts = [0] * (self.n + 1)
        for psid, kids in self.children.items():
            counts[psid + 1] = len(kids)
        return counts


class ReplayPolicy(DependencePolicy):
    """Record-and-replay wrapper over any live ``DependencePolicy``.

    Protocol calls delegate to the wrapped policy until a recording is
    frozen; from then on structurally matching submissions run on the
    :class:`ReplayGraph` alone. See the module docstring for the state
    machine. Unknown attributes delegate to the wrapped policy, so
    driver conveniences (``router``, ``worker_queues``, ``resize``, …)
    keep working."""

    def __init__(self, inner: DependencePolicy) -> None:
        # deliberately NOT calling super().__init__: the wrapped policy
        # owns slots/params/placement/charge; we delegate.
        self.inner = inner
        self.name = f"replay({inner.name})"
        self._state = RECORDING
        # -- recording side (guarded by _rec_lock; slow path) ----------
        self._rec_lock = threading.Lock()
        self._rec_keys: List[_DepsKey] = []
        self._rec_parent: List[int] = []
        self._rec_children: Dict[int, List[Tuple[_DepsKey, int]]] = {}
        self._rec_sid_of: Dict[int, int] = {}
        self._rec_roots: Set[int] = set()
        # -- frozen side (allocated once at freeze) --------------------
        self.replay_graph: Optional[ReplayGraph] = None
        self._gen = 0
        self._iter_wds: List[Optional[WorkDescriptor]] = []
        self._iter_sid_of: Dict[int, int] = {}
        self._iter_counts: List[int] = []       # children seen, by psid+1
        self._rec_counts: List[int] = []        # children recorded, ditto
        # replay tasks in flight per namespace (psid + 1) and in total
        self._outstanding: List[AtomicCounter] = []
        self._live = AtomicCounter(0)
        # -- divergence fallback ---------------------------------------
        self._diverged = False
        self._div_lock = threading.Lock()
        self._div_buffers: Dict[int, List[Tuple[WorkDescriptor, int]]] = {}
        self._div_buffered = 0
        # -- stats -----------------------------------------------------
        self.replay_iterations = 0
        self.replayed_tasks = 0
        self.invalidations = 0
        self.recordings = 0

    # ------------------------------------------------------------------
    # delegation plumbing
    def __getattr__(self, item: str):
        return getattr(object.__getattribute__(self, "inner"), item)

    @property
    def needs_manager_thread(self) -> bool:
        return self.inner.needs_manager_thread

    @property
    def uses_idle_managers(self) -> bool:
        return self.inner.uses_idle_managers

    @property
    def idle_sleep_s(self) -> float:
        return self.inner.idle_sleep_s

    @property
    def callback_entries(self) -> int:
        return self.inner.callback_entries

    @property
    def messages_processed(self) -> int:
        return self.inner.messages_processed

    @property
    def replay_state(self) -> str:
        return self._state

    @property
    def recording_live(self) -> bool:
        """True while the current iteration is being recorded — global
        reconfiguration (e.g. ``ShardedPolicy.resize``) must wait, or
        the recording would freeze against structures that no longer
        exist."""
        return self._state == RECORDING and bool(self._rec_keys)

    # ------------------------------------------------------------------
    # protocol: submit
    def submit(self, wd: WorkDescriptor, slot: int) -> None:
        if self._state == RECORDING:
            self._record_submit(wd, slot)
        else:
            self._replay_submit(wd, slot)

    def _record_submit(self, wd: WorkDescriptor, slot: int) -> None:
        key = _deps_key(wd)
        pid = wd.parent.wd_id if wd.parent is not None else None
        with self._rec_lock:
            sid = len(self._rec_keys)
            if pid is None:
                psid = _ROOT
            else:
                psid = self._rec_sid_of.get(pid, _ROOT)
                if psid == _ROOT:
                    # an unrecorded parent at recording time is the
                    # driver's root task (everything else quiesced at
                    # the iteration boundary)
                    self._rec_roots.add(pid)
            self._rec_keys.append(key)
            self._rec_parent.append(psid)
            self._rec_children.setdefault(psid, []).append((key, sid))
            self._rec_sid_of[wd.wd_id] = sid
        self.inner.submit(wd, slot)

    def _replay_submit(self, wd: WorkDescriptor, slot: int) -> None:
        if self._diverged:
            self._fallback_submit(wd, slot)
            return
        g = self.replay_graph
        psid = self._parent_sid(wd)
        if psid is None:                # unknown live parent: structural
            self._invalidate(wd, slot)  # divergence by definition
            return
        idx = self._iter_counts[psid + 1]
        kids = g.children.get(psid)
        if kids is None or idx >= len(kids) \
                or kids[idx][0] != _deps_key(wd):
            self._invalidate(wd, slot)
            return
        sid = kids[idx][1]
        self._iter_counts[psid + 1] = idx + 1
        self._iter_wds[sid] = wd
        self._iter_sid_of[wd.wd_id] = sid
        self._outstanding[psid + 1].add(1)
        wd.state = TaskState.SUBMITTED
        self._live.add(1)
        self.replayed_tasks += 1
        self.charge.replay_submit()
        self._dec(sid)                  # the submit-phase latch unit

    def _parent_sid(self, wd: WorkDescriptor) -> Optional[int]:
        """The parent's structural id this iteration: its sid if it is a
        replayed task, -1 if it is the driver root, None if it is a live
        (non-replayed) task — which cannot happen before divergence."""
        if wd.parent is None:
            return _ROOT
        pid = wd.parent.wd_id
        sid = self._iter_sid_of.get(pid)
        if sid is not None:
            return sid
        if pid in self.replay_graph.root_ids:
            return _ROOT
        return None

    def _dec(self, sid: int) -> None:
        if self.replay_graph.latches[sid].dec(self._gen) == 0:
            wd = self._iter_wds[sid]
            wd.mark_ready()
            self.placement.push(wd)

    # ------------------------------------------------------------------
    # protocol: complete
    def complete(self, wd: WorkDescriptor, slot: int) -> None:
        sid = self._iter_sid_of.get(wd.wd_id)
        if sid is None:
            self.inner.complete(wd, slot)
            return
        g = self.replay_graph
        succs = g.succs[sid]
        self.charge.replay_done(len(succs))
        for t in succs:
            self._dec(t)
        psid = g.parent_sid[sid]
        if self._outstanding[psid + 1].add(-1) == 0 and self._diverged:
            self._flush_bucket(psid)
        self._live.add(-1)
        # parent bookkeeping LAST: once the waiter observes zero live
        # children it may reset iteration state, so all of this task's
        # replay bookkeeping must already be done.
        wd.mark_completed()

    # ------------------------------------------------------------------
    # divergence fallback
    def _invalidate(self, wd: WorkDescriptor, slot: int) -> None:
        self.invalidations += 1
        self._diverged = True
        self._fallback_submit(wd, slot)

    def _fallback_submit(self, wd: WorkDescriptor, slot: int) -> None:
        psid = self._parent_sid(wd)
        if psid is None:
            # live parent: none of its children were replay-managed, so
            # its namespace has no replayed predecessors to wait for —
            # straight to live analysis (still under _div_lock so
            # per-parent submission order is preserved vs. any flush
            # running on a completion thread).
            with self._div_lock:
                self.inner.submit(wd, slot)
            return
        with self._div_lock:
            if self._outstanding[psid + 1].value == 0 and \
                    not self._div_buffers.get(psid):
                # every replayed sibling completed (its region records
                # are gone from every live structure), so fresh analysis
                # is correct — submit in creation order, inline.
                self.inner.submit(wd, slot)
                return
            self._div_buffers.setdefault(psid, []).append((wd, slot))
            self._div_buffered += 1

    def _flush_bucket(self, psid: int) -> None:
        with self._div_lock:
            buf = self._div_buffers.pop(psid, None)
            if not buf:
                return
            self._div_buffered -= len(buf)
            for wd, slot in buf:
                self.inner.submit(wd, slot)

    # ------------------------------------------------------------------
    # iteration boundaries
    def notify_quiescent(self, root: bool = True) -> None:
        if not root:
            return
        if self._state == RECORDING:
            if self._rec_keys:
                self._freeze()
            return
        # replaying: decide whether the finished iteration kept faith
        if not self._diverged and not any(self._iter_counts):
            return                      # empty boundary (e.g. shutdown)
        if not self._diverged and self._iter_counts == self._rec_counts:
            self.replay_iterations += 1
            self._reset_iteration()
            return
        # structural divergence (mid-iteration fallback, or fewer tasks
        # than recorded): drop the recording, re-record next iteration.
        self.invalidations += 0 if self._diverged else 1
        self._drop_recording()

    def _freeze(self) -> None:
        g = ReplayGraph(self._rec_children, self._rec_parent,
                        self._rec_roots)
        self.replay_graph = g
        self._rec_counts = g.child_counts()
        self._iter_counts = [0] * (g.n + 1)
        self._iter_wds = [None] * g.n
        self._outstanding = [AtomicCounter(0) for _ in range(g.n + 1)]
        self._iter_sid_of = {}
        self._gen = 0
        self._state = REPLAYING
        self.recordings += 1
        self._reset_recording()

    def _reset_iteration(self) -> None:
        self._gen += 1
        self._iter_sid_of.clear()
        counts = self._iter_counts
        for i in range(len(counts)):
            counts[i] = 0
        # _iter_wds entries are overwritten before any latch can reach
        # zero next generation (two-phase latch), so no clear needed.

    def _reset_recording(self) -> None:
        self._rec_keys = []
        self._rec_parent = []
        self._rec_children = {}
        self._rec_sid_of = {}
        self._rec_roots = set()

    def _drop_recording(self) -> None:
        self.replay_graph = None
        self._diverged = False
        self._div_buffers = {}
        self._div_buffered = 0
        self._iter_sid_of = {}
        self._iter_counts = []
        self._rec_counts = []
        self._iter_wds = []
        self._outstanding = []
        self._state = RECORDING
        self._reset_recording()

    # ------------------------------------------------------------------
    # remaining protocol: delegate, folding in replay-side state
    def idle_callback(self, worker_id: int) -> int:
        return self.inner.idle_callback(worker_id)

    def drain_all(self) -> int:
        return self.inner.drain_all()

    def flush(self, slot: int) -> None:
        self.inner.flush(slot)

    def pending(self) -> int:
        return self.inner.pending() + self._div_buffered

    def in_graph(self) -> int:
        return self.inner.in_graph() + self._live.value

    def stats(self) -> Dict[str, object]:
        st = dict(self.inner.stats())
        st["replay"] = {
            "state": self._state,
            "recordings": self.recordings,
            "replay_iterations": self.replay_iterations,
            "replayed_tasks": self.replayed_tasks,
            "invalidations": self.invalidations,
            "recorded_tasks": (self.replay_graph.n
                               if self.replay_graph is not None else 0),
            "recorded_edges": (self.replay_graph.total_edges
                               if self.replay_graph is not None else 0),
        }
        return st
