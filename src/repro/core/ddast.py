"""DDAST — the Distributed DAS Thread manager callback (paper §3.3, Listing 2).

Any idle worker thread that enters the callback becomes a *manager thread*
and drains the per-worker message queues, updating the dependence graph.
Faithful port of Listing 2 with the four tunables and the tuned defaults
from Table 5:

    MAX_DDAST_THREADS  = ceil(num_threads / 8)      (initial: inf)
    MAX_SPINS          = 1                           (initial: 20)
    MAX_OPS_THREAD     = 8                           (initial: 6)
    MIN_READY_TASKS    = 4                           (initial: 4)
"""
from __future__ import annotations

import math
import threading
from dataclasses import dataclass
from typing import TYPE_CHECKING, Optional

if TYPE_CHECKING:  # pragma: no cover
    from .runtime import TaskRuntime


@dataclass
class DDASTParams:
    max_ddast_threads: Optional[int] = None  # None -> ceil(num_threads/8)
    max_spins: int = 1
    max_ops_thread: int = 8
    min_ready_tasks: int = 4

    def resolved_max_threads(self, num_threads: int) -> int:
        if self.max_ddast_threads is None:
            return max(1, math.ceil(num_threads / 8))
        return self.max_ddast_threads

    @staticmethod
    def initial() -> "DDASTParams":
        """Pre-tuning values (Table 5, 'Initial Value' column)."""
        return DDASTParams(max_ddast_threads=1 << 30, max_spins=20,
                           max_ops_thread=6, min_ready_tasks=4)


class DDASTManager:
    """Holds manager-side state; `callback` is what gets registered in the
    Functionality Dispatcher."""

    def __init__(self, runtime: "TaskRuntime", params: DDASTParams) -> None:
        self.rt = runtime
        self.params = params
        self._active = 0
        self._active_lock = threading.Lock()
        self.messages_processed = 0
        self.callback_entries = 0

    # -- Listing 2 ------------------------------------------------------
    def callback(self, worker_id: int) -> None:
        rt, p = self.rt, self.params
        eligible = getattr(rt, "manager_eligible", None)
        if eligible is not None and worker_id != rt.num_workers \
                and worker_id not in eligible:
            return                      # big.LITTLE: not a manager core
        max_threads = p.resolved_max_threads(rt.num_workers)
        with self._active_lock:
            if self._active >= max_threads:
                return
            self._active += 1
        self.callback_entries += 1
        # sharded mode: managers claim whole shards instead of whole
        # per-worker queues; the spin/min-ready policy is identical.
        drain_once = (self._drain_shards_once if rt.mode == "sharded"
                      else self._drain_queues_once)
        try:
            spins = p.max_spins
            while True:
                total_cnt = drain_once(worker_id)
                self.messages_processed += total_cnt
                spins = (spins - 1) if total_cnt == 0 else p.max_spins
                if spins == 0 or rt.ready_count() >= p.min_ready_tasks:
                    break
        finally:
            with self._active_lock:
                self._active -= 1

    def _drain_queues_once(self, worker_id: int) -> int:
        """One pass over the per-worker queues (Listing 2 lines 6-15)."""
        del worker_id
        rt, p = self.rt, self.params
        total_cnt = 0
        for wq in rt.worker_queues:
            if rt.ready_count() >= p.min_ready_tasks:
                break
            cnt = 0
            if wq.acquire_submit():
                try:
                    while cnt < p.max_ops_thread:
                        msg = wq.submit.pop()
                        if msg is None:
                            break
                        rt.satisfy_submit(msg.wd)
                        cnt += 1
                finally:
                    wq.release_submit()
            while cnt < p.max_ops_thread:
                msg = wq.done.pop()
                if msg is None:
                    break
                rt.satisfy_done(msg.wd)
                cnt += 1
            total_cnt += cnt
        return total_cnt

    def _drain_shards_once(self, worker_id: int) -> int:
        """One pass over the shard mailboxes: claim each free shard in
        turn (offset by worker id so concurrent managers spread out) and
        drain up to MAX_OPS_THREAD messages from it."""
        rt, p = self.rt, self.params
        router = rt.shard_router
        n = len(router.mailboxes)
        total_cnt = 0
        for off in range(n):
            if rt.ready_count() >= p.min_ready_tasks:
                break
            idx = (worker_id + off) % n
            if router.mailboxes[idx].pending() == 0:
                continue                # cheap peek before claiming
            total_cnt += router.drain_shard(idx, p.max_ops_thread)
        return total_cnt

    def drain_all(self) -> int:
        """Drain every queue to empty (used at taskwait/shutdown edges)."""
        rt = self.rt
        if rt.mode == "sharded":
            n = rt.shard_router.drain_all()
            self.messages_processed += n
            return n
        n = 0
        progress = True
        while progress:
            progress = False
            for wq in rt.worker_queues:
                if wq.acquire_submit():
                    try:
                        while True:
                            msg = wq.submit.pop()
                            if msg is None:
                                break
                            rt.satisfy_submit(msg.wd)
                            n += 1
                            progress = True
                    finally:
                        wq.release_submit()
                while True:
                    msg = wq.done.pop()
                    if msg is None:
                        break
                    rt.satisfy_done(msg.wd)
                    n += 1
                    progress = True
        self.messages_processed += n
        return n
