"""DDAST tunables (paper §3.3, Table 5).

The Distributed DAS Thread manager itself — the Listing-2 callback any
idle worker enters to become a *manager thread* — lives in
``core.engine.policy`` as :class:`~repro.core.engine.policy.DdastPolicy`
(with the centralized [7] variant as ``DastPolicy`` and the sharded
extension as ``ShardedPolicy``), so the drain protocol is shared between
the threaded runtime and the virtual-time simulator. This module keeps
the four tunables and the tuned defaults from Table 5:

    MAX_DDAST_THREADS  = ceil(num_threads / 8)      (initial: inf)
    MAX_SPINS          = 1                           (initial: 20)
    MAX_OPS_THREAD     = 8                           (initial: 6)
    MIN_READY_TASKS    = 4                           (initial: 4)

``DDASTManager`` remains importable here as an alias of ``DdastPolicy``
(resolved lazily to avoid a circular import with the engine package).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional


@dataclass
class DDASTParams:
    max_ddast_threads: Optional[int] = None  # None -> ceil(num_threads/8)
    max_spins: int = 1
    max_ops_thread: int = 8
    min_ready_tasks: int = 4
    # Scope-fair drain rotation: max dependence-analysis portions one
    # scope may consume per drain pass (ddast queue sweep / sharded
    # combine session) before the drainer rotates to another tenant's
    # backlog. 0 disables the quantum (pure FIFO drain order).
    drain_quantum: int = 16

    def resolved_max_threads(self, num_threads: int) -> int:
        if self.max_ddast_threads is None:
            return max(1, math.ceil(num_threads / 8))
        return self.max_ddast_threads

    @staticmethod
    def initial() -> "DDASTParams":
        """Pre-tuning values (Table 5, 'Initial Value' column)."""
        return DDASTParams(max_ddast_threads=1 << 30, max_spins=20,
                           max_ops_thread=6, min_ready_tasks=4)


def __getattr__(name: str):
    if name == "DDASTManager":
        from .engine.policy import DdastPolicy
        return DdastPolicy
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
