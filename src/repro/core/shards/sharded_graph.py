"""Region-hash-partitioned dependence graph.

The baseline runtime serializes *every* graph mutation on one global
lock (``sync``) or funnels every message through managers that still
share that lock (``dast``/``ddast``) — the residual serialization point
the paper's related work (Álvarez et al. 2021, Yu et al. 2022) attacks
next. Here the graph is split into N independent ``GraphShard``
partitions. A region belongs to shard ``stable_region_hash(region) % N``
— the bare region name, NOT the parent-qualified key, so shard
assignment is reproducible across runs (parent ``wd_id``s come from a
process-global counter) and identical to the simulator's partitioning.
Within a shard the region *map* is keyed by ``(parent_wd_id, region)``
so sibling namespaces stay separate, exactly like the per-parent graphs
of ``depgraph``. Each shard owns its region map, its successor lists,
and its own ``InstrumentedLock``, so mutations on different shards never
contend.

A task whose deps span k shards is joined by a per-WD pending
``AtomicCounter`` (see ``router.ShardRouter`` for the protocol): the
counter starts at k (a "submit latch": +1 per shard portion not yet
inserted), each shard's insert atomically adds ``local_preds - 1``, and
each satisfied edge subtracts 1. The unique decrement that reaches zero
marks the task ready — no shard ever needs another shard's lock.

Why there is no "is the predecessor still alive?" filtering (the
``state not in (COMPLETED, DELETED)`` check of ``depgraph.submit``): a
predecessor found in a shard's region map cannot have had its Done
processed *at this shard* (Done scrubs the region map under the same
shard lock), therefore the matching decrement for any edge recorded
here is still pending and no wakeup can be lost. If the Done won the
race instead, the region entry is already gone and no stale edge is
created — the same semantics the global-lock graph provides, per shard.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Dict, List, Tuple

from ..depgraph import (_RegionState, collect_preds_and_register,
                        scrub_regions)
from ..queues import InstrumentedLock
from ..wd import WorkDescriptor
from .steal_deque import AtomicCounter, stable_region_hash


def _parent_id(wd: WorkDescriptor) -> int:
    return wd.parent.wd_id if wd.parent is not None else -1


def partition_deps(wd: WorkDescriptor, num_shards: int) -> Dict[int, list]:
    """Partition ``wd.deps`` by owning shard: {shard_index: [(map_key,
    mode), ...]} with map_key = (parent_wd_id, region). Shard choice
    hashes the bare region (reproducible + simulator-identical); the
    map key keeps sibling namespaces separate. Computed once per WD and
    cached on it by the router."""
    pid = _parent_id(wd)
    parts: Dict[int, list] = {}
    for region, mode in wd.deps:
        s = stable_region_hash(region) % num_shards
        parts.setdefault(s, []).append(((pid, region), mode))
    return parts


class GraphShard:
    """One partition: a region map + successor lists under one lock.

    ``submit_local`` / ``complete_local`` must be called with ``lock``
    held (the ``ShardRouter`` guarantees additionally that at most one
    manager drains a shard's mailbox at a time, preserving the paper's
    Submit-exclusivity invariant per shard instead of globally).
    """

    __slots__ = ("index", "num_shards", "lock", "_regions", "_succs",
                 "in_shard", "max_in_shard", "total_submitted",
                 "total_edges", "requests", "delegated", "combined",
                 "handoffs", "scope_portions")

    def __init__(self, index: int, num_shards: int) -> None:
        self.index = index
        self.num_shards = num_shards
        self.lock = InstrumentedLock()
        self._regions: Dict[Tuple[int, Any], _RegionState] = {}
        # pred wd_id -> successors whose edge was recorded at THIS shard;
        # decremented by this shard's processing of the pred's Done.
        self._succs: Dict[int, List[WorkDescriptor]] = {}
        self.in_shard = 0
        self.max_in_shard = 0
        self.total_submitted = 0
        self.total_edges = 0
        # -- delegation/combining (see shards.router) ------------------
        # MPSC publication list: producers append their Submit/Done
        # portion here (deque.append is GIL-atomic) and then *compete*
        # for ``lock`` with a trylock; the winner — the combiner —
        # drains this list and applies every published portion in one
        # combined critical section. The three counters are maintained
        # by the combiner only, under ``lock``, so plain ints are safe:
        #   delegated — portions that traversed the publication list
        #               (structural: identical sim-vs-real),
        #   combined  — combine sessions that applied >= 1 portion,
        #   handoffs  — post-release re-acquisitions (the releasing
        #               holder found late-published requests and took
        #               the lock back rather than strand them).
        self.requests: deque = deque()
        self.delegated = 0
        self.combined = 0
        self.handoffs = 0
        # scope -> portions this shard applied for that tenant (None =
        # the scope-less root context); folded into scope_rollup().
        self.scope_portions: Dict[Any, int] = {}

    # ------------------------------------------------------------------
    def local_deps(self, wd: WorkDescriptor):
        """The subset of ``wd.deps`` this shard owns, as (map-key, mode)
        pairs. The partition is computed ONCE per WD by the router
        (``wd.shard_parts``) so the hot path — which runs under the
        shard lock — never re-hashes regions."""
        parts = wd.shard_parts
        if parts is None:               # direct use without a router
            parts = wd.shard_parts = partition_deps(wd, self.num_shards)
        return parts.get(self.index, ())

    def submit_local(self, wd: WorkDescriptor) -> int:
        """Insert this shard's portion of ``wd``; returns the number of
        local predecessor edges recorded (the exact region rules of
        ``DependenceGraph.submit`` via the shared helper, deduplicated
        within the shard). No liveness filter is applied — see the
        module docstring for why every recorded predecessor is live."""
        preds = collect_preds_and_register(self._regions, wd,
                                           self.local_deps(wd))
        for p in preds:
            self._succs.setdefault(p.wd_id, []).append(wd)
        self.total_edges += len(preds)
        self.total_submitted += 1
        self.in_shard += 1
        self.max_in_shard = max(self.max_in_shard, self.in_shard)
        return len(preds)

    def complete_local(self, wd: WorkDescriptor) -> List[WorkDescriptor]:
        """Scrub this shard's portion of a finished ``wd``; returns the
        successors whose edge at this shard is now satisfied."""
        scrub_regions(self._regions, wd, self.local_deps(wd))
        self.in_shard -= 1
        return self._succs.pop(wd.wd_id, [])


class ShardedDependenceGraph:
    """N independent shard partitions + whole-graph occupancy counters."""

    def __init__(self, num_shards: int) -> None:
        if num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        self.num_shards = num_shards
        self.shards = [GraphShard(i, num_shards) for i in range(num_shards)]
        self._in_graph = AtomicCounter(0)
        self.max_in_graph = 0

    # -- routing -------------------------------------------------------
    def shard_for(self, region: Any) -> int:
        return stable_region_hash(region) % self.num_shards

    def shards_for(self, wd: WorkDescriptor) -> List[int]:
        """Ordered, de-duplicated shard indices touched by ``wd.deps``."""
        return list(partition_deps(wd, self.num_shards))

    # -- whole-graph occupancy (stats parity with DependenceGraph) -----
    def task_entered(self) -> None:
        v = self._in_graph.add(1)
        if v > self.max_in_graph:      # benign race: max may lag briefly
            self.max_in_graph = v

    def task_left(self) -> None:
        self._in_graph.add(-1)

    @property
    def in_graph(self) -> int:
        return self._in_graph.value

    @property
    def total_edges(self) -> int:
        return sum(s.total_edges for s in self.shards)
