"""Sharded dependence-manager subsystem.

Partitions dependence management by region hash so the runtime's hot
path has no global serialization point left:

  * :class:`ShardedDependenceGraph` — N independent shard partitions,
    each with its own lock and region map; cross-shard tasks joined by a
    per-WD pending-predecessor :class:`AtomicCounter`;
  * :class:`ShardRouter` — routes Submit/Done messages to per-shard
    mailboxes so each shard has at most one manager mutating it
    (the paper's Submit-exclusivity invariant, per shard);
  * :class:`StealDeque` — per-worker ready deques with owner-side LIFO
    pop and thief-side FIFO steal, replacing the global ready lock.

Used by ``TaskRuntime(mode="sharded")`` and mirrored in virtual time by
``RuntimeSimulator(mode="sharded")``.
"""
from .router import ShardMailbox, ShardRouter
from .sharded_graph import GraphShard, ShardedDependenceGraph
from .steal_deque import AtomicCounter, StealDeque, stable_region_hash

__all__ = [
    "AtomicCounter", "GraphShard", "ShardMailbox", "ShardRouter",
    "ShardedDependenceGraph", "StealDeque", "stable_region_hash",
]
