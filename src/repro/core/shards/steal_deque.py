"""Lock-free primitives for the sharded dependence manager.

``StealDeque`` is the per-worker ready pool of Distributed Breadth-First
scheduling without the global ready lock the baseline runtime used: the
owner pops LIFO from the hot end (cache-warm, newest task first) while
thieves steal FIFO from the cold end (oldest task first — the classic
Chase-Lev / Cilk discipline). In CPython ``collections.deque`` append /
pop / popleft are each atomic under the GIL, so owner and thief never
corrupt the structure; a concurrent pop+steal race on a single remaining
element resolves to exactly one winner (the loser sees ``IndexError`` and
reports empty). This also fixes the O(n) ``list.pop(0)`` steal of the
previous implementation — ``popleft`` is O(1). The deque is two-lane:
an optional banded priority lane (one GIL-atomic deque per discrete
priority band, highest band drained first) serves the critical-path
replay placement without reintroducing any lock.

``AtomicCounter`` is the per-WD pending-predecessor join counter used by
cross-shard tasks: every shard portion of a Submit adds its local
predecessor count, every satisfied edge subtracts one, and the unique
caller that observes zero marks the task ready. CPython has no lock-free
fetch-add, so a private lock guards the two-instruction update; the
counter is per-task, touched only a handful of times, and therefore never
a contention point (that is the whole idea of the subsystem).

``stable_region_hash`` partitions regions across shards. ``hash()`` is
salted per process for strings, which would make shard assignment — and
with it every per-shard statistic — unreproducible across runs, so we
hash the ``repr`` with crc32 instead: stable, cheap, and good enough
spread for block-index tuples like ``("M", i, j)``.
"""
from __future__ import annotations

import threading
import zlib
from collections import deque
from typing import Any, Generic, Optional, TypeVar

T = TypeVar("T")


def stable_region_hash(key: Any) -> int:
    """Deterministic (cross-process) non-negative hash of a region key.

    crc32 alone is linear: two reprs differing in one digit produce a
    fixed XOR delta that often misses the low bits, so ``% num_shards``
    would lump adjacent block ids onto one shard. The murmur3 fmix32
    finalizer below is nonlinear and spreads any input difference across
    all 32 bits, making small-modulus partitioning uniform."""
    h = zlib.crc32(repr(key).encode("utf-8", "backslashreplace"))
    h ^= h >> 16
    h = (h * 0x85EBCA6B) & 0xFFFFFFFF
    h ^= h >> 13
    h = (h * 0xC2B2AE35) & 0xFFFFFFFF
    h ^= h >> 16
    return h


class AtomicCounter:
    """Lock-guarded integer with a fetch-add that returns the new value."""

    __slots__ = ("_value", "_lock")

    def __init__(self, value: int = 0) -> None:
        self._value = value
        self._lock = threading.Lock()

    def add(self, delta: int) -> int:
        with self._lock:
            self._value += delta
            return self._value

    @property
    def value(self) -> int:
        return self._value

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"AtomicCounter({self._value})"


class StealDeque(Generic[T]):
    """Per-worker TWO-LANE ready deque: a normal lane with owner-side
    LIFO pop / thief-side FIFO steal, plus an optional banded *priority
    lane* consumed before it.

    Push may come from any thread (managers make tasks ready); deque
    append is atomic, so no producer lock is needed either.

    The priority lane (used by the critical-path replay placement) is a
    list of GIL-atomic deques, one per discrete priority band — highest
    band drained first by owner and thieves alike, so the longest
    remaining chain is always started before breadth work. A banded
    array instead of a heap is what keeps the lane lock-free: every
    band operation is a single atomic ``deque`` append/pop, and a
    concurrent pop+steal race on a band's last element resolves to
    exactly one winner just like the normal lane. Within a band the
    owner pops the hot end (LIFO) and thieves the cold end (FIFO) — the
    classic discipline per band. ``set_num_bands`` swaps the band array
    wholesale and must only be called at quiescent points (the replay
    freeze / iteration boundaries, where the deques are empty).

    ``shared_counts`` (optional, installed by ``set_num_bands``) is a
    band-indexed list of occupancy counters SHARED across all deques of
    one placement: every band push increments its entry, every band
    pop/steal decrements it, so a popper can find the best band across
    the whole ring in O(bands) without touching any other deque. The
    updates are plain GIL-interleavable ``+=``/``-=`` — the counters
    are a *hint*, never load-bearing: a stale positive entry costs one
    wasted cross-deque scan, a stale zero merely loses the global-order
    improvement for one pop (the per-deque band scan below still drains
    every band, so no task can be stranded).
    """

    __slots__ = ("_q", "_bands", "_counts", "pushed", "popped", "stolen")

    def __init__(self, num_bands: int = 0) -> None:
        self._q: deque = deque()
        self._bands: list = [deque() for _ in range(num_bands)]
        self._counts: Optional[list] = None
        self.pushed = 0
        self.popped = 0
        self.stolen = 0

    def set_num_bands(self, num_bands: int,
                      shared_counts: Optional[list] = None) -> None:
        """(Re)allocate the priority lane. Quiescent points only: items
        still sitting in the old band array would be orphaned."""
        self._bands = [deque() for _ in range(num_bands)]
        self._counts = shared_counts

    @property
    def num_bands(self) -> int:
        return len(self._bands)

    def push(self, item: T) -> None:
        self._q.append(item)
        self.pushed += 1

    def push_priority(self, item: T, band: int) -> None:
        """Priority lane: ``band`` indexes the band array (higher =
        drained first)."""
        self._bands[band].append(item)
        if self._counts is not None:
            self._counts[band] += 1
        self.pushed += 1

    def best_band(self) -> int:
        """Highest non-empty band of THIS deque (O(bands) emptiness
        scan), -1 when the priority lane is empty."""
        for b in range(len(self._bands) - 1, -1, -1):
            if self._bands[b]:
                return b
        return -1

    def steal_band(self, band: int) -> Optional[T]:
        """Thief-side pop from one specific band (the cross-deque
        global-best-band scan); None when that band is empty here."""
        bands = self._bands
        if not 0 <= band < len(bands) or not bands[band]:
            return None
        try:
            item = bands[band].popleft()
        except IndexError:
            return None
        if self._counts is not None:
            self._counts[band] -= 1
        self.stolen += 1
        return item

    def pop(self) -> Optional[T]:
        """Owner side: highest priority band first, then the normal
        lane's newest task (LIFO — cache-warm end). The emptiness
        pre-checks keep the idle-spin path free of raised exceptions;
        the try/except still arbitrates the last-element pop+steal
        race."""
        for i in range(len(self._bands) - 1, -1, -1):
            b = self._bands[i]
            if not b:
                continue
            try:
                item = b.pop()
            except IndexError:
                continue
            if self._counts is not None:
                self._counts[i] -= 1
            self.popped += 1
            return item
        if not self._q:
            return None
        try:
            item = self._q.pop()
        except IndexError:
            return None
        self.popped += 1
        return item

    def steal(self) -> Optional[T]:
        """Thief side: highest priority band first (critical work is
        globally urgent), then the normal lane's oldest task (FIFO — the
        breadth-first end); FIFO within each band."""
        for i in range(len(self._bands) - 1, -1, -1):
            b = self._bands[i]
            if not b:
                continue
            try:
                item = b.popleft()
            except IndexError:
                continue
            if self._counts is not None:
                self._counts[i] -= 1
            self.stolen += 1
            return item
        if not self._q:
            return None
        try:
            item = self._q.popleft()
        except IndexError:
            return None
        self.stolen += 1
        return item

    @property
    def lane_len(self) -> int:
        """Length of the normal lane alone — O(1), used by the
        shard-affine load cap (priority-lane work is excluded there:
        banded items are drained highest-first by owner and thieves
        alike, so they never pin to the owner the way the LIFO lane
        does)."""
        return len(self._q)

    def __len__(self) -> int:
        n = len(self._q)
        for b in self._bands:
            n += len(b)
        return n
