"""Shard router: per-shard delegation/combining + the cross-shard join
protocol.

Message flow in ``sharded`` mode (compare Fig. 3 of the paper, where the
mailboxes are per *worker*). With delegation (the default), every
Submit/Done portion goes through a flat-combining publication protocol:

    worker creates/finishes task ──publish──▶ ``GraphShard.requests``
        (GIL-atomic MPSC append), then TRYLOCK the shard lock:
          * trylock fails  → return immediately (wait-free): the current
            holder — the **combiner** — applies the published portion
            before or right after releasing;
          * trylock wins   → become the combiner: drain the request list
            and apply every published portion (own + delegated) in one
            combined critical section, in per-scope round-robin quanta.

A combiner that releases re-checks the request list: a producer that
published *during* the release window already failed its trylock and
returned, so the releasing holder takes the lock back rather than
strand the portion. With ``delegation=False`` the pre-existing blocking
transport is used: per-shard mailboxes drained under a claim flag, each
message applied under a blocking ``with shard.lock`` acquisition — the
baseline the contention benchmark compares against.

Either way, exactly one thread mutates a given shard at a time, and
portions published by one producer are applied in publication order
(deque FIFO + in-order combine), so per-(parent, region) submission
order — the §3.1 invariant the dependence rules require — is preserved
per shard, while different shards proceed fully in parallel. Portions
of *different* scopes may be interleaved by the fairness rotation;
that is sound because scoped dependence namespaces never share a
(parent, region) key.

Join protocol for a task whose deps span k shards:

  * ``prepare_submit`` sets ``wd.shard_pending = k`` (the submit latch)
    and ``wd.shard_done = k`` (the completion latch); ``route_submit``
    then posts one SubmitTaskMessage per shard (or the ShardedPolicy
    buffers the WD and later posts one ``SubmitBatchMessage`` per shard
    per batch). k == 0 (no deps) short-circuits to ready.
  * each shard's Submit processing atomically adds
    ``local_pred_edges - 1``; the unique update that reaches 0 marks the
    task ready (all shards inserted, no unsatisfied edge).
  * each shard's Done processing subtracts 1 per satisfied edge of each
    local successor, and subtracts 1 from the finished task's
    ``shard_done``; the unique update reaching 0 completes the WD
    (parent bookkeeping, graph occupancy).

A predecessor recorded via two regions on two different shards yields
two edges and, symmetrically, two decrements — counts balance, so the
deduplication the single graph performs globally is only needed (and
done) within each shard.

Every graph action is priced through the router's
:class:`~repro.core.engine.charge.CostCharger` — a no-op under real
threads, a virtual-time clock under the simulator — so both drivers
share this exact code path.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Callable, List, Optional, Union

from ..messages import (DoneBatchMessage, DoneTaskMessage,
                        SubmitBatchMessage, SubmitTaskMessage)
from ..trace import (EV_COMBINE, EV_DELEGATE, EV_DEPS, EV_MSG_DRAIN,
                     EV_MSG_ENQ, NULL_TRACER)
from ..wd import TaskState, WorkDescriptor
from .sharded_graph import ShardedDependenceGraph, partition_deps
from .steal_deque import AtomicCounter

_Message = Union[SubmitTaskMessage, SubmitBatchMessage, DoneTaskMessage,
                 DoneBatchMessage]


class ShardMailbox:
    """MPSC FIFO message queue of one shard: every worker thread pushes
    (CPython deque.append is atomic under the GIL), one draining manager
    at a time pops (claim flag). Deliberately NOT an SPSCQueue — that
    class's contract and counters assume a single producer."""

    __slots__ = ("index", "_q", "_drain_flag", "messages_processed")

    def __init__(self, index: int) -> None:
        self.index = index
        self._q: deque = deque()
        self._drain_flag = threading.Lock()
        # only the claiming manager mutates this, so a plain int is safe
        self.messages_processed = 0

    def push(self, msg: "_Message") -> None:
        self._q.append(msg)

    def pop(self) -> Optional["_Message"]:
        try:
            return self._q.popleft()
        except IndexError:
            return None

    def try_claim(self) -> bool:
        return self._drain_flag.acquire(blocking=False)

    def release(self) -> None:
        self._drain_flag.release()

    def pending(self) -> int:
        return len(self._q)


class ShardRouter:
    """Routes Submit/Done to shard mailboxes and applies the join
    protocol when managers process them."""

    def __init__(self, graph: ShardedDependenceGraph,
                 on_ready: Callable[[WorkDescriptor], None],
                 charge=None, tracer=None, delegation: bool = True,
                 drain_quantum: int = 16) -> None:
        from ..engine.charge import CostCharger
        self.graph = graph
        self.on_ready = on_ready
        self.charge = charge if charge is not None else CostCharger()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        #: delegation/combining transport (module docstring); False =
        #: the blocking mailbox baseline.
        self.delegation = delegation
        #: max portions one scope's bucket contributes per rotation pass
        #: of a combine session (DDASTParams.drain_quantum upstream);
        #: 0 disables the quantum — pure FIFO drain order, matching the
        #: ddast queue sweep's reading of the same knob.
        self.drain_quantum = max(0, drain_quantum)
        self.mailboxes: List[ShardMailbox] = [
            ShardMailbox(i) for i in range(graph.num_shards)]

    # -- producer side (any worker thread) -----------------------------
    def prepare_submit(self, wd: WorkDescriptor) -> bool:
        """Partition the deps once (shards read ``wd.shard_parts`` on the
        hot path instead of re-hashing regions under their lock),
        initialize both join latches, and record graph occupancy. Both
        latches MUST be set before the first message is visible to a
        manager. Returns True for a dependence-free task, which is made
        ready immediately and needs no Submit messages."""
        parts = partition_deps(wd, self.graph.num_shards)
        wd.shard_parts = parts
        k = len(parts)
        wd.shard_pending = AtomicCounter(k)
        wd.shard_done = AtomicCounter(k)
        wd.state = TaskState.SUBMITTED
        self.graph.task_entered()
        if k == 0:                       # dependence-free: ready now
            wd.mark_ready()
            self.on_ready(wd)
            return True
        return False

    def _publish(self, s: int, msg: "_Message", kind: str, n: int) -> None:
        """Transport one message to shard ``s``. Delegation: append to
        the shard's MPSC publication list (GIL-atomic), then compete for
        the combiner role — losing the trylock is the wait-free return.
        Blocking baseline: the claim-flagged mailbox."""
        tr = self.tracer
        if not self.delegation:
            self.mailboxes[s].push(msg)
            if tr.enabled:
                tr.mgr_event(EV_MSG_ENQ, -1, data=(kind, s, n))
            return
        self.graph.shards[s].requests.append(msg)
        self.charge.delegate()
        if tr.enabled:
            tr.mgr_event(EV_DELEGATE, -1, data=(kind, s, n))
        self._try_combine(s)

    def route_submit(self, wd: WorkDescriptor) -> None:
        if self.prepare_submit(wd):
            return
        msg = SubmitTaskMessage(wd)
        for s in wd.shard_parts:
            self._publish(s, msg, "submit", 1)

    def push_batch(self, wds: List[WorkDescriptor]) -> None:
        """Ship already-prepared WDs (see ``prepare_submit``) as one
        SubmitBatchMessage per shard touched by the batch, preserving the
        producer's creation order within each entry."""
        per_shard = {}
        for wd in wds:
            for s in wd.shard_parts:
                per_shard.setdefault(s, []).append(wd)
        for s, group in per_shard.items():
            self._publish(s, SubmitBatchMessage(group), "submit_batch",
                          len(group))

    def route_done(self, wd: WorkDescriptor) -> None:
        parts = wd.shard_parts            # cached by prepare_submit
        if not parts:                     # never entered any shard
            self.graph.task_left()
            wd.mark_completed()
            return
        msg = DoneTaskMessage(wd)
        for s in parts:
            self._publish(s, msg, "done", 1)

    def push_done_batch(self, wds: List[WorkDescriptor]) -> None:
        """Ship finished WDs (each with at least one shard portion) as
        one DoneBatchMessage per shard touched by the batch — the Done
        analogue of ``push_batch``."""
        per_shard = {}
        for wd in wds:
            for s in wd.shard_parts:
                per_shard.setdefault(s, []).append(wd)
        for s, group in per_shard.items():
            self._publish(s, DoneBatchMessage(group), "done_batch",
                          len(group))

    # -- consumer side (the claiming manager) --------------------------
    def _submit_local(self, shard, wd: WorkDescriptor) -> bool:
        """Insert one shard portion; returns True if the join latch hit
        zero (caller marks ready). Must hold ``shard.lock``."""
        local_preds = shard.submit_local(wd)
        # +local edges, -1 for this shard's latch unit
        return wd.shard_pending.add(local_preds - 1) == 0

    def process(self, shard_index: int, msg: _Message) -> None:
        """Apply one mailbox entry to one shard. Caller must hold the
        shard's mailbox claim (single manager per shard)."""
        shard = self.graph.shards[shard_index]
        self.charge.message()
        tr = self.tracer
        if tr.enabled:
            n = len(msg.wds) if type(msg) in (SubmitBatchMessage,
                                              DoneBatchMessage) else 1
            kind = ("submit" if type(msg) in (SubmitTaskMessage,
                                              SubmitBatchMessage)
                    else "done")
            tr.mgr_event(EV_MSG_DRAIN, -1, data=(kind, shard_index, n))
        if type(msg) is SubmitBatchMessage:
            self.charge.submit_batch_cs(
                ("shard", shard_index),
                [(len(wd.shard_parts[shard_index]), len(wd.shard_parts))
                 for wd in msg.wds])
            newly = []
            with shard.lock:
                for wd in msg.wds:
                    if self._submit_local(shard, wd):
                        newly.append(wd)
            if tr.enabled:
                # one deps_resolved per shard portion; consumers use
                # the LAST one per task (the latch-zero portion)
                for wd in msg.wds:
                    tr.task_event(EV_DEPS, wd, -1, data=shard_index)
            for wd in newly:
                wd.mark_ready()
                self.on_ready(wd)
        elif type(msg) is SubmitTaskMessage:
            wd = msg.wd
            self.charge.submit_portion_cs(
                ("shard", shard_index),
                len(wd.shard_parts[shard_index]), len(wd.shard_parts))
            with shard.lock:
                ready = self._submit_local(shard, wd)
            if tr.enabled:
                tr.task_event(EV_DEPS, wd, -1, data=shard_index)
            if ready:
                wd.mark_ready()
                self.on_ready(wd)
        elif type(msg) is DoneBatchMessage:
            self.charge.done_batch_cs(
                ("shard", shard_index),
                [(len(wd.shard_parts[shard_index]), len(wd.shard_parts))
                 for wd in msg.wds])
            all_succs = []
            with shard.lock:
                for wd in msg.wds:
                    all_succs.append(shard.complete_local(wd))
            for wd, succs in zip(msg.wds, all_succs):
                self._finish_done(wd, succs)
        else:
            wd = msg.wd
            self.charge.done_portion_cs(
                ("shard", shard_index),
                len(wd.shard_parts[shard_index]), len(wd.shard_parts))
            with shard.lock:
                succs = shard.complete_local(wd)
            self._finish_done(wd, succs)
        self.mailboxes[shard_index].messages_processed += 1

    # -- delegation/combining (consumer side) --------------------------
    @staticmethod
    def _split_scopes(msg: "_Message"):
        """Split one published message into ``(scope, message)`` pieces,
        each single-scope, preserving intra-message order. Single-task
        messages and single-scope batches (the common case: per-slot
        batch buffers usually fill within one tenant's burst) pass
        through untouched. A mixed-scope batch becomes one sub-batch per
        same-scope *run*, so every portion lands in its own scope's
        fairness bucket: bucketing a whole mixed batch under one scope
        would let the rotation apply its other-scope tail ahead of that
        scope's earlier, still-bucketed messages — reordering same-scope
        same-(parent, region) Submits and breaking the §3.1 invariant."""
        t = type(msg)
        if t in (SubmitBatchMessage, DoneBatchMessage):
            wds = msg.wds
            first = wds[0].scope
            if all(wd.scope == first for wd in wds):
                return ((first, msg),)
            out = []
            run = [wds[0]]
            cur = first
            for wd in wds[1:]:
                if wd.scope == cur:
                    run.append(wd)
                else:
                    out.append((cur, t(run)))
                    run = [wd]
                    cur = wd.scope
            out.append((cur, t(run)))
            return out
        return ((msg.wd.scope, msg),)

    def _try_combine(self, shard_index: int) -> int:
        """Compete for the combiner role on one shard. The caller's
        portion (if any) is already published, so losing the trylock IS
        the wait-free path: the current holder applies it. Returns
        portions applied by THIS thread."""
        shard = self.graph.shards[shard_index]
        applied = 0
        first = True
        while shard.requests:
            if not shard.lock.try_acquire():
                # someone else holds the shard: they re-check the
                # request list before abandoning the lock (below), so
                # every published portion is applied by somebody
                return applied
            if not first:
                shard.handoffs += 1
            try:
                applied += self._combine_locked(shard_index, shard)
            finally:
                shard.lock.release()
            first = False
            # post-release re-check: a producer that published after our
            # final drain already failed its trylock and returned — loop
            # and take the lock back rather than strand its portion.
        return applied

    def _combine_locked(self, shard_index: int, shard) -> int:
        """One combine session (``shard.lock`` held): stage every
        published request into per-scope buckets (mixed-scope batches
        split into single-scope runs first, see ``_split_scopes``), then
        apply them in round-robin quanta of ``drain_quantum`` portions
        per scope per pass — one tenant's flood cannot monopolize this
        shard's dependence analysis. Within a scope, publication (FIFO)
        order is preserved, which is what carries the §3.1 per-producer
        ordering invariant through the combiner. ``drain_quantum == 0``
        disables the rotation entirely: requests are applied in pure
        publication-FIFO order."""
        reqs = shard.requests
        if not reqs:
            return 0
        self.charge.combine()
        applied = 0
        quantum = self.drain_quantum
        share = shard.scope_portions
        if quantum == 0:
            # quantum disabled: pure FIFO drain, no staging pass
            while True:
                try:
                    msg = reqs.popleft()
                except IndexError:  # producers only append; safe bound
                    break
                for sc, piece in self._split_scopes(msg):
                    n = self._apply(shard_index, shard, piece)
                    applied += n
                    share[sc] = share.get(sc, 0) + n
        else:
            buckets: dict = {}
            order: list = []
            while True:
                try:
                    msg = reqs.popleft()
                except IndexError:  # producers only append; safe bound
                    break
                for sc, piece in self._split_scopes(msg):
                    b = buckets.get(sc)
                    if b is None:
                        b = buckets[sc] = deque()
                        order.append(sc)
                    b.append(piece)
            while order:
                for sc in list(order):
                    b = buckets[sc]
                    used = 0
                    while b and used < quantum:
                        n = self._apply(shard_index, shard, b.popleft())
                        used += n
                    if used:
                        applied += used
                        share[sc] = share.get(sc, 0) + used
                    if not b:
                        del buckets[sc]
                        order.remove(sc)
        if applied:
            shard.delegated += applied
            shard.combined += 1
            tr = self.tracer
            if tr.enabled:
                tr.mgr_event(EV_COMBINE, -1,
                             data=("combine", shard_index, applied))
        return applied

    def _apply(self, shard_index: int, shard, msg: "_Message") -> int:
        """Apply one published message under the combiner's already-held
        shard lock; returns the number of shard portions it carried.
        Mirrors :meth:`process` minus the per-message lock acquisition —
        that is the whole point of combining."""
        self.charge.message()
        tr = self.tracer
        if type(msg) is SubmitBatchMessage:
            n = len(msg.wds)
            if tr.enabled:
                tr.mgr_event(EV_MSG_DRAIN, -1,
                             data=("submit", shard_index, n))
            self.charge.submit_batch_cs(
                ("shard", shard_index),
                [(len(wd.shard_parts[shard_index]), len(wd.shard_parts))
                 for wd in msg.wds])
            newly = []
            for wd in msg.wds:
                if self._submit_local(shard, wd):
                    newly.append(wd)
            if tr.enabled:
                for wd in msg.wds:
                    tr.task_event(EV_DEPS, wd, -1, data=shard_index)
            for wd in newly:
                wd.mark_ready()
                self.on_ready(wd)
        elif type(msg) is SubmitTaskMessage:
            n = 1
            wd = msg.wd
            if tr.enabled:
                tr.mgr_event(EV_MSG_DRAIN, -1,
                             data=("submit", shard_index, 1))
            self.charge.submit_portion_cs(
                ("shard", shard_index),
                len(wd.shard_parts[shard_index]), len(wd.shard_parts))
            ready = self._submit_local(shard, wd)
            if tr.enabled:
                tr.task_event(EV_DEPS, wd, -1, data=shard_index)
            if ready:
                wd.mark_ready()
                self.on_ready(wd)
        elif type(msg) is DoneBatchMessage:
            n = len(msg.wds)
            if tr.enabled:
                tr.mgr_event(EV_MSG_DRAIN, -1,
                             data=("done", shard_index, n))
            self.charge.done_batch_cs(
                ("shard", shard_index),
                [(len(wd.shard_parts[shard_index]), len(wd.shard_parts))
                 for wd in msg.wds])
            for wd in msg.wds:
                succs = shard.complete_local(wd)
                self._finish_done(wd, succs)
        else:
            n = 1
            wd = msg.wd
            if tr.enabled:
                tr.mgr_event(EV_MSG_DRAIN, -1,
                             data=("done", shard_index, 1))
            self.charge.done_portion_cs(
                ("shard", shard_index),
                len(wd.shard_parts[shard_index]), len(wd.shard_parts))
            succs = shard.complete_local(wd)
            self._finish_done(wd, succs)
        self.mailboxes[shard_index].messages_processed += 1
        return n

    def _finish_done(self, wd: WorkDescriptor,
                     succs: List[WorkDescriptor]) -> None:
        """Latch arithmetic after one shard scrubbed its Done portion of
        ``wd``: satisfy local successor edges, then retire the portion."""
        for s in succs:
            if s.shard_pending.add(-1) == 0:
                s.mark_ready()
                self.on_ready(s)
        if wd.shard_done.add(-1) == 0:
            self.graph.task_left()
            wd.mark_completed()

    def drain_shard(self, shard_index: int, max_ops: int) -> int:
        """Idle-manager drain of one shard. Delegation: become the
        combiner if the lock is free (a combine session applies every
        published portion — ``max_ops`` does not bound it; bounding
        would just strand requests for the next pass). Blocking: claim
        the mailbox and process up to ``max_ops`` entries. Returns 0 if
        another thread already owns the shard."""
        if self.delegation:
            return self._try_combine(shard_index)
        mb = self.mailboxes[shard_index]
        if not mb.try_claim():
            return 0
        cnt = 0
        try:
            while cnt < max_ops:
                msg = mb.pop()
                if msg is None:
                    break
                self.process(shard_index, msg)
                cnt += 1
        finally:
            mb.release()
        return cnt

    def drain_all(self) -> int:
        """Drain every shard to empty (taskwait/shutdown edges). Like
        the blocking variant, loops only while THIS thread progresses:
        requests held by a concurrent combiner are its to apply, and the
        caller's quiescence loop re-polls ``pending()``."""
        if self.delegation:
            n = 0
            progress = True
            while progress:
                progress = False
                for i, shard in enumerate(self.graph.shards):
                    if shard.requests:
                        c = self._try_combine(i)
                        if c:
                            n += c
                            progress = True
            return n
        n = 0
        progress = True
        while progress:
            progress = False
            for mb in self.mailboxes:
                if not mb.try_claim():
                    continue
                try:
                    while True:
                        msg = mb.pop()
                        if msg is None:
                            break
                        self.process(mb.index, msg)
                        n += 1
                        progress = True
                finally:
                    mb.release()
        return n

    def pending(self) -> int:
        return (sum(mb.pending() for mb in self.mailboxes)
                + sum(len(s.requests) for s in self.graph.shards))

    @property
    def messages_processed(self) -> int:
        return sum(mb.messages_processed for mb in self.mailboxes)

    # -- delegation counters (combiner-maintained, see GraphShard) -----
    @property
    def delegated_portions(self) -> int:
        return sum(s.delegated for s in self.graph.shards)

    @property
    def combined_drains(self) -> int:
        return sum(s.combined for s in self.graph.shards)

    @property
    def lock_handoffs(self) -> List[int]:
        return [s.handoffs for s in self.graph.shards]

    def scope_portions(self) -> dict:
        """scope -> portions applied for that tenant, summed over
        shards (None = the scope-less root context)."""
        out: dict = {}
        for s in self.graph.shards:
            for sc, n in s.scope_portions.items():
                out[sc] = out.get(sc, 0) + n
        return out
