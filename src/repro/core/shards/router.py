"""Shard router: per-shard mailboxes + the cross-shard join protocol.

Message flow in ``sharded`` mode (compare Fig. 3 of the paper, where the
mailboxes are per *worker*):

    worker creates task ──route_submit──▶ mailbox of every shard its
                                          regions hash to (FIFO, MPSC)
    worker finishes task ─route_done────▶ same mailboxes
    idle worker (manager) ──claims a shard──▶ drains its mailbox,
                                          mutating ONLY that shard

Exactly one manager drains a given mailbox at a time (``try_claim``, the
per-shard analogue of the per-worker Submit-queue exclusivity flag of
Listing 2 line 8). Because a region maps to exactly one shard and a
parent's children are created by the single thread executing the parent,
FIFO mailbox order preserves per-region submission order — the §3.1
invariant the dependence rules require — while different shards proceed
fully in parallel.

Join protocol for a task whose deps span k shards:

  * ``prepare_submit`` sets ``wd.shard_pending = k`` (the submit latch)
    and ``wd.shard_done = k`` (the completion latch); ``route_submit``
    then posts one SubmitTaskMessage per shard (or the ShardedPolicy
    buffers the WD and later posts one ``SubmitBatchMessage`` per shard
    per batch). k == 0 (no deps) short-circuits to ready.
  * each shard's Submit processing atomically adds
    ``local_pred_edges - 1``; the unique update that reaches 0 marks the
    task ready (all shards inserted, no unsatisfied edge).
  * each shard's Done processing subtracts 1 per satisfied edge of each
    local successor, and subtracts 1 from the finished task's
    ``shard_done``; the unique update reaching 0 completes the WD
    (parent bookkeeping, graph occupancy).

A predecessor recorded via two regions on two different shards yields
two edges and, symmetrically, two decrements — counts balance, so the
deduplication the single graph performs globally is only needed (and
done) within each shard.

Every graph action is priced through the router's
:class:`~repro.core.engine.charge.CostCharger` — a no-op under real
threads, a virtual-time clock under the simulator — so both drivers
share this exact code path.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Callable, List, Optional, Union

from ..messages import (DoneBatchMessage, DoneTaskMessage,
                        SubmitBatchMessage, SubmitTaskMessage)
from ..trace import EV_DEPS, EV_MSG_DRAIN, EV_MSG_ENQ, NULL_TRACER
from ..wd import TaskState, WorkDescriptor
from .sharded_graph import ShardedDependenceGraph, partition_deps
from .steal_deque import AtomicCounter

_Message = Union[SubmitTaskMessage, SubmitBatchMessage, DoneTaskMessage,
                 DoneBatchMessage]


class ShardMailbox:
    """MPSC FIFO message queue of one shard: every worker thread pushes
    (CPython deque.append is atomic under the GIL), one draining manager
    at a time pops (claim flag). Deliberately NOT an SPSCQueue — that
    class's contract and counters assume a single producer."""

    __slots__ = ("index", "_q", "_drain_flag", "messages_processed")

    def __init__(self, index: int) -> None:
        self.index = index
        self._q: deque = deque()
        self._drain_flag = threading.Lock()
        # only the claiming manager mutates this, so a plain int is safe
        self.messages_processed = 0

    def push(self, msg: "_Message") -> None:
        self._q.append(msg)

    def pop(self) -> Optional["_Message"]:
        try:
            return self._q.popleft()
        except IndexError:
            return None

    def try_claim(self) -> bool:
        return self._drain_flag.acquire(blocking=False)

    def release(self) -> None:
        self._drain_flag.release()

    def pending(self) -> int:
        return len(self._q)


class ShardRouter:
    """Routes Submit/Done to shard mailboxes and applies the join
    protocol when managers process them."""

    def __init__(self, graph: ShardedDependenceGraph,
                 on_ready: Callable[[WorkDescriptor], None],
                 charge=None, tracer=None) -> None:
        from ..engine.charge import CostCharger
        self.graph = graph
        self.on_ready = on_ready
        self.charge = charge if charge is not None else CostCharger()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.mailboxes: List[ShardMailbox] = [
            ShardMailbox(i) for i in range(graph.num_shards)]

    # -- producer side (any worker thread) -----------------------------
    def prepare_submit(self, wd: WorkDescriptor) -> bool:
        """Partition the deps once (shards read ``wd.shard_parts`` on the
        hot path instead of re-hashing regions under their lock),
        initialize both join latches, and record graph occupancy. Both
        latches MUST be set before the first message is visible to a
        manager. Returns True for a dependence-free task, which is made
        ready immediately and needs no Submit messages."""
        parts = partition_deps(wd, self.graph.num_shards)
        wd.shard_parts = parts
        k = len(parts)
        wd.shard_pending = AtomicCounter(k)
        wd.shard_done = AtomicCounter(k)
        wd.state = TaskState.SUBMITTED
        self.graph.task_entered()
        if k == 0:                       # dependence-free: ready now
            wd.mark_ready()
            self.on_ready(wd)
            return True
        return False

    def route_submit(self, wd: WorkDescriptor) -> None:
        if self.prepare_submit(wd):
            return
        msg = SubmitTaskMessage(wd)
        tr = self.tracer
        for s in wd.shard_parts:
            self.mailboxes[s].push(msg)
            if tr.enabled:
                tr.task_event(EV_MSG_ENQ, wd, -1, data=("submit", s, 1))

    def push_batch(self, wds: List[WorkDescriptor]) -> None:
        """Ship already-prepared WDs (see ``prepare_submit``) as one
        SubmitBatchMessage per shard touched by the batch, preserving the
        producer's creation order within each entry."""
        per_shard = {}
        for wd in wds:
            for s in wd.shard_parts:
                per_shard.setdefault(s, []).append(wd)
        tr = self.tracer
        for s, group in per_shard.items():
            self.mailboxes[s].push(SubmitBatchMessage(group))
            if tr.enabled:
                tr.mgr_event(EV_MSG_ENQ, -1,
                             data=("submit_batch", s, len(group)))

    def route_done(self, wd: WorkDescriptor) -> None:
        parts = wd.shard_parts            # cached by prepare_submit
        if not parts:                     # never entered any shard
            self.graph.task_left()
            wd.mark_completed()
            return
        msg = DoneTaskMessage(wd)
        tr = self.tracer
        for s in parts:
            self.mailboxes[s].push(msg)
            if tr.enabled:
                tr.task_event(EV_MSG_ENQ, wd, -1, data=("done", s, 1))

    def push_done_batch(self, wds: List[WorkDescriptor]) -> None:
        """Ship finished WDs (each with at least one shard portion) as
        one DoneBatchMessage per shard touched by the batch — the Done
        analogue of ``push_batch``."""
        per_shard = {}
        for wd in wds:
            for s in wd.shard_parts:
                per_shard.setdefault(s, []).append(wd)
        tr = self.tracer
        for s, group in per_shard.items():
            self.mailboxes[s].push(DoneBatchMessage(group))
            if tr.enabled:
                tr.mgr_event(EV_MSG_ENQ, -1,
                             data=("done_batch", s, len(group)))

    # -- consumer side (the claiming manager) --------------------------
    def _submit_local(self, shard, wd: WorkDescriptor) -> bool:
        """Insert one shard portion; returns True if the join latch hit
        zero (caller marks ready). Must hold ``shard.lock``."""
        local_preds = shard.submit_local(wd)
        # +local edges, -1 for this shard's latch unit
        return wd.shard_pending.add(local_preds - 1) == 0

    def process(self, shard_index: int, msg: _Message) -> None:
        """Apply one mailbox entry to one shard. Caller must hold the
        shard's mailbox claim (single manager per shard)."""
        shard = self.graph.shards[shard_index]
        self.charge.message()
        tr = self.tracer
        if tr.enabled:
            n = len(msg.wds) if type(msg) in (SubmitBatchMessage,
                                              DoneBatchMessage) else 1
            kind = ("submit" if type(msg) in (SubmitTaskMessage,
                                              SubmitBatchMessage)
                    else "done")
            tr.mgr_event(EV_MSG_DRAIN, -1, data=(kind, shard_index, n))
        if type(msg) is SubmitBatchMessage:
            self.charge.submit_batch_cs(
                ("shard", shard_index),
                [(len(wd.shard_parts[shard_index]), len(wd.shard_parts))
                 for wd in msg.wds])
            newly = []
            with shard.lock:
                for wd in msg.wds:
                    if self._submit_local(shard, wd):
                        newly.append(wd)
            if tr.enabled:
                # one deps_resolved per shard portion; consumers use
                # the LAST one per task (the latch-zero portion)
                for wd in msg.wds:
                    tr.task_event(EV_DEPS, wd, -1, data=shard_index)
            for wd in newly:
                wd.mark_ready()
                self.on_ready(wd)
        elif type(msg) is SubmitTaskMessage:
            wd = msg.wd
            self.charge.submit_portion_cs(
                ("shard", shard_index),
                len(wd.shard_parts[shard_index]), len(wd.shard_parts))
            with shard.lock:
                ready = self._submit_local(shard, wd)
            if tr.enabled:
                tr.task_event(EV_DEPS, wd, -1, data=shard_index)
            if ready:
                wd.mark_ready()
                self.on_ready(wd)
        elif type(msg) is DoneBatchMessage:
            self.charge.done_batch_cs(
                ("shard", shard_index),
                [(len(wd.shard_parts[shard_index]), len(wd.shard_parts))
                 for wd in msg.wds])
            all_succs = []
            with shard.lock:
                for wd in msg.wds:
                    all_succs.append(shard.complete_local(wd))
            for wd, succs in zip(msg.wds, all_succs):
                self._finish_done(wd, succs)
        else:
            wd = msg.wd
            self.charge.done_portion_cs(
                ("shard", shard_index),
                len(wd.shard_parts[shard_index]), len(wd.shard_parts))
            with shard.lock:
                succs = shard.complete_local(wd)
            self._finish_done(wd, succs)
        self.mailboxes[shard_index].messages_processed += 1

    def _finish_done(self, wd: WorkDescriptor,
                     succs: List[WorkDescriptor]) -> None:
        """Latch arithmetic after one shard scrubbed its Done portion of
        ``wd``: satisfy local successor edges, then retire the portion."""
        for s in succs:
            if s.shard_pending.add(-1) == 0:
                s.mark_ready()
                self.on_ready(s)
        if wd.shard_done.add(-1) == 0:
            self.graph.task_left()
            wd.mark_completed()

    def drain_shard(self, shard_index: int, max_ops: int) -> int:
        """Claim one shard and process up to ``max_ops`` mailbox entries.
        Returns entries processed (0 if the shard was already claimed)."""
        mb = self.mailboxes[shard_index]
        if not mb.try_claim():
            return 0
        cnt = 0
        try:
            while cnt < max_ops:
                msg = mb.pop()
                if msg is None:
                    break
                self.process(shard_index, msg)
                cnt += 1
        finally:
            mb.release()
        return cnt

    def drain_all(self) -> int:
        """Drain every shard mailbox to empty (taskwait/shutdown edges)."""
        n = 0
        progress = True
        while progress:
            progress = False
            for mb in self.mailboxes:
                if not mb.try_claim():
                    continue
                try:
                    while True:
                        msg = mb.pop()
                        if msg is None:
                            break
                        self.process(mb.index, msg)
                        n += 1
                        progress = True
                finally:
                    mb.release()
        return n

    def pending(self) -> int:
        return sum(mb.pending() for mb in self.mailboxes)

    @property
    def messages_processed(self) -> int:
        return sum(mb.messages_processed for mb in self.mailboxes)
