"""Detrimental-pattern detectors over merged event timelines.

The three pathologies of "Detrimental task execution patterns in
mainstream OpenMP runtimes" (PAPERS.md, 2406.03077), phrased against
this runtime's structures:

  * **ready-queue starvation** — a worker sits idle while ready work
    exists: another slot's deque is deep (placement imbalance the
    steal path isn't covering), or the manager queues hold a backlog
    nobody is draining (every thread is busy or the admission gate is
    too tight).
  * **priority inversion** — under the critical-path replay placement
    a low-band task *started* while a strictly higher-band task had
    been ready (globally available) since earlier.  Cross-checked
    against the bands ``CriticalPathPlacement`` publishes: ``ready``
    events carry ``("band", b)`` payloads, so the detector only speaks
    where band data exists.
  * **affinity miss** — a task the shard-affine placement deliberately
    pinned (``ready`` payload ``"affine"``) executed on a different
    slot, correlated with a ``steal`` event for the same task (a miss
    without a steal is a benign re-pop; a steal of an affine task
    means locality was traded for load balance).

Replay awareness (the false-positive fix the replay subsystem needs):
replayed iterations skip dependence analysis and manager messages *by
design*, so windows whose closing ``quiesce`` boundary shows
``replay_iterations`` advanced are treated as manager-silent — the
backlog-based starvation signal is suppressed there; depth-based
signals (which read only ``ready``/``start`` events, present under
replay too) remain active.

All detectors return :class:`Finding` records and are pure functions of
the event list — fabricated timelines make positive oracles, clean
sim runs make negative ones.

One timeline quirk the sweeps must absorb: the simulator's documented
causality approximation (state produced by a core running locally ahead
becomes visible to other cores at their *next* event) can stamp a
task's ``start`` with an earlier virtual time than its ``ready``.
Each detector therefore pairs ready/start by ``wd_id`` in whichever
order they arrive, never assuming ready sorts first.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .recorder import (EV_COMBINE, EV_DELEGATE, EV_END, EV_MSG_DRAIN,
                       EV_MSG_ENQ, EV_QUIESCE, EV_READY, EV_START,
                       EV_STEAL, TraceEvent)

STARVATION = "ready_queue_starvation"
INVERSION = "priority_inversion"
AFFINITY_MISS = "affinity_miss"


@dataclass
class Finding:
    kind: str
    t0: float
    t1: float
    slot: int = -1                # the slot the finding points at
    count: int = 0                # occurrences / tasks involved
    detail: dict = field(default_factory=dict)


# ---------------------------------------------------------------------
# replay-window bookkeeping
def replay_windows(events: Sequence[TraceEvent]
                   ) -> List[Tuple[float, float]]:
    """Time intervals served by record-and-replay: for each scope, the
    span between consecutive ``quiesce`` boundaries whose
    ``replay_iterations`` payload advanced. Manager events are absent
    there by design, so backlog-based signals must stay silent."""
    wins: List[Tuple[float, float]] = []
    last: Dict[Optional[int], Tuple[float, int]] = {}
    for e in events:
        if e.ev != EV_QUIESCE:
            continue
        data = e.data or {}
        scope = data.get("scope") if isinstance(data, dict) else None
        iters = data.get("replay_iterations", 0) \
            if isinstance(data, dict) else 0
        t_prev, iters_prev = last.get(scope, (0.0, 0))
        if iters > iters_prev:
            wins.append((t_prev, e.t))
        last[scope] = (e.t, iters)
    wins.sort()
    return wins


def _in_windows(t: float, wins: Sequence[Tuple[float, float]]) -> bool:
    return any(a <= t <= b for a, b in wins)


def _msg_count(data) -> int:
    """Message events carry ``(kind, where, n)`` payloads; ``n`` is the
    task count the entry covers (batches > 1)."""
    if isinstance(data, (tuple, list)) and len(data) >= 3:
        return int(data[2])
    return 1


# ---------------------------------------------------------------------
def detect_starvation(events: Sequence[TraceEvent],
                      min_dur: Optional[float] = None,
                      depth_min: int = 4,
                      backlog_min: int = 8) -> List[Finding]:
    """Sweep the timeline tracking (a) per-slot ready-deque depth from
    ``ready``/``start`` events, (b) worker busy/idle state from
    ``start``/``end``, (c) manager backlog from enq/drain counts. Flag
    sustained spans where a known worker is idle while either another
    slot's deque holds ``depth_min``+ tasks (the steal path is not
    covering the imbalance) or the managers sit on ``backlog_min``+
    undrained tasks with nothing ready anywhere. Spans shorter than
    ``min_dur`` (default 2 % of the traced span) are noise — a ready
    burst always precedes the pops that serve it. A ``msg_drained``
    event is *progress*, so it closes any backlog-only span: deep
    mailboxes behind an actively draining manager are ordinary
    pipelining, and only a drain gap longer than ``min_dur`` with idle
    workers waiting on it counts as starvation."""
    if not events:
        return []
    t_lo, t_hi = events[0].t, events[-1].t
    if min_dur is None:
        min_dur = 0.02 * max(t_hi - t_lo, 1e-12)
    wins = replay_windows(events)

    #: per-slot deque depth; banded ready events (critical-path replay
    #: lane, payload ``("band", b)``) go to the SHARED key instead — the
    #: priority lane is one pool every worker pops, so its depth is not
    #: placement imbalance and a start from it is progress (closes a
    #: backlog-style span), exactly like a manager drain
    SHARED = -2
    depth: Dict[int, int] = {}          # slot -> ready-deque depth
    placed: Dict[int, int] = {}         # wd_id -> slot it was pushed to
    early: set = set()                  # started before its ready event
    busy: Dict[int, bool] = {}          # slot -> executing now (workers
    #                                     appear at their first start)
    backlog = 0                         # undrained manager entries

    findings: List[Finding] = []
    span_start: Optional[float] = None
    span_deep_slot = -1
    span_backlog_only = True
    span_idle: List[int] = []           # idle set when the span opened
    t_prev = t_lo          # when the state creating a new span arose

    def close_span(t: float) -> None:
        nonlocal span_start
        if span_start is not None and t - span_start >= min_dur:
            findings.append(Finding(
                STARVATION, span_start, t, slot=span_deep_slot,
                count=len(span_idle),
                detail={"idle_slots": sorted(span_idle),
                        "backlog_only": span_backlog_only}))
        span_start = None

    for e in events:
        t = e.t
        # -- evaluate the condition over the interval ending at `t` ----
        idle_workers = [s for s, b in busy.items() if not b]
        deep_elsewhere = max(
            ((d, s) for s, d in depth.items()
             if s != SHARED and d >= depth_min
             and any(w != s for w in idle_workers)),
            default=None)
        total_depth = sum(depth.values())
        starving_on_backlog = (idle_workers and backlog >= backlog_min
                               and total_depth == 0
                               and not _in_windows(t, wins))
        flag = bool(deep_elsewhere) or starving_on_backlog
        if flag and span_start is None:
            # the condition became true when the *previous* event was
            # applied; a sparse timeline (enq ... long gap ... drain)
            # must accrue that whole gap, not open at the closing event
            span_start = t_prev
            span_deep_slot = deep_elsewhere[1] if deep_elsewhere else -1
            span_backlog_only = not deep_elsewhere
            span_idle = idle_workers
        elif not flag and span_start is not None:
            close_span(t)
        # -- apply the event ------------------------------------------
        if e.ev == EV_READY:
            banded = (isinstance(e.data, (tuple, list))
                      and len(e.data) == 2 and e.data[0] == "band")
            if e.wd_id in early:        # start already swept past
                early.discard(e.wd_id)
            else:
                dst = SHARED if banded else e.slot
                depth[dst] = depth.get(dst, 0) + 1
                placed[e.wd_id] = dst
        elif e.ev == EV_START:
            src = placed.pop(e.wd_id, None)
            if src is not None:
                depth[src] = depth.get(src, 0) - 1
                if src == SHARED and span_start is not None \
                        and span_backlog_only:
                    close_span(t)       # shared-lane pop = progress
            else:                       # ready not swept yet: cancel it
                early.add(e.wd_id)
            busy[e.slot] = True
        elif e.ev == EV_END:
            busy[e.slot] = False
        elif e.ev in (EV_MSG_ENQ, EV_DELEGATE):
            # a delegated portion is backlog exactly like a mailbox
            # entry: published, not yet applied by a combiner
            backlog += _msg_count(e.data)
        elif e.ev == EV_MSG_DRAIN:
            backlog -= _msg_count(e.data)
            if span_start is not None and span_backlog_only:
                close_span(t)           # the manager IS making progress
        elif e.ev == EV_COMBINE:
            # a combine session applied n published portions in one
            # critical section; the per-portion arithmetic already rode
            # the msg_drained events — this is pure progress evidence
            if span_start is not None and span_backlog_only:
                close_span(t)
        t_prev = t
    close_span(t_hi)
    return findings


# ---------------------------------------------------------------------
def detect_priority_inversion(events: Sequence[TraceEvent],
                              min_band_gap: int = 1,
                              min_count: int = 3) -> List[Finding]:
    """Only meaningful where ``ready`` events carry published bands
    (``CriticalPathPlacement`` under an active replay recording): flag
    each ``start`` of band *b* while a task of band >= *b* +
    ``min_band_gap`` had been ready strictly earlier and was still
    unstarted. Fewer than ``min_count`` occurrences is scheduling
    jitter (a band swap racing one pop), not a pathology."""
    avail: Dict[int, Tuple[int, float]] = {}   # wd_id -> (band, t_ready)
    started: set = set()                # starts swept before their ready
    hits: List[Tuple[float, int, int]] = []
    for e in events:
        if e.ev == EV_READY:
            d = e.data
            if isinstance(d, (tuple, list)) and len(d) == 2 \
                    and d[0] == "band" and e.wd_id not in started:
                avail[e.wd_id] = (int(d[1]), e.t)
        elif e.ev == EV_START:
            mine = avail.pop(e.wd_id, None)
            if mine is None:
                started.add(e.wd_id)
                continue
            band, _ = mine
            best = -1
            for b2, t2 in avail.values():
                if t2 < e.t and b2 > best:
                    best = b2
            if best >= band + min_band_gap:
                hits.append((e.t, band, best))
    if len(hits) < min_count:
        return []
    return [Finding(INVERSION, hits[0][0], hits[-1][0], count=len(hits),
                    detail={"examples": hits[:8]})]


# ---------------------------------------------------------------------
def detect_affinity_misses(events: Sequence[TraceEvent],
                           min_count: int = 3,
                           min_frac: float = 0.25) -> List[Finding]:
    """Among tasks the placement pinned for locality (``ready`` payload
    ``"affine"``), count those that *started* on a different slot AND
    have a ``steal`` event — locality was built, then traded away.
    Flagged only when both the absolute count and the affine fraction
    clear their thresholds: sporadic steals are the load balancer
    working as intended."""
    placed: Dict[int, Tuple[int, bool]] = {}   # wd_id -> (slot, affine)
    stolen: Dict[int, int] = {}                # wd_id -> victim slot
    started_at: Dict[int, Tuple[float, int]] = {}  # start before ready
    affine_total = 0
    misses: List[Tuple[float, int, int]] = []
    for e in events:
        if e.ev == EV_READY:
            affine = e.data == "affine"
            if affine:
                affine_total += 1
            s = started_at.pop(e.wd_id, None)
            if s is not None:           # pair late: the start came first
                if affine and s[1] != e.slot and e.wd_id in stolen:
                    misses.append((s[0], e.slot, s[1]))
            else:
                placed[e.wd_id] = (e.slot, affine)
        elif e.ev == EV_STEAL:
            stolen[e.wd_id] = e.data if isinstance(e.data, int) else -1
        elif e.ev == EV_START:
            p = placed.pop(e.wd_id, None)
            if p is None:
                started_at[e.wd_id] = (e.t, e.slot)
            elif p[1] and e.slot != p[0] and e.wd_id in stolen:
                misses.append((e.t, p[0], e.slot))
    if not affine_total or len(misses) < min_count:
        return []
    frac = len(misses) / affine_total
    if frac < min_frac:
        return []
    return [Finding(AFFINITY_MISS, misses[0][0], misses[-1][0],
                    count=len(misses),
                    detail={"affine_total": affine_total,
                            "miss_frac": round(frac, 4),
                            "examples": misses[:8]})]


# ---------------------------------------------------------------------
def detect_all(events: Sequence[TraceEvent], **kw) -> List[Finding]:
    """Run every detector; keyword args prefixed ``starvation_`` /
    ``inversion_`` / ``affinity_`` are routed to the matching one."""
    def sub(prefix):
        n = len(prefix)
        return {k[n:]: v for k, v in kw.items() if k.startswith(prefix)}
    out = detect_starvation(events, **sub("starvation_"))
    out += detect_priority_inversion(events, **sub("inversion_"))
    out += detect_affinity_misses(events, **sub("affinity_"))
    return out


# ---------------------------------------------------------------------
class IncrementalDetector:
    """Stateful wrapper driving the batch detectors over a *live*
    window mid-run (the metrics sampler calls :meth:`sweep` each tick)
    instead of once over the final timeline.

    Each sweep runs ``detect_all`` on the trailing ``window`` events
    and reports only findings not yet seen — dedup keys on
    ``(kind, t0, slot)``, which is stable because every detector stamps
    ``t0`` from event times, not wall clock.  When the full timeline
    fits inside the window, the union of sweep results equals a single
    post-hoc ``detect_all`` pass (the agreement property
    ``bench_metrics`` gates in CI); a longer run degrades gracefully to
    phase-local findings, which is exactly what the live consumer
    (``DynamicTuner``) wants.
    """

    def __init__(self, window: int = 4096, **kw) -> None:
        self.window = window
        self.kw = kw
        self._seen: set = set()
        self.findings: List[Finding] = []

    def sweep(self, events: Sequence[TraceEvent]) -> List[Finding]:
        """Detect over the trailing window; return only NEW findings."""
        evs = events[-self.window:] if len(events) > self.window else events
        fresh: List[Finding] = []
        for f in detect_all(evs, **self.kw):
            key = (f.kind, round(f.t0, 9), f.slot)
            if key not in self._seen:
                self._seen.add(key)
                fresh.append(f)
                self.findings.append(f)
        return fresh
