"""Per-task event tracing: append-only ring buffers, one per worker slot.

The paper argues about *where time goes inside the runtime* — manager
queue residency, lock waits, idle drains — and "Detrimental task
execution patterns" (PAPERS.md, 2406.03077) shows per-task lifecycle
timelines are enough to detect the pathologies automatically. This
module is the recording layer both drivers share:

  * task lifecycle:  ``created`` → ``deps_resolved`` → ``ready`` →
    ``start`` → ``end`` (stamped by whichever layer owns the
    transition: driver, dependence policy, placement);
  * manager side:    ``msg_enqueued`` / ``msg_drained`` (per-worker
    queues and shard mailboxes), ``steal`` (a ready task left another
    slot's deque), ``admission_defer`` (FairAdmission held a tenant's
    task in its ring);
  * boundaries:      ``quiesce`` at every root-taskwait quiescence,
    carrying the replay iteration count so consumers can tell live
    windows (manager events present) from replayed ones (elided by
    design).

Design constraints, in order:

1. **No new locks on the hot path.** Each slot appends to its own
   ``collections.deque(maxlen=capacity)`` — append is GIL-atomic and
   O(1), and a bounded deque drops from the head, so a run that
   outlives the capacity loses the *oldest* events per slot and nothing
   blocks. Producers that act on behalf of no particular slot
   (managers draining another worker's queue, the sharded router) use
   one shared overflow ring; deque append atomicity makes that safe
   too.
2. **Disabled cost = one attribute check.** Every call site guards with
   ``if tracer.enabled:``; ``NULL_TRACER`` answers ``enabled = False``
   and no-ops everything, so ``trace=False`` runs never construct an
   event tuple.
3. **One schema for both drivers.** Events are plain tuples
   ``(t, ev, wd_id, slot, label, scope, data)``; the clock is a
   callable — ``time.perf_counter()`` relative to run start under
   threads, ``SimCharger.now`` (virtual µs) under the simulator. The
   simulator additionally prices each stamp (``SimCosts.trace_event``)
   through the charger so the traced-vs-untraced overhead gate in
   ``bench_traces.py`` measures a real cost, not zero by construction.
"""
from __future__ import annotations

import json
from collections import deque
from typing import (Any, Callable, List, NamedTuple, Optional, Tuple)

# -- event kinds (string constants so traces stay greppable) -----------
EV_CREATED = "created"            # WD allocated + submitted by a worker
EV_DEPS = "deps_resolved"         # dependence analysis applied (per
#                                   shard portion in sharded mode)
EV_READY = "ready"                # pushed into a slot's ready deque;
#                                   slot = target deque; data: "affine"
#                                   or ("band", b) when applicable
EV_START = "start"                # body started on slot
EV_END = "end"                    # body finished on slot
EV_MSG_ENQ = "msg_enqueued"       # Submit/Done posted to a queue/mailbox
EV_MSG_DRAIN = "msg_drained"      # a manager processed one entry
EV_DELEGATE = "delegated"         # Submit/Done portion published to a
#                                   shard's MPSC request list (the
#                                   delegation analogue of msg_enqueued;
#                                   same (kind, shard, n) payload)
EV_COMBINE = "combined"           # one combine session: the lock holder
#                                   applied n published portions in a
#                                   single combined critical section
EV_STEAL = "steal"                # popped from another slot's deque;
#                                   slot = thief, data = victim slot
EV_ADMIT_DEFER = "admission_defer"  # FairAdmission held the task back
EV_QUIESCE = "quiesce"            # root-taskwait quiescence boundary

# -- fault-tolerance events (core.errors; process-backend supervisor
#    and the threaded retry path) ---------------------------------------
EV_WORKER_LOST = "worker_lost"    # a worker process died; data: pid,
#                                   exitcode, in-flight task labels
EV_RESPAWN = "respawn"            # supervisor replaced the worker;
#                                   slot = the respawned worker's slot
EV_RETRY = "retry"                # a task was re-dispatched after a
#                                   fault; data: attempt no. + reason
EV_TIMEOUT_KILL = "timeout_kill"  # per-task timeout expired: the stuck
#                                   worker was killed
EV_SCOPE_EXPIRED = "scope_expired"  # a scope's deadline/budget ran out;
#                                   its unrun tasks drain-and-fail
EV_TRACE_LOST = "trace_lost"      # a crashed worker's in-flight task
#                                   events could not be reconstructed

TASK_LIFECYCLE = (EV_CREATED, EV_DEPS, EV_READY, EV_START, EV_END)
FAULT_EVENTS = (EV_WORKER_LOST, EV_RESPAWN, EV_RETRY, EV_TIMEOUT_KILL,
                EV_SCOPE_EXPIRED, EV_TRACE_LOST)


class TraceEvent(NamedTuple):
    t: float                      # clock units (s threaded, µs sim)
    ev: str
    wd_id: int                    # -1 for manager/boundary events
    slot: int                     # acting slot; -1 when unattributed
    label: str
    scope: Optional[int]
    data: Any                     # event-specific payload (JSON-able)


class NullTraceRecorder:
    """The ``trace=False`` stub: every producer guards on ``.enabled``,
    so these bodies exist only for callers that skip the guard."""

    enabled = False

    def task_event(self, ev, wd, slot, data=None) -> None:
        pass

    def mgr_event(self, ev, slot, data=None) -> None:
        pass

    def quiesce(self, data=None) -> None:
        pass

    def ingest(self, events) -> None:
        pass

    def events(self) -> List[TraceEvent]:
        return []

    @property
    def dropped(self) -> int:
        return 0

    @property
    def total_appended(self) -> int:
        return 0


NULL_TRACER = NullTraceRecorder()


class TraceRecorder:
    """Per-slot bounded ring buffers + merge/save. One instance per run."""

    enabled = True

    def __init__(self, num_slots: int, clock: Callable[[], float],
                 capacity: int = 1 << 16, charge=None,
                 time_unit: str = "s") -> None:
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.num_slots = num_slots
        self.clock = clock
        self.capacity = capacity
        self.time_unit = time_unit          # "s" (threads) | "us" (sim)
        # priced stamps under the simulator; None under real threads
        self._charge = charge
        # rings[slot] for attributed producers, rings[-1] shared overflow
        self._rings: List[deque] = [deque(maxlen=capacity)
                                    for _ in range(num_slots + 1)]
        self._appended = [0] * (num_slots + 1)

    # -- producers (hot path: one append, no lock) ---------------------
    def _emit(self, slot: int, tup: Tuple) -> None:
        i = slot if 0 <= slot < self.num_slots else self.num_slots
        self._rings[i].append(tup)
        self._appended[i] += 1

    def task_event(self, ev: str, wd, slot: int, data=None) -> None:
        if self._charge is not None:
            self._charge.trace_event()
        self._emit(slot, (self.clock(), ev, wd.wd_id, slot, wd.label,
                          wd.scope, data))

    def mgr_event(self, ev: str, slot: int, data=None) -> None:
        if self._charge is not None:
            self._charge.trace_event()
        self._emit(slot, (self.clock(), ev, -1, slot, "", None, data))

    def quiesce(self, data=None) -> None:
        self.mgr_event(EV_QUIESCE, -1, data)

    def ingest(self, events) -> None:
        """Merge pre-stamped tuples recorded in another process (the
        process backend's per-worker rings, shipped at shutdown, and its
        replay-plane start/end stamps). Tuples must already be in the
        standard 7-field schema on this recorder's clock; the slot is
        read from the tuple, so worker events land in their own rings
        and the usual overflow accounting applies."""
        for e in events:
            self._emit(e[3], tuple(e))

    # -- consumers (cold path) -----------------------------------------
    @property
    def dropped(self) -> int:
        """Events evicted by ring overflow (oldest-first, per slot)."""
        return sum(self._appended) - sum(len(r) for r in self._rings)

    @property
    def total_appended(self) -> int:
        """Lifetime append count — a cheap has-anything-new probe for
        periodic consumers (the tuner's quiescence hook)."""
        return sum(self._appended)

    def events(self) -> List[TraceEvent]:
        """All retained events, merged and time-sorted. The sort is
        stable, so same-timestamp events keep per-ring append order."""
        evs = [TraceEvent(*e) for ring in self._rings for e in ring]
        evs.sort(key=lambda e: e.t)
        return evs

    def save(self, path: str) -> None:
        save_trace(path, self.events(), time_unit=self.time_unit,
                   num_slots=self.num_slots, dropped=self.dropped)


def save_trace(path: str, events, time_unit: str = "s",
               num_slots: int = 0, dropped: int = 0) -> None:
    """Write an event list in :meth:`TraceRecorder.save` format — for
    results that carry merged events but no recorder (``SimResult``,
    a post-shutdown ``RuntimeStats``)."""
    if not num_slots:
        num_slots = max((e[3] for e in events), default=0) + 1
    with open(path, "w") as f:
        json.dump({"time_unit": time_unit,
                   "num_slots": num_slots,
                   "dropped": dropped,
                   "events": [list(e) for e in events]}, f)


def load_trace(path: str) -> Tuple[List[TraceEvent], dict]:
    """Load a :meth:`TraceRecorder.save` file. Tuple payloads round-trip
    as lists; consumers index ``data`` rather than type-check it."""
    with open(path) as f:
        doc = json.load(f)
    events = [TraceEvent(*e) for e in doc["events"]]
    meta = {k: doc.get(k) for k in ("time_unit", "num_slots", "dropped")}
    return events, meta


def replay_iterations_of(policy, scope_id=None) -> int:
    """The replay iteration count the ``quiesce`` event should carry:
    resolved through the scope multiplexer when one is present, 0 for
    policies with no replay wrapper. Shared by both drivers so the
    boundary payloads are identical."""
    resolve = getattr(policy, "scope_policy", None)
    if resolve is not None:
        policy = resolve(scope_id)      # None -> the default root slot
    return getattr(policy, "replay_iterations", 0)
