"""Low-overhead per-task event tracing + detrimental-pattern detection.

``recorder`` is the shared recording layer (per-slot GIL-atomic ring
buffers, one schema for the threaded and simulated drivers); ``detect``
holds the three pathology detectors (ready-queue starvation, priority
inversion, affinity misses) that feed the ``DynamicTuner`` via its
quiescence hook and the ``repro.analysis.traceview`` exporter.
"""
from .detect import (AFFINITY_MISS, INVERSION, STARVATION, Finding,
                     IncrementalDetector, detect_affinity_misses,
                     detect_all, detect_priority_inversion,
                     detect_starvation, replay_windows)
from .recorder import (EV_ADMIT_DEFER, EV_COMBINE, EV_CREATED,
                       EV_DELEGATE, EV_DEPS, EV_END, EV_MSG_DRAIN,
                       EV_MSG_ENQ, EV_QUIESCE, EV_READY, EV_RESPAWN,
                       EV_RETRY, EV_SCOPE_EXPIRED, EV_START, EV_STEAL,
                       EV_TIMEOUT_KILL, EV_TRACE_LOST, EV_WORKER_LOST,
                       FAULT_EVENTS, NULL_TRACER, TASK_LIFECYCLE,
                       NullTraceRecorder, TraceEvent, TraceRecorder,
                       load_trace, replay_iterations_of, save_trace)

__all__ = [
    "TraceRecorder", "NullTraceRecorder", "NULL_TRACER", "TraceEvent",
    "load_trace", "save_trace", "replay_iterations_of", "TASK_LIFECYCLE",
    "EV_CREATED", "EV_DEPS", "EV_READY", "EV_START", "EV_END",
    "EV_MSG_ENQ", "EV_MSG_DRAIN", "EV_DELEGATE", "EV_COMBINE",
    "EV_STEAL", "EV_ADMIT_DEFER", "EV_QUIESCE",
    "EV_WORKER_LOST", "EV_RESPAWN", "EV_RETRY", "EV_TIMEOUT_KILL",
    "EV_SCOPE_EXPIRED", "EV_TRACE_LOST", "FAULT_EVENTS",
    "Finding", "IncrementalDetector", "detect_all", "detect_starvation",
    "detect_priority_inversion", "detect_affinity_misses",
    "replay_windows", "STARVATION", "INVERSION", "AFFINITY_MISS",
]
