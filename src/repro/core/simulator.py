"""Deterministic discrete-event simulator of the task runtime.

This container exposes ONE physical core, so the paper's headline results
(speedup vs. 16-64 worker threads, Figs 9-11) cannot be measured with
real threads. The simulator reproduces them in *virtual time*: N virtual
cores, task durations in microseconds, critical sections serialized on
virtual locks.

Since the unified dependence-policy engine (``core.engine``), the
simulator does NOT re-implement the dependence protocol: it drives the
*same* ``DependencePolicy`` objects the threaded ``TaskRuntime`` uses
(``SyncPolicy`` / ``DastPolicy`` / ``DdastPolicy`` / ``ShardedPolicy``
over the real ``DependenceGraph`` / ``ShardedDependenceGraph`` /
``ShardRouter`` structures), installing a
:class:`~repro.core.engine.charge.SimCharger` so every protocol step is
priced in virtual time: critical sections serialize on one
:class:`~repro.core.engine.charge.VirtualLock` per lock key
(FIFO-handover approximation), every mailbox entry costs one
``msg_overhead`` (a Submit *batch* therefore costs one, which is the
point of batching), and sharded portions cost
``submit_cs / k + portion_overhead`` each. Message counts and dependence
orderings are therefore identical to the threaded runtime by
construction, not by parallel maintenance.

Cost constants default to values calibrated from the real threaded
runtime on this machine (see ``benchmarks/bench_contention.py``, whose
``--calibrate`` flag measures ``portion_overhead``) and can be
overridden. The cache-pollution effect the paper measures (§6.1: task
bodies ~33 % faster under DDAST because workers stop touching runtime
structures between tasks) is modeled by the charger: a virtual-lock
acquisition flags the acting core, and the next task body it executes is
charged a duration multiplier.

``run(specs, iterations=n)`` re-submits the same graph n times with a
root taskwait between iterations (the paper's epoch loop) and reports
per-iteration makespan/lock/message deltas; with ``replay=True`` the
policy is wrapped in the record-and-replay ``ReplayPolicy``, whose
steady-state iterations are priced as pure latch arithmetic (no
VirtualLock, no message, no pollution flag).

Everything is deterministic: no wall clock, no randomness — identical
inputs give identical makespans (required for hypothesis-based testing).
One approximation is accepted relative to a fully causal event model:
state produced while a core's local clock runs ahead (inside a lock
wait) becomes visible to other cores at their next event rather than at
the exact virtual instant; waits themselves are always charged in full.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .ddast import DDASTParams
from .engine import (SimCharger, make_placement, make_policy,
                     mode_needs_manager_thread, mode_uses_shards)
from .metrics import NULL_METRICS, MetricsHub, MetricsSampler
from .scopes import (FairAdmission, ScopedPolicy, scope_rollup,
                     scoped_deps)
from .trace import (EV_CREATED, EV_END, EV_START, NULL_TRACER,
                    TraceRecorder, replay_iterations_of)
from .wd import DepMode, TaskState, WorkDescriptor

# ---------------------------------------------------------------------------


@dataclass
class SimTaskSpec:
    """One task in virtual time. `deps` = (region, DepMode) pairs; `dur` in
    microseconds; `children` makes this a nesting parent (N-Body style):
    the executing core creates the children, taskwaits on them (working as
    a normal worker meanwhile), then the parent completes."""
    dur: float
    deps: Sequence[Tuple[Any, DepMode]] = ()
    children: Optional[List["SimTaskSpec"]] = None
    label: str = "t"


@dataclass
class SimCosts:
    """Virtual-time costs (µs). Defaults calibrated on this host (see
    EXPERIMENTS.md §Paper/contention)."""
    create: float = 3.1        # WD alloc + arg capture (measured: 3.15us)
    push: float = 0.08         # SPSC queue push (measured: 0.076us)
    submit_cs: float = 2.0     # graph insert critical section (base)
    submit_cs_dep: float = 0.8    # ... plus this per declared dependence
    done_cs: float = 1.0       # graph completion critical section (base)
    done_cs_dep: float = 0.5   # ... plus this per dependence scrubbed
    msg_overhead: float = 0.25  # manager pop+dispatch per mailbox entry
    portion_overhead: float = 0.35  # fixed cost per shard portion (latch
    #   arithmetic + per-shard dispatch; measured by
    #   bench_contention.py --calibrate, replacing the idealized
    #   submit_cs / k split)
    lock_overhead: float = 0.12  # uncontended acquire/release
    pollution: float = 1.25    # duration multiplier after graph ops (§6.1)
    # Record-and-replay steady-state steps (engine/replay.py): a Submit
    # is a structural-key check + one latch decrement, a Done is one
    # latch decrement per recorded successor — no lock, no message, and
    # no pollution flag (the replay path touches no shared runtime
    # structures, which is how the §6.1 cache win compounds).
    replay_submit: float = 0.12  # key compare + submit-phase latch dec
    replay_done: float = 0.05    # completion bookkeeping (fixed part)
    replay_dec: float = 0.04     # per recorded successor latch dec
    # Critical-path placement lane traffic (sched/placement.py): a
    # priority push is one banded deque append, a pop pays the band
    # scan — both lock-free, priced so the critical_path-vs-round_robin
    # makespan comparison in bench_sched.py is honest.
    prio_push: float = 0.06      # banded append + band lookup
    prio_pop: float = 0.04       # pop-side band scan while replaying
    # One tracing ring-buffer append (core.trace, trace=True only):
    # a tuple build + GIL-atomic deque append. Priced so the
    # traced-vs-untraced overhead gate in bench_traces.py measures a
    # real cost instead of zero by construction.
    trace_event: float = 0.05
    # Cross-process mailbox traffic (core.procs ring buffers), so the
    # simulator can model backend="processes" before buying cores: one
    # Submit batch encoded + pushed onto an exec ring, and one Done
    # batch popped + decoded off a done ring. Measure on the current
    # host with ``bench_contention.py --calibrate`` (real shm-ring
    # round-trips against an echo process).
    ipc_submit_us: float = 12.0  # encode_submit_batch + ring push
    ipc_done_us: float = 8.0     # ring pop + decode_done_batch
    # Delegation/combining fast path (shards.router): publishing one
    # message onto a shard's MPSC request list (a GIL-atomic deque
    # append + one trylock attempt), and one combine-session fixed cost
    # on the lock-holder side (staging the drained requests into
    # per-scope buckets). Measure with ``bench_contention.py
    # --calibrate`` (delegate row = publish+trylock on a held lock).
    delegate_us: float = 0.18    # request-list append + failed trylock
    combine_us: float = 0.30     # per combine session (staging/rotation)
    # Live metrics plane (core.metrics, metrics=True only): one per-slot
    # instrument write (counter bump / histogram bucket increment) per
    # task start and per task end, and one sampler pass (probe walk +
    # series appends) per sampling interval. Priced so the
    # metrics-overhead gate in bench_metrics.py measures a real cost.
    metric_event: float = 0.02   # per-slot counter/histogram write
    metric_sample: float = 0.8   # one probe-walk sampling pass


@dataclass
class SimResult:
    makespan_us: float
    serial_us: float
    tasks: int
    lock_wait_us: float = 0.0
    lock_acquisitions: int = 0
    messages: int = 0
    max_in_graph: int = 0
    total_edges: int = 0
    trace: List[Tuple[float, int, int]] = field(default_factory=list)
    # Per-task event timeline (core.trace; empty unless trace=True),
    # same schema as RuntimeStats.events with virtual-µs timestamps.
    events: list = field(default_factory=list)
    trace_dropped: int = 0
    # Placement counters surfaced per run (see RuntimeStats).
    worker_steals: List[int] = field(default_factory=list)
    load_cap_skips: int = 0
    exec_order: List[str] = field(default_factory=list)  # task labels
    # Per-iteration breakdown when run(..., iterations=n): virtual time,
    # lock acquisitions, and mailbox entries attributable to each
    # iteration (deltas between root-quiescence boundaries). Under a
    # frozen replay recording the steady-state entries are 0 locks and
    # 0 messages — the quantity bench_replay.py gates on.
    iterations: int = 1
    iter_makespans_us: List[float] = field(default_factory=list)
    iter_lock_acq: List[int] = field(default_factory=list)
    iter_messages: List[int] = field(default_factory=list)
    # Delegation/combining counters (sharded mode; zero elsewhere or
    # with delegation=False). delegated_portions is structural — every
    # portion that traversed a shard request list — so the threaded
    # driver and the simulator report identical values on the same
    # program (extends the sim-vs-real identity tests).
    delegated_portions: int = 0
    combined_drains: int = 0
    lock_handoffs: List[int] = field(default_factory=list)
    # Per-scope rollups when run_scopes(...) drove multiple tenant
    # programs: scope name -> {tasks, weight, finish_us,
    # iter_makespans_us, replay_iterations, replayed_tasks, admitted,
    # admission_waits, max_queued}. Only per-scope-attributable
    # quantities appear here — lock/message counters are runtime-wide
    # (compare iterations=1 vs iterations=n runs to bound replay cost).
    scopes: Dict[str, dict] = field(default_factory=dict)
    # Live-metrics snapshot (core.metrics; empty unless metrics=True):
    # per-slot counters, virtual-µs latency histogram, sampled series —
    # the same structure RuntimeStats.metrics carries on real threads.
    metrics: Dict[str, object] = field(default_factory=dict)

    @property
    def speedup(self) -> float:
        return self.serial_us / self.makespan_us if self.makespan_us else 0.0


# ---------------------------------------------------------------------------


class _SimProgram:
    """One client program driven by the event loop: a spec graph
    re-submitted ``iterations`` times with a root taskwait between
    (``run()``: the single scope-less main program; ``run_scopes()``:
    one per tenant, each on its own client core)."""

    __slots__ = ("scope_id", "name", "specs", "iterations", "weight",
                 "epoch", "marks", "finish_us", "serial_us", "tasks")

    def __init__(self, scope_id: Optional[int], name: str,
                 specs: List[SimTaskSpec], iterations: int,
                 weight: float = 1.0) -> None:
        self.scope_id = scope_id
        self.name = name
        self.specs = specs
        self.iterations = iterations
        self.weight = weight
        self.epoch = 0
        self.marks: List[Tuple[float, int, int]] = []
        self.finish_us = 0.0
        self.serial_us = 0.0
        self.tasks = 0


class RuntimeSimulator:
    """Event-driven simulation of `TaskRuntime` on `num_cores` virtual
    cores, driving the shared dependence-policy objects.

    Core 0 runs the "main thread" program (creates the top-level tasks,
    then taskwaits, working as a normal worker while waiting) — the same
    structure as the real runtime and the paper's benchmarks. Under the
    ``dast`` policy, core ``num_cores - 1`` is the dedicated manager.
    """

    def __init__(self, num_cores: int, mode: str = "ddast",
                 params: Optional[DDASTParams] = None,
                 costs: Optional[SimCosts] = None,
                 trace: bool = False,
                 num_shards: Optional[int] = None,
                 batch_size: Optional[int] = None,
                 placement: Any = "round_robin",
                 replay: bool = False,
                 delegation: bool = True,
                 metrics: bool = False,
                 metrics_interval_us: float = 200.0) -> None:
        # mode validation lives in the policy registry (raises on an
        # unknown mode) — the driver itself stays free of mode branching
        if mode_needs_manager_thread(mode) and num_cores < 2:
            # core P-1 is the dedicated manager; with one core the main
            # program could never run and the result would be silently
            # empty.
            raise ValueError("dast needs >= 2 cores (one is the manager)")
        if num_shards is not None and num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        if batch_size is not None and batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        self.P = num_cores
        self.mode = mode
        self.params = params or DDASTParams()
        self.costs = costs or SimCosts()
        self.trace_enabled = trace
        self.num_shards = num_shards
        self.batch_size = batch_size
        self.placement_kind = placement
        self.replay = replay
        self.delegation = delegation
        self.metrics_enabled = metrics
        self.metrics_interval_us = metrics_interval_us

    # -- public ---------------------------------------------------------
    def run(self, specs: List[SimTaskSpec],
            iterations: int = 1) -> SimResult:
        """Simulate the graph; with ``iterations > 1`` the main program
        re-submits the same spec graph that many times with a root
        taskwait between iterations (the paper's epoch/timestep loop) —
        the shape record-and-replay (``replay=True``) exploits."""
        if iterations < 1:
            raise ValueError("iterations must be >= 1")
        charge = self._make_charge()
        tracer = self._make_tracer(charge)
        placement = self._make_placement()
        policy = self._make_policy(placement, charge, replay=self.replay,
                                   tracer=tracer)
        prog = _SimProgram(None, "main", list(specs), iterations)
        hub, sampler = self._make_metrics(charge, placement, policy)
        return self._drive([prog], charge, placement, policy, tracer,
                           hub=hub, sampler=sampler)

    def run_scopes(self, scope_specs: Sequence[List[SimTaskSpec]],
                   weights: Optional[Sequence[float]] = None,
                   max_inflight: Optional[Sequence[Optional[int]]] = None,
                   iterations: int = 1,
                   names: Optional[Sequence[str]] = None) -> SimResult:
        """Multi-tenant event loop: one virtual *client core* per entry
        of ``scope_specs`` runs that scope's program (create the graph,
        taskwait — working as a normal worker while blocked — then
        re-submit ``iterations`` times), mirroring ``TaskRuntime``
        client threads with ``open_scope``. The same scope layers run
        underneath: the region-keying shim, one replay slot per scope
        (``replay=True``), and weighted-deficit-round-robin admission
        (``weights``, per-scope ``max_inflight``). Per-scope rollups
        land in ``SimResult.scopes``."""
        S = len(scope_specs)
        if S < 1:
            raise ValueError("run_scopes needs at least one scope")
        if iterations < 1:
            raise ValueError("iterations must be >= 1")
        P = self.P
        if S > P:
            raise ValueError(f"{S} scopes need at least {S} cores")
        if mode_needs_manager_thread(self.mode) and S > P - 1:
            raise ValueError("dast reserves the last core for the "
                             "manager: need num_cores > num_scopes")
        weights = list(weights) if weights is not None else [1.0] * S
        caps = list(max_inflight) if max_inflight is not None \
            else [None] * S
        names = list(names) if names is not None \
            else [f"scope{i}" for i in range(S)]
        if not (len(weights) == len(caps) == len(names) == S):
            raise ValueError("weights/max_inflight/names length mismatch")
        charge = self._make_charge()
        tracer = self._make_tracer(charge)
        placement = FairAdmission(self._make_placement())
        # the scope multiplexer owns the replay wrapping (one recording
        # slot per scope), so the base policy stays live
        policy = ScopedPolicy(self._make_policy(placement, charge,
                                                replay=False,
                                                tracer=tracer),
                              replay=self.replay)
        programs = []
        for i in range(S):
            sid = i + 1
            policy.register_scope(sid)
            placement.register_scope(sid, weights[i], caps[i])
            programs.append(_SimProgram(sid, names[i],
                                        list(scope_specs[i]), iterations,
                                        weight=weights[i]))
        hub, sampler = self._make_metrics(charge, placement, policy)
        return self._drive(programs, charge, placement, policy, tracer,
                           hub=hub, sampler=sampler)

    def _make_charge(self) -> SimCharger:
        """Wait-free shard-lock accounting only applies where shard
        locks exist; other modes keep the blocking model regardless of
        the ``delegation`` flag."""
        return SimCharger(self.costs,
                          delegation=self.delegation
                          and mode_uses_shards(self.mode))

    def _make_tracer(self, charge: SimCharger):
        """Virtual-time tracer: stamps `charge.now` and prices each
        append through `SimCharger.trace_event()`, so the traced run's
        makespan honestly carries the instrumentation cost."""
        if not self.trace_enabled:
            return NULL_TRACER
        return TraceRecorder(self.P, clock=lambda: charge.now,
                             charge=charge, time_unit="us")

    def _make_metrics(self, charge: SimCharger, placement, policy):
        """Virtual-time metrics plane: the hub prices every instrument
        write through ``SimCharger.metric_event()`` and the sampler
        prices each pass through ``metric_sample()`` — same honesty
        contract as :meth:`_make_tracer`, so the overhead gate in
        bench_metrics.py measures a real cost."""
        if not self.metrics_enabled:
            return NULL_METRICS, None
        hub = MetricsHub(self.P, clock=lambda: charge.now,
                         charge=charge, time_unit="us")
        sampler = MetricsSampler(clock=lambda: charge.now,
                                 interval=self.metrics_interval_us,
                                 charge=charge)
        sampler.add_probe("ready", placement.ready_count)
        sampler.add_probe(
            "ready_depth",
            lambda: {str(i): len(d)
                     for i, d in enumerate(placement.deques)})
        sampler.add_probe("pending_msgs", policy.pending)
        sampler.add_probe("in_graph", policy.in_graph)
        sampler.add_probe("busy_frac", lambda: hub.busy_fraction(self.P))
        if isinstance(placement, FairAdmission):
            sampler.add_probe("admission_backlog",
                              placement.admission_backlog)
            sampler.add_probe("admission_waits",
                              placement.admission_waits_total)
            sampler.add_probe(
                "scope_inflight",
                lambda: {str(k): v
                         for k, v in placement.scope_inflight().items()})
        return hub, sampler

    def _make_placement(self):
        return make_placement(
            self.placement_kind, self.P,
            num_shards=(self.num_shards or self.P)
            if mode_uses_shards(self.mode) else None)

    def _make_policy(self, placement, charge: SimCharger, replay: bool,
                     tracer=NULL_TRACER):
        return make_policy(
            self.mode, self.P,
            num_workers=self.P,
            params=self.params,
            placement=placement,
            charge=charge,
            main_slot=0,
            num_shards=self.num_shards or self.P,
            batch_size=self.batch_size,
            delegation=self.delegation,
            replay=replay,
            tracer=tracer)

    # -- the event loop (shared by run and run_scopes) ------------------
    def _drive(self, programs: List["_SimProgram"], charge: SimCharger,
               placement, policy, tracer=NULL_TRACER,
               hub=NULL_METRICS, sampler=None) -> SimResult:
        P, costs = self.P, self.costs
        mgr_core = P - 1 if policy.needs_manager_thread else -1

        roots: Dict[int, WorkDescriptor] = {}
        for core, prog in enumerate(programs):
            root = WorkDescriptor(func=None, label=f"sim-{prog.name}",
                                  scope=prog.scope_id)
            root.state = TaskState.RUNNING
            roots[core] = root

        serial_us = 0.0
        total_tasks = 0
        for prog in programs:
            stack_count = [list(prog.specs)]
            while stack_count:
                for s in stack_count.pop():
                    prog.serial_us += s.dur
                    prog.tasks += 1
                    if s.children:
                        stack_count.append(s.children)
            prog.serial_us *= prog.iterations
            prog.tasks *= prog.iterations
            serial_us += prog.serial_us
            total_tasks += prog.tasks

        trace: List[Tuple[float, int, int]] = []
        exec_order: List[str] = []

        # events: (time, seq, core, kind, wd). Kinds: "step" re-evaluates
        # the core's state machine; "fin" delivers a task-body completion
        # at its finish time (evaluating it eagerly at start time would
        # advance virtual locks into the future and stall every
        # earlier-timestamped acquirer — a causality violation).
        events: List[Tuple[float, int, int, str, Optional[WorkDescriptor]]] = []
        seq = [0]
        sleeping: set = set()
        finished = [False]
        makespan = [0.0]

        def schedule(t: float, core: int, kind: str = "step",
                     wd: Optional[WorkDescriptor] = None) -> None:
            heapq.heappush(events, (t, seq[0], core, kind, wd))
            seq[0] += 1

        def wake_all(t: float) -> None:
            for core in sorted(sleeping):
                schedule(t, core)
            sleeping.clear()

        def sample(t: float) -> None:
            if self.trace_enabled:
                trace.append((t, policy.in_graph(),
                              placement.ready_count()))

        # progs[core] = stack of creation frames [specs, idx, parent_wd];
        # parent_wd is None for a top-level (program-root) frame. Program
        # p runs on client core p (run(): the single program on core 0).
        progs: Dict[int, List[List[Any]]] = {i: [] for i in range(P)}
        for core, prog in enumerate(programs):
            progs[core].append([list(prog.specs), 0, None])

        # iteration (epoch) bookkeeping: cumulative snapshots taken at
        # each program-root quiescence, turned into per-iteration deltas
        # below (per program — each tenant has its own epoch loop)
        done = [0]

        def finish_epoch(core: int) -> None:
            prog = programs[core]
            t = max(makespan[0], charge.now)
            policy.notify_quiescent(True, scope_id=prog.scope_id)
            if tracer.enabled:
                # quiesce markers delimit replay windows for the
                # detectors: replayed iterations are manager-silent by
                # design, not starving (see trace/detect.py)
                tracer.quiesce({"scope": prog.scope_id,
                                "replay_iterations": replay_iterations_of(
                                    policy, prog.scope_id)})
            prog.marks.append((t, charge.lock_acquisitions(),
                               policy.stats()["messages_processed"]))
            if sampler is not None:
                # quiescence edge: always sample (the same boundary the
                # threaded sampler's quiescent_callback rides)
                sampler.tick(force=True)
            prog.epoch += 1
            if prog.epoch < prog.iterations:
                progs[core].append([list(prog.specs), 0, None])
                schedule(charge.now, core)
                return
            prog.finish_us = t
            done[0] += 1
            if done[0] == len(programs):
                finished[0] = True
                makespan[0] = t
            else:
                # this client core keeps working for the other tenants
                schedule(charge.now, core)

        def run_worker(core: int) -> bool:
            """Pop + start one ready task on `core` at charge.now.
            Returns True if a task was started."""
            wd = placement.pop(core)
            if wd is None:
                return False
            t = charge.now
            dur = wd.duration * (costs.pollution
                                 if core in charge.polluted else 1.0)
            charge.polluted.discard(core)
            wd.mark_running()
            if hub.enabled:
                hub.task_start(core)
            if tracer.enabled:
                tracer.task_event(EV_START, wd, core)
            exec_order.append(wd.label)
            children = getattr(wd, "sim_children", None)
            if children:
                # parent body runs for `dur`, then the creation frame
                # takes over (children created after the body, as in the
                # threaded apps where the body IS the creation loop).
                progs[core].append([children, 0, wd])
                schedule(t + dur, core)
            else:
                schedule(t + dur, core, kind="fin", wd=wd)
            return True

        def step_core(core: int, t: float) -> None:
            charge.begin(core, t)
            if core == mgr_core:            # dedicated manager [7]
                n = policy.drain_all()
                if n:
                    sample(charge.now)
                    wake_all(charge.now)
                    schedule(charge.now, core)
                else:
                    sleeping.add(core)
                return
            stack = progs[core]
            if stack:
                frame = stack[-1]
                specs_, idx, parent = frame
                if idx < len(specs_):       # creation program
                    spec = specs_[idx]
                    frame[1] += 1
                    charge.create()
                    parent_wd = parent if parent is not None \
                        else roots[core]
                    # the scopes keying shim: a tenant's regions are
                    # scope-qualified exactly as on the real runtime
                    wd = WorkDescriptor(
                        func=None,
                        deps=tuple(scoped_deps(parent_wd.scope,
                                               spec.deps)),
                        label=spec.label, parent=parent_wd)
                    wd.duration = spec.dur
                    wd.sim_children = spec.children
                    if tracer.enabled:
                        tracer.task_event(EV_CREATED, wd, core)
                    policy.submit(wd, core)
                    sample(charge.now)
                    wake_all(charge.now)
                    schedule(charge.now, core)
                    return
                # taskwait phase of this frame
                policy.flush(core)
                waiter = parent if parent is not None else roots[core]
                # scoped waiters gate on their own subtree only (see
                # TaskRuntime._taskwait_on): children are counted from
                # creation, so children == 0 implies none of the
                # scope's submits are still queued anywhere
                if waiter.num_children_alive == 0 and \
                        (waiter.scope is not None or not policy.pending()):
                    stack.pop()
                    if parent is not None:  # nested parent completes
                        policy.notify_quiescent(False)
                        parent.mark_finished()
                        if hub.enabled:
                            hub.task_end(core, parent.duration)
                        if tracer.enabled:
                            tracer.task_event(EV_END, parent, core)
                        placement.note_executed(parent, core)
                        policy.complete(parent, core)
                        sample(charge.now)
                        wake_all(charge.now)
                        schedule(charge.now, core)
                    else:                   # main program done (epoch)
                        finish_epoch(core)
                    return
                # blocked in taskwait: fall through and work
            if run_worker(core):
                return
            # idle: offer cycles to the policy (Listing 2), take a
            # metrics sample (the DDAST idle-thread discipline), or sleep
            n = policy.idle_callback(core) \
                if policy.uses_idle_managers else 0
            if sampler is not None and sampler.tick():
                n += 1
            if n or charge.now > t:
                sample(charge.now)
                wake_all(charge.now)
                schedule(charge.now, core)
            else:
                sleeping.add(core)

        for i in range(P):
            schedule(0.0, i)

        guard = 0
        while events and not finished[0]:
            t, _, core, kind, wd = heapq.heappop(events)
            makespan[0] = max(makespan[0], t)
            if kind == "fin":
                charge.begin(core, t)
                wd.mark_finished()
                if hub.enabled:
                    hub.task_end(core, wd.duration)
                if tracer.enabled:
                    tracer.task_event(EV_END, wd, core)
                placement.note_executed(wd, core)
                policy.complete(wd, core)
                sample(charge.now)
                wake_all(charge.now)
                schedule(charge.now, core)
            else:
                step_core(core, t)
            guard += 1
            if guard > 100_000_000:  # pragma: no cover
                raise RuntimeError("simulator exceeded event budget")

        st = policy.stats()

        def _deltas(marks):
            mk, la, msg = [], [], []
            prev = (0.0, 0, 0)
            for mark in marks:
                mk.append(mark[0] - prev[0])
                la.append(mark[1] - prev[1])
                msg.append(mark[2] - prev[2])
                prev = mark
            return mk, la, msg

        # the flat iter_* lists keep their single-program meaning; with
        # several tenants the boundaries interleave, so per-scope lists
        # live in the rollups instead
        iter_mk, iter_la, iter_msg = _deltas(
            programs[0].marks if len(programs) == 1 else [])
        scopes: Dict[str, dict] = {}
        if len(programs) > 1 or programs[0].scope_id is not None:
            for prog in programs:
                mk, _, _ = _deltas(prog.marks)
                # lock/message counters are runtime-wide, so deltas at
                # one scope's boundaries would silently include every
                # OTHER tenant's activity — per-scope rollups carry only
                # quantities attributable to the scope (verify replay
                # cost globally via iterations=1 vs iterations=n runs)
                entry = {"tasks": prog.tasks, "weight": prog.weight,
                         "finish_us": prog.finish_us,
                         "iter_makespans_us": mk}
                entry.update(scope_rollup(placement, policy,
                                          prog.scope_id))
                scopes[prog.name] = entry
        metrics_snap: Dict[str, object] = {}
        if hub.enabled:
            metrics_snap = dict(hub.snapshot())
            metrics_snap["gauges"] = {
                "ready": placement.ready_count(),
                "pending_msgs": policy.pending(),
                "in_graph": policy.in_graph(),
            }
            if sampler is not None:
                metrics_snap["sampler"] = sampler.snapshot()
        return SimResult(
            makespan_us=max(makespan[0], charge.max_free_at()),
            serial_us=serial_us,
            tasks=total_tasks,
            lock_wait_us=charge.lock_wait_us(),
            lock_acquisitions=charge.lock_acquisitions(),
            messages=st["messages_processed"],
            max_in_graph=st["max_in_graph"],
            total_edges=st["total_edges"],
            delegated_portions=st["delegated_portions"],
            combined_drains=st["combined_drains"],
            lock_handoffs=list(st["shard_lock_handoffs"]),
            trace=trace,
            events=tracer.events() if tracer.enabled else [],
            trace_dropped=tracer.dropped,
            worker_steals=[d.stolen for d in placement.deques],
            load_cap_skips=int(placement.stats().get("load_cap_skips", 0)),
            exec_order=exec_order,
            iterations=max(p.iterations for p in programs),
            iter_makespans_us=iter_mk,
            iter_lock_acq=iter_la,
            iter_messages=iter_msg,
            scopes=scopes,
            metrics=metrics_snap,
        )
