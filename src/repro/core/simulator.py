"""Deterministic discrete-event simulator of the task runtime.

This container exposes ONE physical core, so the paper's headline results
(speedup vs. 16-64 worker threads, Figs 9-11) cannot be measured with
real threads. The simulator reproduces them in *virtual time*: N virtual
cores, task durations in microseconds, critical sections serialized on
virtual locks.

Since the unified dependence-policy engine (``core.engine``), the
simulator does NOT re-implement the dependence protocol: it drives the
*same* ``DependencePolicy`` objects the threaded ``TaskRuntime`` uses
(``SyncPolicy`` / ``DastPolicy`` / ``DdastPolicy`` / ``ShardedPolicy``
over the real ``DependenceGraph`` / ``ShardedDependenceGraph`` /
``ShardRouter`` structures), installing a
:class:`~repro.core.engine.charge.SimCharger` so every protocol step is
priced in virtual time: critical sections serialize on one
:class:`~repro.core.engine.charge.VirtualLock` per lock key
(FIFO-handover approximation), every mailbox entry costs one
``msg_overhead`` (a Submit *batch* therefore costs one, which is the
point of batching), and sharded portions cost
``submit_cs / k + portion_overhead`` each. Message counts and dependence
orderings are therefore identical to the threaded runtime by
construction, not by parallel maintenance.

Cost constants default to values calibrated from the real threaded
runtime on this machine (see ``benchmarks/bench_contention.py``, whose
``--calibrate`` flag measures ``portion_overhead``) and can be
overridden. The cache-pollution effect the paper measures (§6.1: task
bodies ~33 % faster under DDAST because workers stop touching runtime
structures between tasks) is modeled by the charger: a virtual-lock
acquisition flags the acting core, and the next task body it executes is
charged a duration multiplier.

``run(specs, iterations=n)`` re-submits the same graph n times with a
root taskwait between iterations (the paper's epoch loop) and reports
per-iteration makespan/lock/message deltas; with ``replay=True`` the
policy is wrapped in the record-and-replay ``ReplayPolicy``, whose
steady-state iterations are priced as pure latch arithmetic (no
VirtualLock, no message, no pollution flag).

Everything is deterministic: no wall clock, no randomness — identical
inputs give identical makespans (required for hypothesis-based testing).
One approximation is accepted relative to a fully causal event model:
state produced while a core's local clock runs ahead (inside a lock
wait) becomes visible to other cores at their next event rather than at
the exact virtual instant; waits themselves are always charged in full.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .ddast import DDASTParams
from .engine import (SimCharger, make_placement, make_policy,
                     mode_needs_manager_thread, mode_uses_shards)
from .wd import DepMode, TaskState, WorkDescriptor

# ---------------------------------------------------------------------------


@dataclass
class SimTaskSpec:
    """One task in virtual time. `deps` = (region, DepMode) pairs; `dur` in
    microseconds; `children` makes this a nesting parent (N-Body style):
    the executing core creates the children, taskwaits on them (working as
    a normal worker meanwhile), then the parent completes."""
    dur: float
    deps: Sequence[Tuple[Any, DepMode]] = ()
    children: Optional[List["SimTaskSpec"]] = None
    label: str = "t"


@dataclass
class SimCosts:
    """Virtual-time costs (µs). Defaults calibrated on this host (see
    EXPERIMENTS.md §Paper/contention)."""
    create: float = 3.1        # WD alloc + arg capture (measured: 3.15us)
    push: float = 0.08         # SPSC queue push (measured: 0.076us)
    submit_cs: float = 2.0     # graph insert critical section (base)
    submit_cs_dep: float = 0.8    # ... plus this per declared dependence
    done_cs: float = 1.0       # graph completion critical section (base)
    done_cs_dep: float = 0.5   # ... plus this per dependence scrubbed
    msg_overhead: float = 0.25  # manager pop+dispatch per mailbox entry
    portion_overhead: float = 0.35  # fixed cost per shard portion (latch
    #   arithmetic + per-shard dispatch; measured by
    #   bench_contention.py --calibrate, replacing the idealized
    #   submit_cs / k split)
    lock_overhead: float = 0.12  # uncontended acquire/release
    pollution: float = 1.25    # duration multiplier after graph ops (§6.1)
    # Record-and-replay steady-state steps (engine/replay.py): a Submit
    # is a structural-key check + one latch decrement, a Done is one
    # latch decrement per recorded successor — no lock, no message, and
    # no pollution flag (the replay path touches no shared runtime
    # structures, which is how the §6.1 cache win compounds).
    replay_submit: float = 0.12  # key compare + submit-phase latch dec
    replay_done: float = 0.05    # completion bookkeeping (fixed part)
    replay_dec: float = 0.04     # per recorded successor latch dec
    # Critical-path placement lane traffic (sched/placement.py): a
    # priority push is one banded deque append, a pop pays the band
    # scan — both lock-free, priced so the critical_path-vs-round_robin
    # makespan comparison in bench_sched.py is honest.
    prio_push: float = 0.06      # banded append + band lookup
    prio_pop: float = 0.04       # pop-side band scan while replaying


@dataclass
class SimResult:
    makespan_us: float
    serial_us: float
    tasks: int
    lock_wait_us: float = 0.0
    lock_acquisitions: int = 0
    messages: int = 0
    max_in_graph: int = 0
    total_edges: int = 0
    trace: List[Tuple[float, int, int]] = field(default_factory=list)
    exec_order: List[str] = field(default_factory=list)  # task labels
    # Per-iteration breakdown when run(..., iterations=n): virtual time,
    # lock acquisitions, and mailbox entries attributable to each
    # iteration (deltas between root-quiescence boundaries). Under a
    # frozen replay recording the steady-state entries are 0 locks and
    # 0 messages — the quantity bench_replay.py gates on.
    iterations: int = 1
    iter_makespans_us: List[float] = field(default_factory=list)
    iter_lock_acq: List[int] = field(default_factory=list)
    iter_messages: List[int] = field(default_factory=list)

    @property
    def speedup(self) -> float:
        return self.serial_us / self.makespan_us if self.makespan_us else 0.0


# ---------------------------------------------------------------------------


class RuntimeSimulator:
    """Event-driven simulation of `TaskRuntime` on `num_cores` virtual
    cores, driving the shared dependence-policy objects.

    Core 0 runs the "main thread" program (creates the top-level tasks,
    then taskwaits, working as a normal worker while waiting) — the same
    structure as the real runtime and the paper's benchmarks. Under the
    ``dast`` policy, core ``num_cores - 1`` is the dedicated manager.
    """

    def __init__(self, num_cores: int, mode: str = "ddast",
                 params: Optional[DDASTParams] = None,
                 costs: Optional[SimCosts] = None,
                 trace: bool = False,
                 num_shards: Optional[int] = None,
                 batch_size: Optional[int] = None,
                 placement: Any = "round_robin",
                 replay: bool = False) -> None:
        # mode validation lives in the policy registry (raises on an
        # unknown mode) — the driver itself stays free of mode branching
        if mode_needs_manager_thread(mode) and num_cores < 2:
            # core P-1 is the dedicated manager; with one core the main
            # program could never run and the result would be silently
            # empty.
            raise ValueError("dast needs >= 2 cores (one is the manager)")
        if num_shards is not None and num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        if batch_size is not None and batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        self.P = num_cores
        self.mode = mode
        self.params = params or DDASTParams()
        self.costs = costs or SimCosts()
        self.trace_enabled = trace
        self.num_shards = num_shards
        self.batch_size = batch_size
        self.placement_kind = placement
        self.replay = replay

    # -- public ---------------------------------------------------------
    def run(self, specs: List[SimTaskSpec],
            iterations: int = 1) -> SimResult:
        """Simulate the graph; with ``iterations > 1`` the main program
        re-submits the same spec graph that many times with a root
        taskwait between iterations (the paper's epoch/timestep loop) —
        the shape record-and-replay (``replay=True``) exploits."""
        if iterations < 1:
            raise ValueError("iterations must be >= 1")
        P, costs = self.P, self.costs
        charge = SimCharger(costs)
        placement = make_placement(
            self.placement_kind, P,
            num_shards=(self.num_shards or P)
            if mode_uses_shards(self.mode) else None)
        policy = make_policy(
            self.mode, P,
            num_workers=P,
            params=self.params,
            placement=placement,
            charge=charge,
            main_slot=0,
            num_shards=self.num_shards or P,
            batch_size=self.batch_size,
            replay=self.replay)
        mgr_core = P - 1 if policy.needs_manager_thread else -1

        root = WorkDescriptor(func=None, label="sim-main")
        root.state = TaskState.RUNNING

        serial_us = 0.0
        total_tasks = 0
        stack_count = [list(specs)]
        while stack_count:
            for s in stack_count.pop():
                serial_us += s.dur
                total_tasks += 1
                if s.children:
                    stack_count.append(s.children)
        serial_us *= iterations
        total_tasks *= iterations

        trace: List[Tuple[float, int, int]] = []
        exec_order: List[str] = []

        # events: (time, seq, core, kind, wd). Kinds: "step" re-evaluates
        # the core's state machine; "fin" delivers a task-body completion
        # at its finish time (evaluating it eagerly at start time would
        # advance virtual locks into the future and stall every
        # earlier-timestamped acquirer — a causality violation).
        events: List[Tuple[float, int, int, str, Optional[WorkDescriptor]]] = []
        seq = [0]
        sleeping: set = set()
        finished = [False]
        makespan = [0.0]

        def schedule(t: float, core: int, kind: str = "step",
                     wd: Optional[WorkDescriptor] = None) -> None:
            heapq.heappush(events, (t, seq[0], core, kind, wd))
            seq[0] += 1

        def wake_all(t: float) -> None:
            for core in sorted(sleeping):
                schedule(t, core)
            sleeping.clear()

        def sample(t: float) -> None:
            if self.trace_enabled:
                trace.append((t, policy.in_graph(),
                              placement.ready_count()))

        # progs[core] = stack of creation frames [specs, idx, parent_wd];
        # parent_wd is None for the top-level (root) program frame.
        progs: Dict[int, List[List[Any]]] = {i: [] for i in range(P)}
        progs[0].append([list(specs), 0, None])

        # iteration (epoch) bookkeeping: cumulative snapshots taken at
        # each root quiescence, turned into per-iteration deltas below
        epoch = [0]
        iter_marks: List[Tuple[float, int, int]] = []

        def finish_epoch(core: int) -> None:
            t = max(makespan[0], charge.now)
            policy.notify_quiescent(True)
            iter_marks.append((t, charge.lock_acquisitions(),
                               policy.stats()["messages_processed"]))
            epoch[0] += 1
            if epoch[0] < iterations:
                progs[core].append([list(specs), 0, None])
                schedule(charge.now, core)
            else:
                finished[0] = True
                makespan[0] = t

        def run_worker(core: int) -> bool:
            """Pop + start one ready task on `core` at charge.now.
            Returns True if a task was started."""
            wd = placement.pop(core)
            if wd is None:
                return False
            t = charge.now
            dur = wd.duration * (costs.pollution
                                 if core in charge.polluted else 1.0)
            charge.polluted.discard(core)
            wd.mark_running()
            exec_order.append(wd.label)
            children = getattr(wd, "sim_children", None)
            if children:
                # parent body runs for `dur`, then the creation frame
                # takes over (children created after the body, as in the
                # threaded apps where the body IS the creation loop).
                progs[core].append([children, 0, wd])
                schedule(t + dur, core)
            else:
                schedule(t + dur, core, kind="fin", wd=wd)
            return True

        def step_core(core: int, t: float) -> None:
            charge.begin(core, t)
            if core == mgr_core:            # dedicated manager [7]
                n = policy.drain_all()
                if n:
                    sample(charge.now)
                    wake_all(charge.now)
                    schedule(charge.now, core)
                else:
                    sleeping.add(core)
                return
            stack = progs[core]
            if stack:
                frame = stack[-1]
                specs_, idx, parent = frame
                if idx < len(specs_):       # creation program
                    spec = specs_[idx]
                    frame[1] += 1
                    charge.create()
                    wd = WorkDescriptor(
                        func=None, deps=tuple(spec.deps), label=spec.label,
                        parent=parent if parent is not None else root)
                    wd.duration = spec.dur
                    wd.sim_children = spec.children
                    policy.submit(wd, core)
                    sample(charge.now)
                    wake_all(charge.now)
                    schedule(charge.now, core)
                    return
                # taskwait phase of this frame
                policy.flush(core)
                waiter = parent if parent is not None else root
                if waiter.num_children_alive == 0 and not policy.pending():
                    stack.pop()
                    if parent is not None:  # nested parent completes
                        policy.notify_quiescent(False)
                        parent.mark_finished()
                        placement.note_executed(parent, core)
                        policy.complete(parent, core)
                        sample(charge.now)
                        wake_all(charge.now)
                        schedule(charge.now, core)
                    else:                   # main program done (epoch)
                        finish_epoch(core)
                    return
                # blocked in taskwait: fall through and work
            if run_worker(core):
                return
            # idle: offer cycles to the policy (Listing 2) or sleep
            n = policy.idle_callback(core) \
                if policy.uses_idle_managers else 0
            if n or charge.now > t:
                sample(charge.now)
                wake_all(charge.now)
                schedule(charge.now, core)
            else:
                sleeping.add(core)

        for i in range(P):
            schedule(0.0, i)

        guard = 0
        while events and not finished[0]:
            t, _, core, kind, wd = heapq.heappop(events)
            makespan[0] = max(makespan[0], t)
            if kind == "fin":
                charge.begin(core, t)
                wd.mark_finished()
                placement.note_executed(wd, core)
                policy.complete(wd, core)
                sample(charge.now)
                wake_all(charge.now)
                schedule(charge.now, core)
            else:
                step_core(core, t)
            guard += 1
            if guard > 100_000_000:  # pragma: no cover
                raise RuntimeError("simulator exceeded event budget")

        st = policy.stats()
        iter_mk, iter_la, iter_msg = [], [], []
        prev = (0.0, 0, 0)
        for mark in iter_marks:
            iter_mk.append(mark[0] - prev[0])
            iter_la.append(mark[1] - prev[1])
            iter_msg.append(mark[2] - prev[2])
            prev = mark
        return SimResult(
            makespan_us=max(makespan[0], charge.max_free_at()),
            serial_us=serial_us,
            tasks=total_tasks,
            lock_wait_us=charge.lock_wait_us(),
            lock_acquisitions=charge.lock_acquisitions(),
            messages=st["messages_processed"],
            max_in_graph=st["max_in_graph"],
            total_edges=st["total_edges"],
            trace=trace,
            exec_order=exec_order,
            iterations=iterations,
            iter_makespans_us=iter_mk,
            iter_lock_acq=iter_la,
            iter_messages=iter_msg,
        )
