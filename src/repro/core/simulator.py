"""Deterministic discrete-event simulator of the task runtime.

This container exposes ONE physical core, so the paper's headline results
(speedup vs. 16-64 worker threads, Figs 9-11) cannot be measured with real
threads. The simulator reproduces them in *virtual time*: N virtual cores,
task durations in microseconds, critical sections serialized on virtual
locks, and the three runtime organizations:

  sync    Nanos++ baseline — graph mutated by workers under a global lock,
  dast    centralized manager thread [7] (P cores = P-1 workers + 1 manager),
  ddast   this paper — idle cores run the DDAST callback (Listing 2),
  sharded the core.shards extension — the graph is partitioned by region
          hash into S shards, each with its own virtual lock and mailbox;
          idle cores claim whole shards. A task spanning k shards splits
          its critical section k ways (base cost divided across portions,
          per-dep cost charged where the dep lives), mirroring the real
          runtime's join-latch protocol; lock waits are summed per shard.

Cost constants default to values calibrated from the real threaded runtime
on this machine (see benchmarks/bench_contention.py) and can be overridden.
The cache-pollution effect the paper measures (§6.1: task bodies ~33 %
faster under DDAST because workers stop touching runtime structures
between tasks) is modeled with a per-core pollution flag set by graph
operations and applied as a duration multiplier to the next task executed
by that core.

Everything is deterministic: no wall clock, no randomness — identical
inputs give identical makespans (required for hypothesis-based testing).
"""
from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .ddast import DDASTParams
from .shards import stable_region_hash
from .wd import DepMode

# ---------------------------------------------------------------------------


@dataclass
class SimTaskSpec:
    """One task in virtual time. `deps` = (region, DepMode) pairs; `dur` in
    microseconds; `children` makes this a nesting parent (N-Body style):
    the executing core creates the children, taskwaits on them (working as
    a normal worker meanwhile), then the parent completes."""
    dur: float
    deps: Sequence[Tuple[Any, DepMode]] = ()
    children: Optional[List["SimTaskSpec"]] = None
    label: str = "t"


@dataclass
class SimCosts:
    """Virtual-time costs (µs). Defaults calibrated on this host (see
    EXPERIMENTS.md §Paper/contention)."""
    create: float = 3.1        # WD alloc + arg capture (measured: 3.15us)
    push: float = 0.08         # SPSC queue push (measured: 0.076us)
    submit_cs: float = 2.0     # graph insert critical section (base)
    submit_cs_dep: float = 0.8    # ... plus this per declared dependence
    done_cs: float = 1.0       # graph completion critical section (base)
    done_cs_dep: float = 0.5   # ... plus this per dependence scrubbed
    msg_overhead: float = 0.25  # manager pop+dispatch per message
    lock_overhead: float = 0.12  # uncontended acquire/release
    idle_poll: float = 0.5     # idle re-poll period when nothing to do
    pollution: float = 1.25    # duration multiplier after graph ops (§6.1)


@dataclass
class SimResult:
    makespan_us: float
    serial_us: float
    tasks: int
    lock_wait_us: float = 0.0
    lock_acquisitions: int = 0
    messages: int = 0
    max_in_graph: int = 0
    trace: List[Tuple[float, int, int]] = field(default_factory=list)

    @property
    def speedup(self) -> float:
        return self.serial_us / self.makespan_us if self.makespan_us else 0.0


# ---------------------------------------------------------------------------


class _Task:
    __slots__ = ("spec", "tid", "preds", "succs", "state", "parent",
                 "pending_children", "shard_ids", "shard_parts",
                 "done_pending")

    def __init__(self, spec: SimTaskSpec, tid: int, parent: Optional["_Task"]):
        self.spec = spec
        self.tid = tid
        self.preds = 0
        self.succs: List["_Task"] = []
        self.state = "created"
        self.parent = parent
        self.pending_children = 0
        self.shard_ids: Tuple[int, ...] = ()   # sharded mode only
        self.shard_parts: Dict[int, list] = {}  # shard -> local deps
        self.done_pending = 0                  # sharded mode only


def _reg_collect_and_register(regions: Dict[Any, Tuple[Optional[_Task],
                                                       List[_Task]]],
                              task: _Task, deps) -> set:
    """The region dependence rules (same as depgraph.DependenceGraph):
    collect RAW/WAW/WAR predecessors of `task` from `regions`, then
    register it as last-writer/reader. Shared by the global virtual
    graph and the per-shard region maps so the rules live once."""
    preds = set()
    for region, mode in deps:
        lw, readers = regions.get(region, (None, []))
        if mode.reads and lw is not None:
            preds.add(lw)
        if mode.writes:
            if lw is not None:
                preds.add(lw)
            preds.update(readers)
        if mode.writes:
            regions[region] = (task, [])
        elif mode.reads:
            regions[region] = (lw, readers + [task])
    preds.discard(task)
    return preds


def _reg_scrub(regions: Dict[Any, Tuple[Optional[_Task], List[_Task]]],
               task: _Task, deps) -> None:
    """Remove a completed `task` from the region records (shared by the
    global virtual graph and the per-shard region maps)."""
    for region, mode in deps:
        ent = regions.get(region)
        if ent is None:
            continue
        lw, readers = ent
        if lw is task:
            lw = None
        if mode.reads and task in readers:
            readers = [r for r in readers if r is not task]
        if lw is None and not readers:
            regions.pop(region, None)
        else:
            regions[region] = (lw, readers)


class _VLock:
    """Virtual lock: serializes critical sections in virtual time
    (FIFO-handover approximation: acquirer waits until `free_at`)."""
    __slots__ = ("free_at", "wait_us", "acquisitions")

    def __init__(self) -> None:
        self.free_at = 0.0
        self.wait_us = 0.0
        self.acquisitions = 0

    def acquire(self, t: float, hold: float, overhead: float) -> float:
        start = max(t, self.free_at)
        self.wait_us += start - t
        self.acquisitions += 1
        end = start + hold + overhead
        self.free_at = end
        return end


class _Graph:
    """Virtual-time dependence graph — same rules as depgraph.DependenceGraph."""

    def __init__(self) -> None:
        self._regions: Dict[Any, Tuple[Optional[_Task], List[_Task]]] = {}
        self.in_graph = 0
        self.max_in_graph = 0

    def submit(self, task: _Task) -> bool:
        preds = _reg_collect_and_register(self._regions, task,
                                          task.spec.deps)
        live = [p for p in preds if p.state != "completed"]
        task.preds = len(live)
        for p in live:
            p.succs.append(task)
        self.in_graph += 1
        self.max_in_graph = max(self.max_in_graph, self.in_graph)
        task.state = "submitted"
        if task.preds == 0:
            task.state = "ready"
            return True
        return False

    def complete(self, task: _Task) -> List[_Task]:
        newly = []
        for s in task.succs:
            s.preds -= 1
            if s.preds == 0 and s.state == "submitted":
                s.state = "ready"
                newly.append(s)
        task.succs = []
        _reg_scrub(self._regions, task, task.spec.deps)
        self.in_graph -= 1
        task.state = "completed"
        return newly


# ---------------------------------------------------------------------------


class RuntimeSimulator:
    """Event-driven simulation of `TaskRuntime` on `num_cores` virtual cores.

    Core 0 runs the "main thread" program (creates the top-level tasks,
    then taskwaits, working as a normal worker while waiting) — the same
    structure as the real runtime and the paper's benchmarks.
    """

    def __init__(self, num_cores: int, mode: str = "ddast",
                 params: Optional[DDASTParams] = None,
                 costs: Optional[SimCosts] = None,
                 trace: bool = False,
                 num_shards: Optional[int] = None) -> None:
        assert mode in ("sync", "dast", "ddast", "sharded")
        self.P = num_cores
        self.mode = mode
        self.params = params or DDASTParams()
        self.costs = costs or SimCosts()
        self.trace_enabled = trace
        if num_shards is not None and num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        self.num_shards = num_shards

    # -- public ---------------------------------------------------------
    def run(self, specs: List[SimTaskSpec]) -> SimResult:
        c, mode, P, params = self.costs, self.mode, self.P, self.params
        max_mgr = (params.resolved_max_threads(P) if mode in ("ddast", "sharded")
                   else (1 if mode == "dast" else 0))
        dast_core = P - 1 if mode == "dast" else -1

        graph = _Graph()
        glock = _VLock()
        tid_counter = [0]
        total_tasks = [0]
        completed = [0]
        messages = [0]
        active_mgr = [0]
        polluted = [False] * P
        trace: List[Tuple[float, int, int]] = []
        serial_us = [0.0]

        def count_serial(specs_: Sequence[SimTaskSpec]) -> None:
            for s in specs_:
                serial_us[0] += s.dur
                total_tasks[0] += 1
                if s.children:
                    count_serial(s.children)
        count_serial(specs)

        submit_q: List[List[Tuple[float, _Task]]] = [[] for _ in range(P)]
        done_q: List[List[Tuple[float, _Task]]] = [[] for _ in range(P)]
        submit_busy = [False] * P
        ready: List[Tuple[float, int, _Task]] = []  # heap keyed by avail time

        # ---- sharded-mode state (mirrors core.shards) -----------------
        S = self.num_shards or P
        shard_locks = [_VLock() for _ in range(S)]
        # per-shard FIFO mailbox of (avail_time, kind, task); kind is
        # "sub" or "done"; deque so the head-first drain is O(1)
        shard_q: List[deque] = [deque() for _ in range(S)]
        shard_busy = [False] * S               # one manager per shard
        shard_regions: List[Dict[Any, Tuple[Optional[_Task], List[_Task]]]] = [
            {} for _ in range(S)]
        shard_succs: List[Dict[int, List[_Task]]] = [{} for _ in range(S)]
        in_graph_s = [0]
        max_in_graph_s = [0]

        def partition_task(task: _Task) -> None:
            """Hash each dep's region once; cache shard -> local deps
            (mirrors shards.partition_deps, same bare-region keying)."""
            parts: Dict[int, list] = {}
            for region, m in task.spec.deps:
                parts.setdefault(stable_region_hash(region) % S,
                                 []).append((region, m))
            task.shard_parts = parts
            task.shard_ids = tuple(parts)

        # events: (time, seq, core, finished_task_or_None). Task completion
        # must be delivered as an event at its finish time — evaluating it
        # eagerly at start time would advance the virtual lock's `free_at`
        # into the future and stall every earlier-timestamped acquirer
        # (a causality violation).
        events: List[Tuple[float, int, int, Optional[_Task]]] = []
        seq = [0]
        sleeping: set = set()

        def schedule(t: float, core: int, fin: Optional[_Task] = None) -> None:
            heapq.heappush(events, (t, seq[0], core, fin))
            seq[0] += 1

        def wake_all(t: float) -> None:
            while sleeping:
                schedule(t, sleeping.pop())

        def sample(t: float) -> None:
            if self.trace_enabled:
                ig = in_graph_s[0] if mode == "sharded" else graph.in_graph
                trace.append((t, ig, len(ready)))

        def make_task(spec: SimTaskSpec, parent: Optional[_Task]) -> _Task:
            task = _Task(spec, tid_counter[0], parent)
            tid_counter[0] += 1
            if parent is not None:
                parent.pending_children += 1
            return task

        # ---- graph operations in virtual time -------------------------
        def proc_submit(task: _Task, t: float) -> float:
            hold = c.submit_cs + c.submit_cs_dep * len(task.spec.deps)
            end = glock.acquire(t, hold, c.lock_overhead)
            if graph.submit(task):
                heapq.heappush(ready, (end, task.tid, task))
            sample(end)
            wake_all(end)
            return end

        def proc_done(task: _Task, t: float) -> float:
            hold = c.done_cs + c.done_cs_dep * len(task.spec.deps)
            end = glock.acquire(t, hold, c.lock_overhead)
            for s in graph.complete(task):
                heapq.heappush(ready, (end, s.tid, s))
            if task.parent is not None:
                task.parent.pending_children -= 1
            completed[0] += 1
            sample(end)
            wake_all(end)
            return end

        # ---- sharded graph operations in virtual time -----------------
        def proc_submit_shard(task: _Task, s: int, t: float) -> float:
            local = task.shard_parts[s]
            hold = (c.submit_cs / len(task.shard_ids)
                    + c.submit_cs_dep * len(local))
            end = shard_locks[s].acquire(t, hold, c.lock_overhead)
            preds = _reg_collect_and_register(shard_regions[s], task, local)
            for p in preds:
                shard_succs[s].setdefault(p.tid, []).append(task)
            # join-latch arithmetic: +local edges, -1 for this shard's
            # latch unit (task.preds was initialized to len(shard_ids))
            task.preds += len(preds) - 1
            if task.preds == 0:
                task.state = "ready"
                heapq.heappush(ready, (end, task.tid, task))
            sample(end)
            wake_all(end)
            return end

        def proc_done_shard(task: _Task, s: int, t: float) -> float:
            local = task.shard_parts[s]
            hold = (c.done_cs / len(task.shard_ids)
                    + c.done_cs_dep * len(local))
            end = shard_locks[s].acquire(t, hold, c.lock_overhead)
            _reg_scrub(shard_regions[s], task, local)
            for succ in shard_succs[s].pop(task.tid, []):
                succ.preds -= 1
                if succ.preds == 0 and succ.state == "submitted":
                    succ.state = "ready"
                    heapq.heappush(ready, (end, succ.tid, succ))
            task.done_pending -= 1
            if task.done_pending == 0:          # last shard portion
                task.state = "completed"
                in_graph_s[0] -= 1
                if task.parent is not None:
                    task.parent.pending_children -= 1
                completed[0] += 1
            sample(end)
            wake_all(end)
            return end

        def submit_task(core: int, task: _Task, t: float) -> float:
            if mode == "sync":
                polluted[core] = True
                return proc_submit(task, t)
            if mode == "sharded":
                partition_task(task)
                sids = task.shard_ids
                task.preds = len(sids)          # submit latch
                task.done_pending = len(sids)
                task.state = "submitted"
                in_graph_s[0] += 1
                max_in_graph_s[0] = max(max_in_graph_s[0], in_graph_s[0])
                tp = t + c.push
                if not sids:                    # dependence-free
                    task.state = "ready"
                    heapq.heappush(ready, (tp, task.tid, task))
                else:
                    for s in sids:
                        shard_q[s].append((tp, "sub", task))
                wake_all(tp)
                return tp
            submit_q[core].append((t + c.push, task))
            wake_all(t + c.push)
            return t + c.push

        def finish_task(core: int, task: _Task, t: float) -> float:
            task.state = "finished"
            if mode == "sync":
                polluted[core] = True
                return proc_done(task, t)
            if mode == "sharded":
                tp = t + c.push
                if not task.shard_ids:          # never entered any shard
                    task.state = "completed"
                    in_graph_s[0] -= 1
                    if task.parent is not None:
                        task.parent.pending_children -= 1
                    completed[0] += 1
                else:
                    for s in task.shard_ids:
                        shard_q[s].append((tp, "done", task))
                wake_all(tp)
                return tp
            done_q[core].append((t + c.push, task))
            wake_all(t + c.push)
            return t + c.push

        # ---- DDAST callback (Listing 2) in virtual time ---------------
        def run_callback(core: int, t: float) -> float:
            if active_mgr[0] >= max_mgr:
                return t
            active_mgr[0] += 1
            did_work = False
            spins = params.max_spins
            while True:
                total_cnt = 0
                for w in range(P):
                    if len(ready) >= params.min_ready_tasks:
                        break
                    cnt = 0
                    if not submit_busy[w]:
                        submit_busy[w] = True
                        while (cnt < params.max_ops_thread and submit_q[w]
                               and submit_q[w][0][0] <= t):
                            _, task = submit_q[w].pop(0)
                            t = proc_submit(task, t + c.msg_overhead)
                            messages[0] += 1
                            cnt += 1
                        submit_busy[w] = False
                    while (cnt < params.max_ops_thread and done_q[w]
                           and done_q[w][0][0] <= t):
                        _, task = done_q[w].pop(0)
                        t = proc_done(task, t + c.msg_overhead)
                        messages[0] += 1
                        cnt += 1
                    total_cnt += cnt
                if total_cnt:
                    did_work = True
                spins = (spins - 1) if total_cnt == 0 else params.max_spins
                if spins == 0 or len(ready) >= params.min_ready_tasks:
                    break
            active_mgr[0] -= 1
            if did_work:
                polluted[core] = True
            return t

        # ---- sharded callback: idle cores claim whole shards ----------
        def run_callback_sharded(core: int, t: float) -> float:
            if active_mgr[0] >= max_mgr:
                return t
            active_mgr[0] += 1
            did_work = False
            spins = params.max_spins
            while True:
                total_cnt = 0
                for off in range(S):
                    if len(ready) >= params.min_ready_tasks:
                        break
                    s = (core + off) % S        # spread managers out
                    if shard_busy[s]:
                        continue
                    shard_busy[s] = True
                    cnt = 0
                    while (cnt < params.max_ops_thread and shard_q[s]
                           and shard_q[s][0][0] <= t):
                        _, kind, task = shard_q[s].popleft()
                        proc = (proc_submit_shard if kind == "sub"
                                else proc_done_shard)
                        t = proc(task, s, t + c.msg_overhead)
                        messages[0] += 1
                        cnt += 1
                    shard_busy[s] = False
                    total_cnt += cnt
                if total_cnt:
                    did_work = True
                spins = (spins - 1) if total_cnt == 0 else params.max_spins
                if spins == 0 or len(ready) >= params.min_ready_tasks:
                    break
            active_mgr[0] -= 1
            if did_work:
                polluted[core] = True
            return t

        def drain_dast(t: float) -> float:
            progress = True
            t2 = t
            while progress:
                progress = False
                for w in range(P):
                    while submit_q[w] and submit_q[w][0][0] <= t2:
                        _, task = submit_q[w].pop(0)
                        t2 = proc_submit(task, t2 + c.msg_overhead)
                        messages[0] += 1
                        progress = True
                    while done_q[w] and done_q[w][0][0] <= t2:
                        _, task = done_q[w].pop(0)
                        t2 = proc_done(task, t2 + c.msg_overhead)
                        messages[0] += 1
                        progress = True
            return t2

        # ---- core state machine ---------------------------------------
        # progs[core] = stack of creation frames [specs, idx, parent]
        progs: Dict[int, List[List[Any]]] = {i: [] for i in range(P)}
        progs[0].append([list(specs), 0, None])

        def earliest_msg() -> Optional[float]:
            best: Optional[float] = None
            if mode == "sharded":
                for s in range(S):
                    q = shard_q[s]
                    if q and (best is None or q[0][0] < best):
                        best = q[0][0]
                return best
            for w in range(P):
                for q in (submit_q[w], done_q[w]):
                    if q and (best is None or q[0][0] < best):
                        best = q[0][0]
            return best

        def step_core(core: int, t: float) -> None:
            if core == dast_core:               # dedicated manager [7]
                t2 = drain_dast(t)
                if t2 > t:
                    schedule(t2, core)
                else:
                    nxt = earliest_msg()
                    if nxt is not None and nxt > t:
                        schedule(nxt, core)
                    else:
                        sleeping.add(core)
                return
            # 1. creation-program work (main thread / nesting parents)
            stack = progs[core]
            if stack:
                frame = stack[-1]
                specs_, idx, parent = frame
                if idx < len(specs_):
                    spec = specs_[idx]
                    frame[1] += 1
                    task = make_task(spec, parent)
                    schedule(submit_task(core, task, t + c.create), core)
                    return
                # taskwait phase of this frame
                pend = (parent.pending_children if parent is not None
                        else total_tasks[0] - completed[0])
                if pend == 0:
                    stack.pop()
                    if parent is not None:
                        schedule(finish_task(core, parent, t), core)
                        return
                    schedule(t, core)  # main program done; loop re-checks
                    return
                # blocked in taskwait: fall through and work
            # 2. worker behavior
            if ready and ready[0][0] <= t:
                task = heapq.heappop(ready)[2]
                dur = task.spec.dur * (c.pollution if polluted[core] else 1.0)
                polluted[core] = False
                if task.spec.children:
                    task.state = "running"
                    stack.append([list(task.spec.children), 0, task])
                    schedule(t + dur, core)     # parent body, then children
                else:
                    schedule(t + dur, core, fin=task)   # finish event
                return
            if ready:                            # ready item not visible yet
                schedule(ready[0][0], core)
                return
            # 3. idle: become a manager (ddast/sharded) or sleep until
            # state change
            if mode in ("ddast", "sharded"):
                cb = run_callback if mode == "ddast" else run_callback_sharded
                t2 = cb(core, t)
                if t2 > t:
                    schedule(t2, core)
                    return
                nxt = earliest_msg()
                if nxt is not None and nxt > t:
                    schedule(nxt, core)
                    return
            sleeping.add(core)

        for i in range(P):
            schedule(0.0, i)

        makespan = 0.0
        guard = 0
        while events:
            t, _, core, fin = heapq.heappop(events)
            if completed[0] >= total_tasks[0] and not progs[0]:
                makespan = max(makespan, t)
                break
            if fin is not None:
                schedule(finish_task(core, fin, t), core)
            else:
                step_core(core, t)
            makespan = max(makespan, t)
            guard += 1
            if guard > 100_000_000:  # pragma: no cover
                raise RuntimeError("simulator exceeded event budget")

        if mode == "sharded":
            makespan = max(makespan, *(l.free_at for l in shard_locks))
            return SimResult(
                makespan_us=makespan,
                serial_us=serial_us[0],
                tasks=total_tasks[0],
                lock_wait_us=sum(l.wait_us for l in shard_locks),
                lock_acquisitions=sum(l.acquisitions for l in shard_locks),
                messages=messages[0],
                max_in_graph=max_in_graph_s[0],
                trace=trace,
            )
        makespan = max(makespan, glock.free_at)
        return SimResult(
            makespan_us=makespan,
            serial_us=serial_us[0],
            tasks=total_tasks[0],
            lock_wait_us=glock.wait_us,
            lock_acquisitions=glock.acquisitions,
            messages=messages[0],
            max_in_graph=graph.max_in_graph,
            trace=trace,
        )
