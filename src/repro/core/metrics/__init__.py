"""Live metrics plane (`ISSUE 10`): lock-free per-slot instruments, a
dispatcher-riding time-series sampler, per-scope SLO attainment, and
Prometheus / Perfetto exporters.

Layering: this package imports nothing from the rest of ``repro.core``
(so ``scopes``, ``runtime``, ``procs`` and ``serve`` can all depend on
it without cycles). The incremental detector lives in ``core.trace``
next to its batch siblings; the sampler takes it by injection.
"""
from .instruments import (LogHistogram, MetricsHub, NullMetricsHub,
                          NULL_METRICS, SlotCounter, SlotGauge)
from .sampler import MetricsSampler
from .export import (counter_track_events, load_metrics,
                     prometheus_text, save_metrics)
from .shm_plane import PLANE_FIELDS, ShmCounterPlane, WorkerCounterView

__all__ = [
    "LogHistogram", "MetricsHub", "NullMetricsHub", "NULL_METRICS",
    "SlotCounter", "SlotGauge",
    "MetricsSampler",
    "counter_track_events", "load_metrics", "prometheus_text",
    "save_metrics",
    "PLANE_FIELDS", "ShmCounterPlane", "WorkerCounterView",
]
