"""Live instruments: per-slot counters, gauges and log-bucketed
latency histograms.

Same discipline as ``core.trace.recorder``: every hot-path write is a
single GIL-atomic operation on a slot owned by exactly one thread (a
plain ``list.__setitem__`` / int ``+=`` on CPython is one bytecode-level
store under the GIL, and per-slot single-writer means there is nothing
to race even without it), and the disabled path is one attribute check
on a shared ``NULL_METRICS`` singleton. Aggregation — summing slots,
merging histograms — happens lazily at read time on whichever thread
asks, never on the task path. Zero locks are introduced anywhere in
this module.

The histogram is HDR-style log-bucketed: values are quantized to a
``resolution``, small values get exact buckets, larger values land in
buckets of 4 per power of two, so the relative bucket width is bounded
by 25% at any magnitude. Buckets are a sparse dict (most workloads
touch a handful), merge is element-wise addition (associative and
commutative — the property the merge tests gate), and quantiles report
the bucket's upper bound, so ``quantile(q)`` is always >= the exact
q-quantile and <= ``exact * 1.25 + resolution``.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

__all__ = ["LogHistogram", "SlotCounter", "SlotGauge", "MetricsHub",
           "NullMetricsHub", "NULL_METRICS"]


class LogHistogram:
    """Sparse log-bucketed histogram. Single-writer (``record``) per
    instance; any thread may snapshot/merge (worst case it reads a
    torn-but-valid partial count, same contract as the tracer)."""

    __slots__ = ("resolution", "counts", "count", "total", "min", "max")

    def __init__(self, resolution: float = 1e-6) -> None:
        self.resolution = resolution
        self.counts: Dict[int, int] = {}
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = 0.0

    # -- bucket math ----------------------------------------------------
    @staticmethod
    def _index(v: int) -> int:
        # v is the quantized value (units of `resolution`), >= 0.
        # 0..3 exact; beyond that 4 buckets per power of two: the
        # exponent e = bit_length-3 keeps the top 3 bits, mantissa 4..7.
        if v < 4:
            return v
        e = v.bit_length() - 3
        return 4 * (e + 1) + ((v >> e) - 4)

    def _bounds(self, idx: int) -> tuple:
        """(lo, hi) of bucket ``idx`` in value units; hi is exclusive
        and is the conservative quantile answer."""
        if idx < 4:
            lo, hi = idx, idx + 1
        else:
            e = idx // 4 - 1
            m = idx % 4 + 4
            lo = m << e
            hi = (m + 1) << e
        return lo * self.resolution, hi * self.resolution

    # -- hot path -------------------------------------------------------
    def record(self, value: float) -> None:
        v = int(value / self.resolution)
        if v < 0:
            v = 0
        idx = self._index(v)
        c = self.counts
        c[idx] = c.get(idx, 0) + 1
        self.count += 1
        self.total += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value

    # -- read side ------------------------------------------------------
    def merge(self, other: "LogHistogram") -> "LogHistogram":
        """Element-wise sum into a NEW histogram (inputs untouched).
        Requires equal resolutions; associative and commutative."""
        if other.resolution != self.resolution:
            raise ValueError("histogram resolutions differ: "
                             f"{self.resolution} vs {other.resolution}")
        out = LogHistogram(self.resolution)
        out.counts = dict(self.counts)
        for idx, n in other.counts.items():
            out.counts[idx] = out.counts.get(idx, 0) + n
        out.count = self.count + other.count
        out.total = self.total + other.total
        out.min = min(self.min, other.min)
        out.max = max(self.max, other.max)
        return out

    def quantile(self, q: float) -> float:
        """Conservative q-quantile: upper bound of the bucket holding
        the ceil(q*count)-th sample. 0.0 when empty."""
        if self.count == 0:
            return 0.0
        q = min(max(q, 0.0), 1.0)
        target = max(int(q * self.count + 0.999999), 1)
        seen = 0
        for idx in sorted(self.counts):
            seen += self.counts[idx]
            if seen >= target:
                return self._bounds(idx)[1]
        return self._bounds(max(self.counts))[1]

    def snapshot(self) -> Dict[str, object]:
        """JSON-friendly view: sorted ``[lo, hi, n]`` bucket rows plus
        the scalar moments."""
        rows = [[*self._bounds(idx), n]
                for idx, n in sorted(self.counts.items())]
        return {"count": self.count,
                "sum": self.total,
                "min": self.min if self.count else 0.0,
                "max": self.max,
                "resolution": self.resolution,
                "buckets": rows}

    @staticmethod
    def merge_all(hists: List["LogHistogram"]) -> "LogHistogram":
        if not hists:
            return LogHistogram()
        out = hists[0]
        for h in hists[1:]:
            out = out.merge(h)
        return out


class SlotCounter:
    """Monotonic per-slot counter; writes from slot *i* only ever touch
    ``per_slot[i]`` (GIL-atomic), reads sum lazily. Index ``num_slots``
    is the shared overflow slot for unattributed writers (same layout
    as the tracer's overflow ring)."""

    __slots__ = ("per_slot",)

    def __init__(self, num_slots: int) -> None:
        self.per_slot: List[int] = [0] * (num_slots + 1)

    def add(self, slot: int, delta: int = 1) -> None:
        p = self.per_slot
        n = len(p) - 1
        p[slot if 0 <= slot < n else n] += delta

    @property
    def total(self) -> int:
        return sum(self.per_slot)


class SlotGauge:
    """Per-slot last-value gauge (e.g. busy flags); ``total`` sums."""

    __slots__ = ("per_slot",)

    def __init__(self, num_slots: int) -> None:
        self.per_slot: List[float] = [0.0] * (num_slots + 1)

    def set(self, slot: int, value: float) -> None:
        p = self.per_slot
        n = len(p) - 1
        p[slot if 0 <= slot < n else n] = value

    @property
    def total(self) -> float:
        return sum(self.per_slot)


class MetricsHub:
    """The driver-side instrument bundle: task start/finish counters,
    busy flags, summed exec time and a latency histogram — all per
    slot, all single-writer, aggregated only in :meth:`snapshot`.

    ``charge`` is the simulator's :class:`SimCharger` (or ``None`` on
    real drivers): each instrument write prices one ``metric_event`` of
    local virtual time so the overhead gate measures a real cost, the
    same contract as ``TraceRecorder``.
    """

    enabled = True

    def __init__(self, num_slots: int, clock: Callable[[], float],
                 charge=None, time_unit: str = "s",
                 latency_resolution: Optional[float] = None) -> None:
        self.num_slots = num_slots
        self.clock = clock
        self.time_unit = time_unit
        self._charge = charge
        if latency_resolution is None:
            latency_resolution = 1.0 if time_unit == "us" else 1e-6
        self.tasks_started = [0] * (num_slots + 1)
        self.tasks_finished = [0] * (num_slots + 1)
        self.exec_time = [0.0] * (num_slots + 1)
        self.busy = [0] * (num_slots + 1)
        self.latency = [LogHistogram(latency_resolution)
                        for _ in range(num_slots + 1)]

    def _clamp(self, slot: int) -> int:
        return slot if 0 <= slot < self.num_slots else self.num_slots

    # -- hot path -------------------------------------------------------
    def task_start(self, slot: int) -> None:
        s = self._clamp(slot)
        self.tasks_started[s] += 1
        self.busy[s] = 1
        ch = self._charge
        if ch is not None:
            ch.metric_event()

    def task_end(self, slot: int, dur: float) -> None:
        s = self._clamp(slot)
        self.tasks_finished[s] += 1
        self.exec_time[s] += dur
        self.latency[s].record(dur)
        self.busy[s] = 0
        ch = self._charge
        if ch is not None:
            ch.metric_event()

    # -- read side ------------------------------------------------------
    def busy_fraction(self, num_workers: Optional[int] = None) -> float:
        n = num_workers if num_workers is not None else self.num_slots
        if n <= 0:
            return 0.0
        return sum(self.busy[:n]) / n

    def snapshot(self) -> Dict[str, object]:
        merged = LogHistogram.merge_all(list(self.latency))
        return {
            "time_unit": self.time_unit,
            "counters": {
                "tasks_started": {"total": sum(self.tasks_started),
                                  "per_slot": list(self.tasks_started)},
                "tasks_finished": {"total": sum(self.tasks_finished),
                                   "per_slot": list(self.tasks_finished)},
            },
            "exec_time": {"total": sum(self.exec_time),
                          "per_slot": list(self.exec_time)},
            "busy_slots": list(self.busy),
            "task_latency": merged.snapshot(),
        }


class NullMetricsHub:
    """Metrics-off singleton: one ``.enabled`` check is the entire
    disabled-path cost (gated by the no-op cost test)."""

    enabled = False
    num_slots = 0

    def task_start(self, slot: int) -> None:
        pass

    def task_end(self, slot: int, dur: float) -> None:
        pass

    def busy_fraction(self, num_workers=None) -> float:
        return 0.0

    def snapshot(self) -> Dict[str, object]:
        return {}


NULL_METRICS = NullMetricsHub()
