"""Exporters: Prometheus text exposition and Perfetto counter tracks.

Both consume the JSON-friendly snapshots produced by
``MetricsHub.snapshot`` / ``TaskRuntime.metrics`` /
``ServeEngine.metrics_snapshot`` — exporters never touch live
instruments, so they can run in another process entirely
(``repro.analysis.metricsview``).

Prometheus exposition follows the text format 0.0.4: counters get a
``_total`` suffix with ``{slot="i"}`` labels, log-bucket histograms are
flattened to cumulative ``_bucket{le="..."}`` rows plus ``_sum`` /
``_count``, per-scope series carry a ``scope`` label, per-client ones a
``client`` label. The Perfetto exporter renders every sampled series as
a Chrome-trace "C" (counter) event stream on its own pid, so
``traceview --counters`` can merge them under the task slices.
"""
from __future__ import annotations

import json
import re
from typing import Dict, List

__all__ = ["prometheus_text", "counter_track_events",
           "save_metrics", "load_metrics"]

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _san(name: str) -> str:
    name = _NAME_RE.sub("_", name)
    if not name or name[0].isdigit():
        name = "_" + name
    return name


def _fmt(v) -> str:
    if v == float("inf"):
        return "+Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def _hist_lines(name: str, hist: Dict[str, object],
                labels: str = "") -> List[str]:
    """Flatten a LogHistogram snapshot to cumulative le-buckets."""
    base = labels[:-1] + "," if labels else "{"
    out = [f"# TYPE {name} histogram"]
    cum = 0
    for lo, hi, n in hist.get("buckets", []):
        cum += n
        out.append(f'{name}_bucket{base}le="{_fmt(hi)}"}} {cum}')
    out.append(f'{name}_bucket{base}le="+Inf"}} {hist.get("count", 0)}')
    out.append(f"{name}_sum{labels} {_fmt(hist.get('sum', 0.0))}")
    out.append(f"{name}_count{labels} {hist.get('count', 0)}")
    return out


def prometheus_text(snapshot: Dict[str, object],
                    prefix: str = "repro") -> str:
    """Render any runtime/sim/serve metrics snapshot. Tolerant: only
    sections that are present are emitted."""
    L: List[str] = []
    unit = "us" if snapshot.get("time_unit") == "us" else "seconds"

    for cname, c in (snapshot.get("counters") or {}).items():
        mname = f"{prefix}_{_san(cname)}_total"
        L.append(f"# TYPE {mname} counter")
        if isinstance(c, dict) and "per_slot" in c:
            for i, v in enumerate(c["per_slot"]):
                L.append(f'{mname}{{slot="{i}"}} {_fmt(v)}')
        else:
            tot = c.get("total", c) if isinstance(c, dict) else c
            L.append(f"{mname} {_fmt(tot)}")

    for gname, g in (snapshot.get("gauges") or {}).items():
        mname = f"{prefix}_{_san(gname)}"
        L.append(f"# TYPE {mname} gauge")
        if isinstance(g, dict):
            for k, v in g.items():
                L.append(f'{mname}{{key="{_san(str(k))}"}} {_fmt(v)}')
        else:
            L.append(f"{mname} {_fmt(g)}")

    lat = snapshot.get("task_latency")
    if lat and lat.get("count", 0) >= 0:
        L += _hist_lines(f"{prefix}_task_latency_{unit}", lat)

    for sname, entry in (snapshot.get("scopes") or {}).items():
        lab = f'{{scope="{_san(str(sname))}"}}'
        for k in ("inflight", "tasks_alive"):
            if k in entry:
                L.append(f"{prefix}_scope_{k}{lab} {_fmt(entry[k])}")
        adm = entry.get("admission") or {}
        for k in ("admitted", "admission_waits", "drained",
                  "contended_grants"):
            if k in adm:
                L.append(f"{prefix}_scope_{k}_total{lab} {_fmt(adm[k])}")
        slo = entry.get("slo")
        if slo:
            L.append(f"{prefix}_scope_slo_met_total{lab} "
                     f"{_fmt(slo['met'])}")
            L.append(f"{prefix}_scope_slo_missed_total{lab} "
                     f"{_fmt(slo['missed'])}")
            att = slo.get("attainment")
            if att is not None:
                L.append(f"{prefix}_scope_slo_attainment{lab} "
                         f"{_fmt(att)}")
            if slo.get("slack"):
                L += _hist_lines(f"{prefix}_scope_slack_{unit}",
                                 slo["slack"], lab)

    for cname, entry in (snapshot.get("clients") or {}).items():
        lab = f'{{client="{_san(str(cname))}"}}'
        if entry.get("latency_steps"):
            L += _hist_lines(f"{prefix}_request_latency_steps",
                             entry["latency_steps"], lab)
        adm = entry.get("admission") or {}
        for k in ("admitted", "admission_waits", "drained"):
            if k in adm:
                L.append(f"{prefix}_client_{k}_total{lab} "
                         f"{_fmt(adm[k])}")
        slo = entry.get("slo")
        if slo:
            att = slo.get("attainment")
            if att is not None:
                L.append(f"{prefix}_client_slo_attainment{lab} "
                         f"{_fmt(att)}")
            L.append(f"{prefix}_client_slo_met_total{lab} "
                     f"{_fmt(slo['met'])}")
            L.append(f"{prefix}_client_slo_missed_total{lab} "
                     f"{_fmt(slo['missed'])}")

    workers = snapshot.get("workers") or {}
    if workers.get("totals"):
        for k, v in workers["totals"].items():
            L.append(f"# TYPE {prefix}_worker_{_san(k)} counter")
            L.append(f"{prefix}_worker_{_san(k)} {_fmt(v)}")
        for i, row in enumerate(workers.get("per_worker", [])):
            for k, v in row.items():
                L.append(f'{prefix}_worker_{_san(k)}_slot'
                         f'{{worker="{i}"}} {_fmt(v)}')

    samp = snapshot.get("sampler") or {}
    series = samp.get("series") or {}
    if series:
        mname = f"{prefix}_sampled"
        L.append(f"# TYPE {mname} gauge")
        for sname in sorted(series):
            pts = series[sname]
            if pts:
                L.append(f'{mname}{{series="{_san(sname)}"}} '
                         f"{_fmt(pts[-1][1])}")
    if "samples" in samp:
        L.append(f"{prefix}_sampler_samples_total {samp['samples']}")

    return "\n".join(L) + "\n"


def counter_track_events(series: Dict[str, list], time_unit: str = "s",
                         pid: int = 2,
                         process_name: str = "metrics") -> List[dict]:
    """Render sampled series as Chrome-trace counter ("C") events.
    Chrome-trace timestamps are microseconds, so seconds scale by 1e6
    and simulator microseconds pass through — the same ``_scale`` rule
    as ``analysis.traceview``."""
    k = 1e6 if time_unit == "s" else 1.0
    out: List[dict] = [{"name": "process_name", "ph": "M", "pid": pid,
                        "tid": 0, "args": {"name": process_name}}]
    for name in sorted(series):
        for t, v in series[name]:
            out.append({"name": name, "ph": "C", "pid": pid, "tid": 0,
                        "ts": t * k, "args": {"value": v}})
    return out


def save_metrics(path: str, snapshot: Dict[str, object]) -> None:
    with open(path, "w") as f:
        json.dump(snapshot, f, indent=1)


def load_metrics(path: str) -> Dict[str, object]:
    with open(path) as f:
        return json.load(f)
