"""Shared-memory counter plane for the process backend.

Worker processes cannot write parent-side instrument lists, and
shipping counter updates over the mailbox rings would add IPC frames
to the hot path — the exact cost the metrics plane promises not to
pay. Instead the parent allocates one tiny shm segment laid out as a
``num_workers x len(FIELDS)`` float64 matrix; worker *i* writes only
row *i* (single-writer, so a plain 8-byte store is the whole
protocol — no lock, no fence beyond the hardware's natural aligned-
store atomicity, and a torn read would merely smear one sample), and
the parent scrapes the matrix at sampling time with zero extra IPC
frames.

Ownership follows the ring discipline (``procs.rings``): the parent
creates and is the sole unlinker; workers attach by name and close
without unlinking. A torn float64 is not possible on any platform we
run on (aligned 8-byte stores), and even a stale row only delays one
sample by one scrape.
"""
from __future__ import annotations

from multiprocessing import shared_memory
from typing import Dict, List

__all__ = ["PLANE_FIELDS", "ShmCounterPlane", "WorkerCounterView"]

#: column layout of one worker row (all float64)
PLANE_FIELDS = ("tasks_started", "tasks_finished", "exec_time_s", "busy")
_NF = len(PLANE_FIELDS)


class ShmCounterPlane:
    """Parent side: create, scrape, unlink."""

    def __init__(self, num_workers: int) -> None:
        self.num_workers = num_workers
        size = 8 * _NF * max(num_workers, 1)
        self.shm = shared_memory.SharedMemory(create=True, size=size)
        self.shm.buf[:size] = b"\x00" * size
        self.name = self.shm.name
        self._d = self.shm.buf.cast("d")

    # -- read side ------------------------------------------------------
    def row(self, widx: int) -> Dict[str, float]:
        base = widx * _NF
        d = self._d
        return {f: d[base + i] for i, f in enumerate(PLANE_FIELDS)}

    def totals(self) -> Dict[str, float]:
        out = dict.fromkeys(PLANE_FIELDS, 0.0)
        d = self._d
        for w in range(self.num_workers):
            base = w * _NF
            for i, f in enumerate(PLANE_FIELDS):
                out[f] += d[base + i]
        return out

    def busy_count(self) -> int:
        d = self._d
        return sum(1 for w in range(self.num_workers)
                   if d[w * _NF + PLANE_FIELDS.index("busy")] > 0.0)

    def snapshot(self) -> Dict[str, object]:
        rows: List[Dict[str, float]] = [self.row(w)
                                        for w in range(self.num_workers)]
        return {"per_worker": rows, "totals": self.totals()}

    def close_unlink(self) -> None:
        try:
            self._d.release()
        except (BufferError, ValueError):
            pass
        try:
            self.shm.close()
        except (BufferError, OSError):
            pass
        try:
            self.shm.unlink()
        except (FileNotFoundError, OSError):
            pass


class WorkerCounterView:
    """Worker side: attach by name, stamp row ``widx`` only."""

    __slots__ = ("shm", "_d", "_base")

    def __init__(self, name: str, widx: int) -> None:
        # plain attach: every attacher is a multiprocessing child of
        # the creator, so the shared resource_tracker re-register is a
        # no-op (see procs.rings.attach_shm for the bpo-39959 story)
        self.shm = shared_memory.SharedMemory(name=name)
        self._d = self.shm.buf.cast("d")
        self._base = widx * _NF

    # -- hot path (one aligned f64 store per field) ---------------------
    def task_start(self) -> None:
        b = self._base
        d = self._d
        d[b + 0] += 1.0              # tasks_started
        d[b + 3] = 1.0               # busy

    def task_end(self, dur_s: float) -> None:
        b = self._base
        d = self._d
        d[b + 1] += 1.0              # tasks_finished
        d[b + 2] += dur_s            # exec_time_s
        d[b + 3] = 0.0               # busy

    def close(self) -> None:
        try:
            self._d.release()
        except (BufferError, ValueError):
            pass
        try:
            self.shm.close()
        except (BufferError, OSError):
            pass
