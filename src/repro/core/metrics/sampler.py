"""Time-series sampler: periodic snapshots of derived runtime signals
into bounded rings, riding the FunctionalityDispatcher.

The sampler owns no thread. It registers an idle callback and a
quiescent callback on the dispatcher, so — per the paper's DDAST
discipline — whichever worker is already idle takes the sample; on the
process backend the reaper loop ticks it between ring polls. ``tick``
is rate-limited by a wall/virtual-clock interval checked *before* a
non-blocking try-lock, so concurrent idle workers never serialize
behind a sample in progress: losers return immediately (the lock is a
mutual-exclusion guard on the read-side aggregation only — no task
hot-path ever touches it).

Probes are plain callables registered at runtime construction; each
returns a scalar (one series) or a ``{sub_name: scalar}`` dict (one
series per key — used for per-slot ready depth and per-scope
inflight). Series are bounded ``deque(maxlen=window)`` rings of
``(t, value)`` pairs.

The sampler optionally carries an :class:`IncrementalDetector`
(``core.trace.detect``): every sample with fresh trace events sweeps
the detectors over the live window and forwards *new* findings to the
``on_findings`` hook — this is how ``DynamicTuner`` gets starvation /
inversion verdicts mid-phase instead of only at quiescence.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

__all__ = ["MetricsSampler"]


class MetricsSampler:
    def __init__(self, clock: Callable[[], float], interval: float,
                 window: int = 512, charge=None, tracer=None,
                 detector=None,
                 on_findings: Optional[Callable[[list], object]] = None
                 ) -> None:
        self.clock = clock
        self.interval = interval
        self.window = window
        self._charge = charge
        self.tracer = tracer
        self.detector = detector
        self.on_findings = on_findings
        self._probes: List[Tuple[str, Callable[[], object]]] = []
        self.series: Dict[str, deque] = {}
        self.samples = 0
        self._last: Optional[float] = None
        self._tick_lock = threading.Lock()
        self._trace_seen = 0
        self.live_findings: list = []

    def add_probe(self, name: str, fn: Callable[[], object]) -> None:
        self._probes.append((name, fn))

    # -- dispatcher hooks ----------------------------------------------
    def callback(self, worker_id: int) -> int:
        """Idle-worker hook: at most one sample per interval."""
        del worker_id
        return 1 if self.tick() else 0

    def quiescent_callback(self, worker_id: int) -> int:
        """Quiescence hook: always sample — phase boundaries are the
        points the post-hoc pipeline already anchors on."""
        del worker_id
        return 1 if self.tick(force=True) else 0

    # -- sampling -------------------------------------------------------
    def tick(self, force: bool = False) -> bool:
        t = self.clock()
        last = self._last
        if not force and last is not None and t - last < self.interval:
            return False
        if not self._tick_lock.acquire(False):
            return False                 # someone else is sampling
        try:
            last = self._last            # re-check under the guard
            if not force and last is not None \
                    and t - last < self.interval:
                return False
            self._last = t
            self._sample(t)
            self._sweep()
            return True
        finally:
            self._tick_lock.release()

    def _sample(self, t: float) -> None:
        self.samples += 1
        ch = self._charge
        if ch is not None:
            ch.metric_sample()
        for name, fn in self._probes:
            try:
                val = fn()
            except Exception:
                continue                 # a dying probe never kills a tick
            if isinstance(val, dict):
                for sub, v in val.items():
                    self._append(f"{name}.{sub}", t, v)
            elif val is not None:
                self._append(name, t, val)

    def _append(self, name: str, t: float, v) -> None:
        ring = self.series.get(name)
        if ring is None:
            ring = self.series[name] = deque(maxlen=self.window)
        ring.append((t, float(v)))

    def _sweep(self) -> None:
        det, tr = self.detector, self.tracer
        if det is None or tr is None or not getattr(tr, "enabled", False):
            return
        appended = tr.total_appended
        if appended <= self._trace_seen:
            return                       # no fresh events since last sweep
        self._trace_seen = appended
        fresh = det.sweep(tr.events())
        if fresh:
            self.live_findings.extend(fresh)
            cb = self.on_findings
            if cb is not None:
                try:
                    cb(fresh)
                except Exception:
                    pass

    # -- read side ------------------------------------------------------
    def snapshot(self) -> Dict[str, object]:
        # serialize against ticks: iterating a deque a concurrent
        # sample is appending to raises. Ticks never block on this —
        # they try-lock and skip (one missed sample per racing read).
        with self._tick_lock:
            return {
                "interval": self.interval,
                "window": self.window,
                "samples": self.samples,
                "series": {name: [[t, v] for t, v in ring]
                           for name, ring in self.series.items()},
                "live_findings": [
                    {"kind": f.kind, "t0": f.t0, "t1": f.t1,
                     "slot": f.slot, "count": f.count}
                    for f in self.live_findings],
            }
