"""Per-worker message queues (paper §3.1, Fig. 3).

Each worker owns one Submit queue and one Done ("others") queue:
  * only the owning worker pushes (single producer),
  * only manager threads pop (possibly several for Done; exactly one at a
    time for Submit — enforced with a try-acquire flag, Listing 2 line 8).

CPython's ``collections.deque`` append/popleft are atomic, giving the
lock-free SPSC/MPMC push/pop the paper's C++ queues provide; the Submit
drain-exclusivity is the only extra synchronization, exactly as in the
paper.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Generic, Optional, TypeVar

T = TypeVar("T")


class InstrumentedLock:
    """Lock that records contention (acquisitions + wait time).

    Used for the global graph lock in ``sync`` mode and for each shard
    lock in ``sharded`` mode, so per-organization lock-wait numbers are
    directly comparable (the paper's §1 motivation metric).
    """

    __slots__ = ("_lock", "acquisitions", "wait_s")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.acquisitions = 0
        self.wait_s = 0.0

    def __enter__(self) -> "InstrumentedLock":
        t0 = time.perf_counter()
        self._lock.acquire()
        self.wait_s += time.perf_counter() - t0
        self.acquisitions += 1
        return self

    def __exit__(self, *exc) -> bool:
        self._lock.release()
        return False

    # -- delegation/combining fast path --------------------------------
    def try_acquire(self) -> bool:
        """Non-blocking acquire: counts the acquisition on success and
        never accrues wait time — a failed trylock is exactly the wait
        the delegation/combining protocol turns into a published request
        (``shards.router``), so by construction ``wait_s`` stays zero on
        that path."""
        if self._lock.acquire(blocking=False):
            self.acquisitions += 1
            return True
        return False

    def release(self) -> None:
        self._lock.release()


class SPSCQueue(Generic[T]):
    __slots__ = ("_q", "pushed", "popped")

    def __init__(self) -> None:
        self._q: deque = deque()
        self.pushed = 0
        self.popped = 0

    def push(self, item: T) -> None:
        self._q.append(item)
        self.pushed += 1

    def pop(self) -> Optional[T]:
        try:
            item = self._q.popleft()
        except IndexError:
            return None
        self.popped += 1
        return item

    def peek(self) -> Optional[T]:
        """Head without removal (GIL-atomic index read). Stable only for
        the exclusive Submit drainer; a racing Done drainer may observe a
        head another manager pops first — callers there must re-read the
        actual popped item."""
        try:
            return self._q[0]
        except IndexError:
            return None

    def __len__(self) -> int:
        return len(self._q)


class WorkerQueues:
    """The queue pair owned by one worker thread."""

    def __init__(self, worker_id: int) -> None:
        self.worker_id = worker_id
        self.submit: SPSCQueue = SPSCQueue()
        self.done: SPSCQueue = SPSCQueue()
        self._submit_drain_flag = threading.Lock()

    # -- Submit-queue exclusivity (one manager at a time, in order) ----
    def acquire_submit(self) -> bool:
        return self._submit_drain_flag.acquire(blocking=False)

    def release_submit(self) -> None:
        self._submit_drain_flag.release()

    def pending(self) -> int:
        return len(self.submit) + len(self.done)
