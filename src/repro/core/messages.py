"""Runtime request messages (paper §3.1).

Two request kinds only — Submit and Done; task deletion is covered by the
extra FINISHED -> COMPLETED state transition instead of a third message.

The same types serve both routings: in ``dast``/``ddast`` mode a message
sits in the creating/executing worker's queue pair; in ``sharded`` mode
one message object is pushed to the mailbox of every shard its WD's
regions hash to, and each shard processes only its own portion of the
deps (see ``core.shards.router``). :class:`SubmitBatchMessage` is the
batched Submit: one mailbox entry carrying up to ``batch_size`` per-shard
task portions, so the per-message manager overhead that dominates at
high shard counts is paid once per batch.
"""
from __future__ import annotations

import struct
from dataclasses import dataclass
from typing import List, Sequence, Tuple

from .wd import WorkDescriptor


@dataclass
class SubmitTaskMessage:
    """Worker wants the task inserted in the dependence graph to discover
    its predecessors. MUST be processed in per-worker insertion order and
    by at most one manager per worker queue at a time."""
    wd: WorkDescriptor


@dataclass
class SubmitBatchMessage:
    """Batched Submit for ``sharded`` mode: the receiving shard inserts
    its portion of every WD in ``wds`` under ONE lock acquisition and the
    whole entry costs one manager pop+dispatch. Order within ``wds`` is
    the producer's creation order, so the §3.1 per-region submission
    ordering invariant is preserved batch-internally exactly as FIFO
    mailbox order preserves it across entries."""
    wds: List[WorkDescriptor]


@dataclass
class DoneTaskMessage:
    """Worker finished executing the task; successors must be notified and
    newly-ready ones scheduled. May be processed concurrently by any
    manager — execution finish order carries no semantics."""
    wd: WorkDescriptor


@dataclass
class DoneBatchMessage:
    """Batched Done for ``sharded`` mode, symmetric to
    :class:`SubmitBatchMessage`: the receiving shard scrubs its portion
    of every WD in ``wds`` under ONE lock acquisition and the whole
    entry costs one manager pop+dispatch. Legal because Done processing
    order carries no semantics (see :class:`DoneTaskMessage`) — only the
    per-WD latch arithmetic must balance, and it is unchanged."""
    wds: List[WorkDescriptor]


# ---------------------------------------------------------------------------
# Compact binary wire forms (process backend, core.procs).
#
# The in-process messages above carry live WorkDescriptor references —
# meaningless across an address-space boundary. The process backend
# ships the SAME two batch shapes, but flattened to what the other side
# actually needs: a Submit entry is (wd_id, payload, label) where
# ``payload`` is the pickled (func, args) pair, and a Done entry is
# (wd_id, t_start, t_end, status, blob) where ``blob`` is the pickled
# result (status 0), empty (status 1: result not picklable, dropped),
# or a UTF-8 traceback (status 2: body raised; status 3: replay-plane
# body raised). Struct-framed rather than pickled wholesale so a batch
# entry costs a fixed ~14/29-byte header per task, not a pickler walk
# over dataclasses.

_SUBMIT_HDR = struct.Struct("<QIH")      # wd_id, len(payload), len(label)
_DONE_HDR = struct.Struct("<QddBI")      # wd_id, t0, t1, status, len(blob)
_COUNT = struct.Struct("<I")

DONE_OK = 0              # blob = pickled result
DONE_NO_RESULT = 1       # result not picklable; dropped (blob empty)
DONE_ERROR = 2           # body raised; blob = UTF-8 traceback
DONE_PLANE_ERROR = 3     # replay-plane body raised; wd_id is the sid


def encode_submit_batch(entries: Sequence[Tuple[int, bytes, str]]) -> bytes:
    """Wire form of :class:`SubmitBatchMessage`: one frame per batch."""
    parts = [_COUNT.pack(len(entries))]
    for wd_id, payload, label in entries:
        lb = label.encode("utf-8")
        parts.append(_SUBMIT_HDR.pack(wd_id, len(payload), len(lb)))
        parts.append(payload)
        parts.append(lb)
    return b"".join(parts)


def decode_submit_batch(buf: bytes,
                        off: int = 0) -> List[Tuple[int, bytes, str]]:
    (count,) = _COUNT.unpack_from(buf, off)
    off += _COUNT.size
    out = []
    for _ in range(count):
        wd_id, plen, llen = _SUBMIT_HDR.unpack_from(buf, off)
        off += _SUBMIT_HDR.size
        payload = bytes(buf[off:off + plen])
        off += plen
        label = bytes(buf[off:off + llen]).decode("utf-8")
        off += llen
        out.append((wd_id, payload, label))
    return out


def encode_done_batch(
        entries: Sequence[Tuple[int, float, float, int, bytes]]) -> bytes:
    """Wire form of :class:`DoneBatchMessage`: one frame per batch."""
    parts = [_COUNT.pack(len(entries))]
    for wd_id, t0, t1, status, blob in entries:
        parts.append(_DONE_HDR.pack(wd_id, t0, t1, status, len(blob)))
        parts.append(blob)
    return b"".join(parts)


def decode_done_batch(
        buf: bytes,
        off: int = 0) -> List[Tuple[int, float, float, int, bytes]]:
    (count,) = _COUNT.unpack_from(buf, off)
    off += _COUNT.size
    out = []
    for _ in range(count):
        wd_id, t0, t1, status, blen = _DONE_HDR.unpack_from(buf, off)
        off += _DONE_HDR.size
        blob = bytes(buf[off:off + blen])
        off += blen
        out.append((wd_id, t0, t1, status, blob))
    return out
