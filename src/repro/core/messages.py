"""Runtime request messages (paper §3.1).

Two request kinds only — Submit and Done; task deletion is covered by the
extra FINISHED -> COMPLETED state transition instead of a third message.

The same types serve both routings: in ``dast``/``ddast`` mode a message
sits in the creating/executing worker's queue pair; in ``sharded`` mode
one message object is pushed to the mailbox of every shard its WD's
regions hash to, and each shard processes only its own portion of the
deps (see ``core.shards.router``). :class:`SubmitBatchMessage` is the
batched Submit: one mailbox entry carrying up to ``batch_size`` per-shard
task portions, so the per-message manager overhead that dominates at
high shard counts is paid once per batch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

from .wd import WorkDescriptor


@dataclass
class SubmitTaskMessage:
    """Worker wants the task inserted in the dependence graph to discover
    its predecessors. MUST be processed in per-worker insertion order and
    by at most one manager per worker queue at a time."""
    wd: WorkDescriptor


@dataclass
class SubmitBatchMessage:
    """Batched Submit for ``sharded`` mode: the receiving shard inserts
    its portion of every WD in ``wds`` under ONE lock acquisition and the
    whole entry costs one manager pop+dispatch. Order within ``wds`` is
    the producer's creation order, so the §3.1 per-region submission
    ordering invariant is preserved batch-internally exactly as FIFO
    mailbox order preserves it across entries."""
    wds: List[WorkDescriptor]


@dataclass
class DoneTaskMessage:
    """Worker finished executing the task; successors must be notified and
    newly-ready ones scheduled. May be processed concurrently by any
    manager — execution finish order carries no semantics."""
    wd: WorkDescriptor


@dataclass
class DoneBatchMessage:
    """Batched Done for ``sharded`` mode, symmetric to
    :class:`SubmitBatchMessage`: the receiving shard scrubs its portion
    of every WD in ``wds`` under ONE lock acquisition and the whole
    entry costs one manager pop+dispatch. Legal because Done processing
    order carries no semantics (see :class:`DoneTaskMessage`) — only the
    per-WD latch arithmetic must balance, and it is unchanged."""
    wds: List[WorkDescriptor]
