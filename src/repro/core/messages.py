"""Runtime request messages (paper §3.1).

Two message types only; task deletion is covered by the extra FINISHED ->
COMPLETED state transition instead of a third message.

The same two types serve both routings: in ``dast``/``ddast`` mode a
message sits in the creating/executing worker's queue pair; in
``sharded`` mode one message object is pushed to the mailbox of every
shard its WD's regions hash to, and each shard processes only its own
portion of the deps (see ``core.shards.router``).
"""
from __future__ import annotations

from dataclasses import dataclass

from .wd import WorkDescriptor


@dataclass
class SubmitTaskMessage:
    """Worker wants the task inserted in the dependence graph to discover
    its predecessors. MUST be processed in per-worker insertion order and
    by at most one manager per worker queue at a time."""
    wd: WorkDescriptor


@dataclass
class DoneTaskMessage:
    """Worker finished executing the task; successors must be notified and
    newly-ready ones scheduled. May be processed concurrently by any
    manager — execution finish order carries no semantics."""
    wd: WorkDescriptor
