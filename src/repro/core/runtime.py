"""Threaded task runtime with three dependence-management organizations.

Modes (the paper's §6 comparison set):
  * ``sync``  — Nanos++ baseline: every worker mutates the dependence graph
                directly under a global graph lock at submit & finish.
  * ``dast``  — the authors' earlier centralized design [7]: ONE dedicated
                manager thread drains all queues.
  * ``ddast`` — this paper: no dedicated resources; idle workers become
                managers through the Functionality Dispatcher.

Scheduling is Distributed Breadth-First (paper §4, point 4): one ready
deque per worker with work stealing.

The runtime is instrumented with exactly the quantities the paper plots:
graph-lock wait time, in-graph/ready task counts over time (Figs 12-14),
message counts, and task throughput.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from .ddast import DDASTManager, DDASTParams
from .depgraph import DependenceGraph
from .dispatcher import FunctionalityDispatcher
from .messages import DoneTaskMessage, SubmitTaskMessage
from .queues import WorkerQueues
from .wd import DepMode, TaskState, WorkDescriptor

_MODES = ("sync", "dast", "ddast")

_tls = threading.local()


def _parse_deps(deps: Sequence[Tuple[Any, Union[str, DepMode]]]):
    out = []
    for region, mode in deps:
        if isinstance(mode, str):
            mode = DepMode(mode)
        out.append((region, mode))
    return tuple(out)


@dataclass
class RuntimeStats:
    tasks_executed: int = 0
    lock_acquisitions: int = 0
    lock_wait_s: float = 0.0
    messages_processed: int = 0
    ddast_callback_entries: int = 0
    max_in_graph: int = 0
    total_edges: int = 0
    trace: List[Tuple[float, int, int]] = field(default_factory=list)  # (t, in_graph, ready)
    wall_s: float = 0.0


class _InstrumentedLock:
    """Lock that records contention (acquisitions + wait time)."""

    __slots__ = ("_lock", "acquisitions", "wait_s")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.acquisitions = 0
        self.wait_s = 0.0

    def __enter__(self):
        t0 = time.perf_counter()
        self._lock.acquire()
        self.wait_s += time.perf_counter() - t0
        self.acquisitions += 1
        return self

    def __exit__(self, *exc):
        self._lock.release()
        return False


class TaskRuntime:
    """Host task runtime. Use as a context manager::

        with TaskRuntime(num_workers=4, mode="ddast") as rt:
            rt.task(f, a, b, deps=[(("A", 0), "inout")])
            rt.taskwait()
    """

    def __init__(self, num_workers: int = 4, mode: str = "ddast",
                 params: Optional[DDASTParams] = None,
                 trace: bool = False,
                 manager_eligible: Optional[set] = None) -> None:
        if mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}")
        self.num_workers = num_workers
        self.mode = mode
        self.params = params or DDASTParams()
        self.trace_enabled = trace
        # big.LITTLE support (paper §8): restrict which workers may become
        # manager threads (None = any, the homogeneous default). The main
        # thread (id num_workers) is always eligible so taskwait drains.
        self.manager_eligible = manager_eligible

        self.worker_queues: List[WorkerQueues] = [
            WorkerQueues(i) for i in range(num_workers + 1)]  # +1: main thread
        self._ready: List[List[WorkDescriptor]] = [[] for _ in range(num_workers + 1)]
        self._ready_lock = threading.Lock()
        self._graph_lock = _InstrumentedLock()
        self._graphs: Dict[int, DependenceGraph] = {}
        self.dispatcher = FunctionalityDispatcher()
        self.ddast = DDASTManager(self, self.params)
        if mode == "ddast":
            self.dispatcher.register("ddast", self.ddast.callback, priority=10)

        self._root = WorkDescriptor(func=None, label="main")
        self._root.state = TaskState.RUNNING
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._dast_thread: Optional[threading.Thread] = None
        self.stats = RuntimeStats()
        self._trace_t0 = time.perf_counter()
        self._rr = 0  # round-robin target for newly-ready tasks

    # ------------------------------------------------------------------
    # lifecycle
    def __enter__(self) -> "TaskRuntime":
        self.start()
        return self

    def __exit__(self, *exc) -> bool:
        self.shutdown()
        return False

    def start(self) -> None:
        self._trace_t0 = time.perf_counter()
        _tls.current = self._root
        _tls.worker_id = self.num_workers  # main thread owns the last queue pair
        for i in range(self.num_workers):
            t = threading.Thread(target=self._worker_loop, args=(i,),
                                 name=f"worker-{i}", daemon=True)
            self._threads.append(t)
            t.start()
        if self.mode == "dast":
            self._dast_thread = threading.Thread(
                target=self._dast_loop, name="dast", daemon=True)
            self._dast_thread.start()

    def shutdown(self) -> None:
        self.taskwait()
        self._stop.set()
        for t in self._threads:
            t.join(timeout=5.0)
        if self._dast_thread is not None:
            self._dast_thread.join(timeout=5.0)
        self.stats.wall_s = time.perf_counter() - self._trace_t0
        self.stats.messages_processed = self.ddast.messages_processed
        self.stats.ddast_callback_entries = self.ddast.callback_entries
        self.stats.lock_acquisitions = self._graph_lock.acquisitions
        self.stats.lock_wait_s = self._graph_lock.wait_s
        for g in self._graphs.values():
            self.stats.max_in_graph = max(self.stats.max_in_graph, g.max_in_graph)
            self.stats.total_edges += g.total_edges

    # ------------------------------------------------------------------
    # graph plumbing (called by whoever manages: worker in sync mode,
    # manager threads in dast/ddast mode)
    def _graph_for(self, parent: WorkDescriptor) -> DependenceGraph:
        g = self._graphs.get(parent.wd_id)
        if g is None:
            g = self._graphs[parent.wd_id] = DependenceGraph()
        return g

    def satisfy_submit(self, wd: WorkDescriptor) -> None:
        with self._graph_lock:
            ready = self._graph_for(wd.parent).submit(wd)
        if ready:
            self._push_ready(wd)
        self._sample_trace()

    def satisfy_done(self, wd: WorkDescriptor) -> None:
        with self._graph_lock:
            newly = self._graph_for(wd.parent).complete(wd)
        for s in newly:
            self._push_ready(s)
        self._sample_trace()

    # ------------------------------------------------------------------
    # ready pool (DBF: per-worker deques + stealing)
    def _push_ready(self, wd: WorkDescriptor) -> None:
        with self._ready_lock:
            self._ready[self._rr].append(wd)
            self._rr = (self._rr + 1) % len(self._ready)

    def _pop_ready(self, worker_id: int) -> Optional[WorkDescriptor]:
        with self._ready_lock:
            q = self._ready[worker_id]
            if q:
                return q.pop()
            for other in self._ready:           # steal (FIFO end)
                if other:
                    return other.pop(0)
        return None

    def ready_count(self) -> int:
        return sum(len(q) for q in self._ready)

    def in_graph_count(self) -> int:
        return sum(g.in_graph for g in self._graphs.values())

    def _sample_trace(self) -> None:
        if self.trace_enabled:
            self.stats.trace.append((time.perf_counter() - self._trace_t0,
                                     self.in_graph_count(), self.ready_count()))

    # ------------------------------------------------------------------
    # public task API
    def task(self, func: Callable[..., Any], *args,
             deps: Sequence[Tuple[Any, Union[str, DepMode]]] = (),
             label: str = "task") -> WorkDescriptor:
        """Create + submit a task (life-cycle steps 1-2)."""
        parent = getattr(_tls, "current", self._root)
        wid = getattr(_tls, "worker_id", self.num_workers)
        wd = WorkDescriptor(func=func, args=args, deps=_parse_deps(deps),
                            label=label, parent=parent)
        if self.mode == "sync":
            self.satisfy_submit(wd)            # direct, under the graph lock
        else:
            self.worker_queues[wid].submit.push(SubmitTaskMessage(wd))
        return wd

    def taskwait(self) -> None:
        """Block until all children of the current task completed. The
        blocked thread keeps working: executes ready tasks and (ddast)
        runs the manager callback — the paper's idle-thread philosophy."""
        parent = getattr(_tls, "current", self._root)
        wid = getattr(_tls, "worker_id", self.num_workers)
        while True:
            # account for children whose Submit message is still queued
            if parent.num_children_alive == 0 and not self._pending_msgs():
                return
            wd = self._pop_ready(wid)
            if wd is not None:
                self._execute(wd, wid)
                continue
            if self.mode == "ddast":
                self.dispatcher.notify_idle(wid)
            elif self.mode == "sync":
                time.sleep(0)                   # busy-wait yield
            else:
                time.sleep(1e-5)

    def _pending_msgs(self) -> int:
        return sum(wq.pending() for wq in self.worker_queues)

    # ------------------------------------------------------------------
    # execution
    def _execute(self, wd: WorkDescriptor, worker_id: int) -> None:
        prev_task = getattr(_tls, "current", self._root)
        prev_wid = getattr(_tls, "worker_id", self.num_workers)
        _tls.current, _tls.worker_id = wd, worker_id
        wd.mark_running()
        try:
            if wd.func is not None:
                wd.result = wd.func(*wd.args)
        finally:
            wd.mark_finished()
            _tls.current, _tls.worker_id = prev_task, prev_wid
        self.stats.tasks_executed += 1
        if self.mode == "sync":
            self.satisfy_done(wd)              # direct, under the graph lock
        else:
            self.worker_queues[worker_id].done.push(DoneTaskMessage(wd))

    def _worker_loop(self, worker_id: int) -> None:
        _tls.current = self._root
        _tls.worker_id = worker_id
        while not self._stop.is_set():
            wd = self._pop_ready(worker_id)
            if wd is not None:
                self._execute(wd, worker_id)
                continue
            if self.mode == "ddast":
                self.dispatcher.notify_idle(worker_id)
                self._sample_trace()
            time.sleep(0)                       # yield (busy-wait analogue)

    def _dast_loop(self) -> None:
        """Centralized manager thread (the authors' previous design [7])."""
        while not self._stop.is_set():
            n = self.ddast.drain_all()
            if n == 0:
                time.sleep(1e-6)
