"""Threaded task runtime with four dependence-management organizations.

Modes (the paper's §6 comparison set plus the sharded extension):
  * ``sync``    — Nanos++ baseline: every worker mutates the dependence
                  graph directly under a global graph lock at submit &
                  finish.
  * ``dast``    — the authors' earlier centralized design [7]: ONE
                  dedicated manager thread drains all queues.
  * ``ddast``   — this paper: no dedicated resources; idle workers become
                  managers through the Functionality Dispatcher.
  * ``sharded`` — beyond the paper (after Álvarez et al. 2021 / Yu et al.
                  2022): the graph is partitioned by region hash into N
                  shards, each with its own lock and mailbox; idle
                  workers claim whole shards, so no global serialization
                  point remains (see ``core.shards``).

Scheduling is Distributed Breadth-First (paper §4, point 4): one ready
deque per worker with work stealing — lock-free ``StealDeque``s (owner
LIFO pop, thief FIFO steal) in every mode.

The runtime is instrumented with exactly the quantities the paper plots:
graph-lock wait time (per-shard waits summed in ``sharded`` mode),
in-graph/ready task counts over time (Figs 12-14), message counts, and
task throughput.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from .ddast import DDASTManager, DDASTParams
from .depgraph import DependenceGraph
from .dispatcher import FunctionalityDispatcher
from .messages import DoneTaskMessage, SubmitTaskMessage
from .queues import InstrumentedLock, WorkerQueues
from .shards import ShardedDependenceGraph, ShardRouter, StealDeque
from .wd import DepMode, TaskState, WorkDescriptor

_MODES = ("sync", "dast", "ddast", "sharded")

_tls = threading.local()


def _parse_deps(deps: Sequence[Tuple[Any, Union[str, DepMode]]]):
    out = []
    for region, mode in deps:
        if isinstance(mode, str):
            mode = DepMode(mode)
        out.append((region, mode))
    return tuple(out)


@dataclass
class RuntimeStats:
    tasks_executed: int = 0
    lock_acquisitions: int = 0
    lock_wait_s: float = 0.0           # sharded: per-shard waits summed
    messages_processed: int = 0        # sharded: per-shard counts summed
    ddast_callback_entries: int = 0
    max_in_graph: int = 0
    total_edges: int = 0
    trace: List[Tuple[float, int, int]] = field(default_factory=list)  # (t, in_graph, ready)
    wall_s: float = 0.0
    # Per-shard breakdowns (empty outside "sharded" mode).
    shard_lock_wait_s: List[float] = field(default_factory=list)
    shard_messages: List[int] = field(default_factory=list)


# Backward-compatible alias: the lock now lives in queues.py so the
# shards subsystem can use it without a circular import.
_InstrumentedLock = InstrumentedLock


class TaskRuntime:
    """Host task runtime. Use as a context manager::

        with TaskRuntime(num_workers=4, mode="ddast") as rt:
            rt.task(f, a, b, deps=[(("A", 0), "inout")])
            rt.taskwait()
    """

    def __init__(self, num_workers: int = 4, mode: str = "ddast",
                 params: Optional[DDASTParams] = None,
                 trace: bool = False,
                 manager_eligible: Optional[set] = None,
                 num_shards: Optional[int] = None) -> None:
        if mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}")
        self.num_workers = num_workers
        self.mode = mode
        self.params = params or DDASTParams()
        self.trace_enabled = trace
        # big.LITTLE support (paper §8): restrict which workers may become
        # manager threads (None = any, the homogeneous default). The main
        # thread (id num_workers) is always eligible so taskwait drains.
        self.manager_eligible = manager_eligible

        self.worker_queues: List[WorkerQueues] = [
            WorkerQueues(i) for i in range(num_workers + 1)]  # +1: main thread
        self._ready: List[StealDeque] = [
            StealDeque() for _ in range(num_workers + 1)]
        self._graph_lock = _InstrumentedLock()
        self._graphs: Dict[int, DependenceGraph] = {}
        # sharded mode: region-hash-partitioned graph + per-shard mailboxes
        if num_shards is not None and num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        self.num_shards = num_shards or max(2, num_workers)
        self.shard_graph: Optional[ShardedDependenceGraph] = None
        self.shard_router: Optional[ShardRouter] = None
        if mode == "sharded":
            self.shard_graph = ShardedDependenceGraph(self.num_shards)
            self.shard_router = ShardRouter(self.shard_graph,
                                            on_ready=self._push_ready)
        self.dispatcher = FunctionalityDispatcher()
        self.ddast = DDASTManager(self, self.params)
        if mode in ("ddast", "sharded"):
            self.dispatcher.register("ddast", self.ddast.callback, priority=10)

        self._root = WorkDescriptor(func=None, label="main")
        self._root.state = TaskState.RUNNING
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._dast_thread: Optional[threading.Thread] = None
        self.stats = RuntimeStats()
        self._trace_t0 = time.perf_counter()
        self._rr = 0  # round-robin target for newly-ready tasks

    # ------------------------------------------------------------------
    # lifecycle
    def __enter__(self) -> "TaskRuntime":
        self.start()
        return self

    def __exit__(self, *exc) -> bool:
        self.shutdown()
        return False

    def start(self) -> None:
        self._trace_t0 = time.perf_counter()
        _tls.current = self._root
        _tls.worker_id = self.num_workers  # main thread owns the last queue pair
        for i in range(self.num_workers):
            t = threading.Thread(target=self._worker_loop, args=(i,),
                                 name=f"worker-{i}", daemon=True)
            self._threads.append(t)
            t.start()
        if self.mode == "dast":
            self._dast_thread = threading.Thread(
                target=self._dast_loop, name="dast", daemon=True)
            self._dast_thread.start()

    def shutdown(self) -> None:
        self.taskwait()
        self._stop.set()
        for t in self._threads:
            t.join(timeout=5.0)
        if self._dast_thread is not None:
            self._dast_thread.join(timeout=5.0)
        self.stats.wall_s = time.perf_counter() - self._trace_t0
        self.stats.ddast_callback_entries = self.ddast.callback_entries
        if self.mode == "sharded":
            # Aggregate per-shard counters: the single DDASTManager's
            # counters alone would under-report (shards are also drained
            # via drain_all and taskwait edges).
            self.stats.shard_messages = [
                mb.messages_processed for mb in self.shard_router.mailboxes]
            self.stats.shard_lock_wait_s = [
                s.lock.wait_s for s in self.shard_graph.shards]
            self.stats.messages_processed = sum(self.stats.shard_messages)
            self.stats.lock_acquisitions = sum(
                s.lock.acquisitions for s in self.shard_graph.shards)
            self.stats.lock_wait_s = sum(self.stats.shard_lock_wait_s)
            self.stats.max_in_graph = self.shard_graph.max_in_graph
            self.stats.total_edges = self.shard_graph.total_edges
        else:
            self.stats.messages_processed = self.ddast.messages_processed
            self.stats.lock_acquisitions = self._graph_lock.acquisitions
            self.stats.lock_wait_s = self._graph_lock.wait_s
            for g in self._graphs.values():
                self.stats.max_in_graph = max(self.stats.max_in_graph,
                                              g.max_in_graph)
                self.stats.total_edges += g.total_edges

    # ------------------------------------------------------------------
    # graph plumbing (called by whoever manages: worker in sync mode,
    # manager threads in dast/ddast mode)
    def _graph_for(self, parent: WorkDescriptor) -> DependenceGraph:
        g = self._graphs.get(parent.wd_id)
        if g is None:
            g = self._graphs[parent.wd_id] = DependenceGraph()
        return g

    def satisfy_submit(self, wd: WorkDescriptor) -> None:
        with self._graph_lock:
            ready = self._graph_for(wd.parent).submit(wd)
        if ready:
            self._push_ready(wd)
        self._sample_trace()

    def satisfy_done(self, wd: WorkDescriptor) -> None:
        with self._graph_lock:
            newly = self._graph_for(wd.parent).complete(wd)
        for s in newly:
            self._push_ready(s)
        self._sample_trace()

    # ------------------------------------------------------------------
    # ready pool (DBF: per-worker lock-free StealDeques)
    def _push_ready(self, wd: WorkDescriptor) -> None:
        # Round-robin distribution; the unguarded _rr update is a benign
        # race (any value it yields is a valid target index).
        self._ready[self._rr].push(wd)
        self._rr = (self._rr + 1) % len(self._ready)

    def _pop_ready(self, worker_id: int) -> Optional[WorkDescriptor]:
        wd = self._ready[worker_id].pop()       # own deque: LIFO end
        if wd is not None:
            return wd
        n = len(self._ready)
        for off in range(1, n):                 # steal: FIFO end, O(1)
            wd = self._ready[(worker_id + off) % n].steal()
            if wd is not None:
                return wd
        return None

    def ready_count(self) -> int:
        return sum(len(q) for q in self._ready)

    def in_graph_count(self) -> int:
        if self.mode == "sharded":
            return self.shard_graph.in_graph
        return sum(g.in_graph for g in self._graphs.values())

    def _sample_trace(self) -> None:
        if self.trace_enabled:
            self.stats.trace.append((time.perf_counter() - self._trace_t0,
                                     self.in_graph_count(), self.ready_count()))

    # ------------------------------------------------------------------
    # public task API
    def task(self, func: Callable[..., Any], *args,
             deps: Sequence[Tuple[Any, Union[str, DepMode]]] = (),
             label: str = "task") -> WorkDescriptor:
        """Create + submit a task (life-cycle steps 1-2)."""
        parent = getattr(_tls, "current", self._root)
        wid = self._current_wid()
        wd = WorkDescriptor(func=func, args=args, deps=_parse_deps(deps),
                            label=label, parent=parent)
        if self.mode == "sync":
            self.satisfy_submit(wd)            # direct, under the graph lock
        elif self.mode == "sharded":
            self.shard_router.route_submit(wd)  # to per-shard mailboxes
            self._sample_trace()
        else:
            self.worker_queues[wid].submit.push(SubmitTaskMessage(wd))
        return wd

    def taskwait(self) -> None:
        """Block until all children of the current task completed. The
        blocked thread keeps working: executes ready tasks and (ddast)
        runs the manager callback — the paper's idle-thread philosophy."""
        parent = getattr(_tls, "current", self._root)
        wid = self._current_wid()
        while True:
            # account for children whose Submit message is still queued
            if parent.num_children_alive == 0 and not self._pending_msgs():
                return
            wd = self._pop_ready(wid)
            if wd is not None:
                self._execute(wd, wid)
                continue
            if self.mode in ("ddast", "sharded"):
                self.dispatcher.notify_idle(wid)
            elif self.mode == "sync":
                time.sleep(0)                   # busy-wait yield
            else:
                time.sleep(1e-5)

    def _current_wid(self) -> int:
        """This thread's worker id, clamped to this runtime's queues: the
        TLS is module-global, so a thread that last belonged to a larger
        runtime would otherwise index out of range here."""
        wid = getattr(_tls, "worker_id", self.num_workers)
        return wid if wid < len(self.worker_queues) else self.num_workers

    def _pending_msgs(self) -> int:
        n = sum(wq.pending() for wq in self.worker_queues)
        if self.shard_router is not None:
            n += self.shard_router.pending()
        return n

    # ------------------------------------------------------------------
    # execution
    def _execute(self, wd: WorkDescriptor, worker_id: int) -> None:
        prev_task = getattr(_tls, "current", self._root)
        prev_wid = getattr(_tls, "worker_id", self.num_workers)
        _tls.current, _tls.worker_id = wd, worker_id
        wd.mark_running()
        try:
            if wd.func is not None:
                wd.result = wd.func(*wd.args)
        finally:
            wd.mark_finished()
            _tls.current, _tls.worker_id = prev_task, prev_wid
        self.stats.tasks_executed += 1
        if self.mode == "sync":
            self.satisfy_done(wd)              # direct, under the graph lock
        elif self.mode == "sharded":
            self.shard_router.route_done(wd)   # to per-shard mailboxes
            self._sample_trace()
        else:
            self.worker_queues[worker_id].done.push(DoneTaskMessage(wd))

    def _worker_loop(self, worker_id: int) -> None:
        _tls.current = self._root
        _tls.worker_id = worker_id
        while not self._stop.is_set():
            wd = self._pop_ready(worker_id)
            if wd is not None:
                self._execute(wd, worker_id)
                continue
            if self.mode in ("ddast", "sharded"):
                self.dispatcher.notify_idle(worker_id)
                self._sample_trace()
            time.sleep(0)                       # yield (busy-wait analogue)

    def _dast_loop(self) -> None:
        """Centralized manager thread (the authors' previous design [7])."""
        while not self._stop.is_set():
            n = self.ddast.drain_all()
            if n == 0:
                time.sleep(1e-6)
