"""Threaded task runtime: a thin thread-driver over a DependencePolicy.

The four dependence-management organizations (the paper's §6 comparison
set plus the sharded extension) live in ``core.engine.policy``:

  * ``sync``    — Nanos++ baseline: every worker mutates the dependence
                  graph directly under a global graph lock.
  * ``dast``    — the authors' earlier centralized design [7]: ONE
                  dedicated manager thread drains all queues.
  * ``ddast``   — this paper: no dedicated resources; idle workers become
                  managers through the Functionality Dispatcher.
  * ``sharded`` — beyond the paper: region-hash-partitioned graph shards
                  with per-shard mailboxes; idle workers claim whole
                  shards; optional Submit + Done batching
                  (``batch_size``).

With ``replay=True`` the chosen policy is wrapped in a
``ReplayPolicy`` (``engine/replay.py``): the first root-taskwait
iteration records the task structure, and structurally identical
re-submissions then skip dependence analysis, locks, and mailboxes
entirely (the Taskgraph record-and-replay optimization for iterative
workloads).

This module knows nothing about any of that: it owns the threads, the
thread-local task context, the taskwait protocol, and the stats
aggregation, and delegates every dependence action to ``self.policy``.
The same policy objects run unchanged under ``RuntimeSimulator`` in
virtual time, so sim-vs-real protocol divergence is structurally
impossible.

Scheduling is Distributed Breadth-First (paper §4, point 4): one ready
deque per worker with work stealing — lock-free two-lane ``StealDeque``s
(owner LIFO pop, thief FIFO steal, plus a banded priority lane) owned by
the ``PlacementPolicy`` from the scheduling subsystem (``core.sched``):
round-robin by default, shard-affine with ``placement="shard_affine"``,
and critical-path-over-frozen-replay-graphs with
``placement="critical_path"`` (+ ``replay=True``).

With ``num_clients=N`` the runtime is **multi-tenant**: ``open_scope``
returns a :class:`~repro.core.scopes.JobScope` — an independent root
context with its own taskwait quiescence, its own dependence namespace
(the ``core.scopes`` region-keying shim), its own record-and-replay
slot, and a weighted-fair share of ready-task admission
(:class:`~repro.core.scopes.FairAdmission` in front of the placement).
Client threads each own one submit slot, preserving the §3.1
single-producer queue discipline.

The runtime is instrumented with exactly the quantities the paper plots:
graph-lock wait time (per-shard waits summed under the sharded policy),
in-graph/ready task counts over time (Figs 12-14), message counts, and
task throughput.
"""
from __future__ import annotations

import itertools
import threading
import time
import traceback as _tb
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple,
                    Union)

from .ddast import DDASTParams
from .dispatcher import FunctionalityDispatcher
from .engine import make_placement, make_policy, mode_uses_shards
from .errors import ScopeExpired, TaskFailed
from .metrics import NULL_METRICS, MetricsHub, MetricsSampler
from .queues import InstrumentedLock
from .scopes import (FairAdmission, JobScope, ScopedPolicy, scope_rollup,
                     scoped_deps)
from .trace import (EV_CREATED, EV_END, EV_RETRY, EV_SCOPE_EXPIRED,
                    EV_START, IncrementalDetector, NULL_TRACER,
                    TraceEvent, TraceRecorder, replay_iterations_of)
from .wd import DepMode, TaskState, WorkDescriptor

_MODES = ("sync", "dast", "ddast", "sharded")

_tls = threading.local()


def _parse_deps(deps: Sequence[Tuple[Any, Union[str, DepMode]]]):
    out = []
    for region, mode in deps:
        if isinstance(mode, str):
            mode = DepMode(mode)
        out.append((region, mode))
    return tuple(out)


@dataclass
class RuntimeStats:
    tasks_executed: int = 0
    lock_acquisitions: int = 0
    lock_wait_s: float = 0.0           # sharded: per-shard waits summed
    messages_processed: int = 0        # sharded: per-shard counts summed
    ddast_callback_entries: int = 0
    max_in_graph: int = 0
    total_edges: int = 0
    trace: List[Tuple[float, int, int]] = field(default_factory=list)  # (t, in_graph, ready)
    # Per-task event timeline (core.trace; empty unless trace=True):
    # merged, time-sorted TraceEvents from every slot's ring buffer,
    # plus the count evicted by ring overflow.
    events: List[TraceEvent] = field(default_factory=list)
    trace_dropped: int = 0
    # Placement counters surfaced per run: steals FROM each slot's
    # deque, and shard-affine load-cap fallbacks (0 for placements
    # without the cap).
    worker_steals: List[int] = field(default_factory=list)
    load_cap_skips: int = 0
    wall_s: float = 0.0
    # Per-shard breakdowns (empty outside the sharded policy).
    shard_lock_wait_s: List[float] = field(default_factory=list)
    shard_messages: List[int] = field(default_factory=list)
    # Delegation/combining counters (sharded mode with delegation=True;
    # zero elsewhere). delegated_portions counts every dependence
    # portion published onto a shard's MPSC request list (structural —
    # identical between this driver and the simulator on the same
    # program); combined_drains counts combine sessions; the per-shard
    # handoff list counts post-release re-acquisitions by a combiner
    # that found new requests published behind its back.
    delegated_portions: int = 0
    combined_drains: int = 0
    shard_lock_handoffs: List[int] = field(default_factory=list)
    # Record-and-replay counters (zero unless replay=True).
    replay_iterations: int = 0         # iterations served fully by replay
    replayed_tasks: int = 0            # submits elided from live analysis
    replay_invalidations: int = 0      # recordings retired on divergence
    replay_cache_hits: int = 0         # recordings reused from the cache
    # Per-scope rollups (empty unless scopes were opened): scope name ->
    # {tasks, weight, iterations, wall_s, admitted, admission_waits,
    #  max_queued, replay_iterations, replayed_tasks}.
    scopes: Dict[str, dict] = field(default_factory=dict)
    # Process-backend IPC counters (zero under threads): ring frames
    # shipped each way (Submit batches, Done batches, control frames)
    # and the per-root-quiescence (submit, done) frame deltas — the
    # replay steady-state 0-message gate in bench_procs.py reads
    # ipc_iter.
    ipc_submit_msgs: int = 0
    ipc_done_msgs: int = 0
    ipc_ctrl_msgs: int = 0
    ipc_iter: List[Tuple[int, int]] = field(default_factory=list)
    # Fault-tolerance counters. Respawns, timeout kills, transport
    # errors, zombies and shm leaks are process-backend quantities;
    # retries/poisoned also count threaded body-error retries, and
    # scopes_expired counts deadline/budget expiries (threads).
    worker_respawns: int = 0
    task_retries: int = 0
    tasks_poisoned: int = 0
    timeout_kills: int = 0
    transport_errors: int = 0
    trace_lost: int = 0
    zombie_workers: int = 0
    leaked_shm: List[str] = field(default_factory=list)
    scopes_expired: int = 0
    # Final live-metrics snapshot (core.metrics; empty unless
    # metrics=True): the same structure rt.metrics() serves mid-run —
    # per-slot counters, latency histogram, sampled series, scope SLO.
    metrics: Dict[str, object] = field(default_factory=dict)


# Backward-compatible alias: the lock lives in queues.py so every layer
# can use it without circular imports.
_InstrumentedLock = InstrumentedLock


class TaskRuntime:
    """Host task runtime. Use as a context manager::

        with TaskRuntime(num_workers=4, mode="ddast") as rt:
            rt.task(f, a, b, deps=[(("A", 0), "inout")])
            rt.taskwait()
    """

    def __new__(cls, *args, backend: str = "threads", **kwargs):
        # Backend dispatch: ``TaskRuntime(backend="processes")`` builds
        # the multi-process sibling driver (core.procs). ProcessRuntime
        # is deliberately NOT a subclass — it returns fully constructed
        # from here, so this __init__ never runs on it and the two
        # drivers cannot half-share thread state by accident.
        if cls is TaskRuntime and backend == "processes":
            from .procs import ProcessRuntime
            return ProcessRuntime(*args, backend=backend, **kwargs)
        return super().__new__(cls)

    def __init__(self, num_workers: int = 4, mode: str = "ddast",
                 params: Optional[DDASTParams] = None,
                 trace: bool = False,
                 manager_eligible: Optional[set] = None,
                 num_shards: Optional[int] = None,
                 batch_size: Optional[int] = None,
                 placement: Any = "round_robin",
                 replay: bool = False,
                 num_clients: int = 0,
                 delegation: bool = True, *,
                 backend: str = "threads",
                 metrics: bool = False,
                 metrics_interval_s: float = 0.002) -> None:
        # keyword-only on purpose: __new__ dispatches on the *keyword*
        # backend, so a positional value would silently select the
        # threaded driver — make that a TypeError instead
        if backend not in ("threads", "processes"):
            raise ValueError("backend must be 'threads' or 'processes'")
        if mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}")
        if num_shards is not None and num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        if num_clients < 0:
            raise ValueError("num_clients must be >= 0")
        self.num_workers = num_workers
        self.mode = mode
        self.params = params or DDASTParams()
        self.trace_enabled = trace
        self.manager_eligible = manager_eligible
        self.num_shards = num_shards or max(2, num_workers)
        self.batch_size = batch_size
        self.replay = replay
        self.num_clients = num_clients
        self.delegation = delegation

        # +1: the main thread's slot; client threads (multi-tenant
        # scopes) each own one more so the single-producer submit-queue
        # discipline (§3.1) survives concurrent tenants
        num_slots = num_workers + 1 + num_clients
        # the event tracer must exist before the policy stack: the
        # policy ctor wires it into the placement, the router, etc.
        self._trace_t0 = time.perf_counter()
        self.tracer = TraceRecorder(
            num_slots, clock=lambda: time.perf_counter() - self._trace_t0,
            time_unit="s") if trace else NULL_TRACER
        # shard-id affinity keying only makes sense over a shard
        # partition; other modes keep exact-region keying
        self.placement = make_placement(
            placement, num_slots,
            num_shards=self.num_shards if mode_uses_shards(mode) else None)
        if num_clients > 0:
            # multi-tenant: fair admission in front of the deques; the
            # scope multiplexer below owns the replay wrapping (one
            # recording slot per scope), so the base policy stays live
            self.placement = FairAdmission(self.placement)
        self.policy: Any = make_policy(
            mode, num_slots,
            num_workers=num_workers,
            params=self.params,
            placement=self.placement,
            manager_eligible=manager_eligible,
            main_slot=num_workers,
            num_shards=self.num_shards,
            batch_size=batch_size,
            delegation=delegation,
            replay=replay and num_clients == 0,
            tracer=self.tracer)
        if num_clients > 0:
            self.policy = ScopedPolicy(self.policy, replay=replay)
        self.dispatcher = FunctionalityDispatcher()
        if self.policy.uses_idle_managers:
            self.dispatcher.register("policy", self.policy.callback,
                                     priority=10)
        # live metrics plane (core.metrics): per-slot instruments on
        # the task path, sampler as ONE MORE idle/quiescent callback —
        # per DDAST discipline, idle threads take the samples
        self.metrics_enabled = metrics
        self.instruments = MetricsHub(
            num_slots,
            clock=lambda: time.perf_counter() - self._trace_t0,
            time_unit="s") if metrics else NULL_METRICS
        self.sampler: Optional[MetricsSampler] = None
        if metrics:
            self.sampler = MetricsSampler(
                clock=lambda: time.perf_counter() - self._trace_t0,
                interval=metrics_interval_s,
                tracer=self.tracer if trace else None,
                detector=IncrementalDetector() if trace else None)
            self._register_probes()
            self.dispatcher.register("metrics-sampler",
                                     self.sampler.callback, priority=1)
            self.dispatcher.register_quiescent(
                "metrics-sampler", self.sampler.quiescent_callback,
                priority=2)

        self._root = WorkDescriptor(func=None, label="main")
        self._root.state = TaskState.RUNNING
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._manager_thread: Optional[threading.Thread] = None
        self.stats = RuntimeStats()
        self._trace_t0 = time.perf_counter()
        # multi-tenant bookkeeping (inert when num_clients == 0)
        self._scopes: List[JobScope] = []
        self._scope_seq = itertools.count(1)
        self._main_thread = threading.current_thread()
        self._client_slot_lock = threading.Lock()
        self._free_client_slots = list(range(num_workers + 1, num_slots))
        self._client_slot_of: Dict[int, int] = {}   # thread ident -> slot
        self._client_slot_refs: Dict[int, int] = {}  # slot -> open scopes
        # per-scope failure isolation: body errors keyed by the failing
        # task's scope (None = the default root context) and raised only
        # from that scope's taskwait — one tenant's crash never surfaces
        # in another tenant's wait
        self._task_errors: Dict[Optional[int],
                                List[Tuple[str, str, list]]] = {}
        self._error_lock = threading.Lock()
        self._scope_by_id: Dict[int, JobScope] = {}
        self._retry_count = 0
        self._poisoned_count = 0

    # ------------------------------------------------------------------
    # historical accessors (the policy owns the structures now)
    @property
    def ddast(self):
        """The manager-side policy object (historically a DDASTManager)."""
        return self.policy

    @property
    def worker_queues(self):
        return getattr(self.policy, "worker_queues", [])

    @property
    def shard_router(self):
        return getattr(self.policy, "router", None)

    @property
    def shard_graph(self):
        return getattr(self.policy, "graph", None)

    # ------------------------------------------------------------------
    # lifecycle
    def __enter__(self) -> "TaskRuntime":
        self.start()
        return self

    def __exit__(self, *exc) -> bool:
        self.shutdown()
        return False

    def start(self) -> None:
        self._trace_t0 = time.perf_counter()
        self._main_thread = threading.current_thread()
        _tls.current = self._root
        _tls.worker_id = self.num_workers  # main thread owns the last slot
        for i in range(self.num_workers):
            t = threading.Thread(target=self._worker_loop, args=(i,),
                                 name=f"worker-{i}", daemon=True)
            self._threads.append(t)
            t.start()
        if self.policy.needs_manager_thread:
            self._manager_thread = threading.Thread(
                target=self._manager_loop, name="manager", daemon=True)
            self._manager_thread.start()

    def shutdown(self) -> None:
        # scope roots are NOT children of the runtime root: drain every
        # still-open tenant before the final root taskwait (close() is
        # a no-op for scopes the client already closed). A failing
        # tenant must not abort the teardown of the others: collect the
        # first error, finish draining and joining, then re-raise.
        err: Optional[BaseException] = None
        for sc in self._scopes:
            try:
                sc.close()
            except (TaskFailed, ScopeExpired) as e:
                if err is None:
                    err = e
        try:
            self.taskwait()
        except (TaskFailed, ScopeExpired) as e:
            if err is None:
                err = e
        self._stop.set()
        for t in self._threads:
            t.join(timeout=5.0)
        if self._manager_thread is not None:
            self._manager_thread.join(timeout=5.0)
        self.stats.wall_s = time.perf_counter() - self._trace_t0
        self.stats.ddast_callback_entries = self.policy.callback_entries
        st = self.policy.stats()
        self.stats.messages_processed = st["messages_processed"]
        self.stats.lock_acquisitions = st["lock_acquisitions"]
        self.stats.lock_wait_s = st["lock_wait_s"]
        self.stats.max_in_graph = st["max_in_graph"]
        self.stats.total_edges = st["total_edges"]
        self.stats.shard_messages = st["shard_messages"]
        self.stats.shard_lock_wait_s = st["shard_lock_wait_s"]
        self.stats.delegated_portions = st["delegated_portions"]
        self.stats.combined_drains = st["combined_drains"]
        self.stats.shard_lock_handoffs = list(st["shard_lock_handoffs"])
        pst = self.placement.stats()
        self.stats.worker_steals = [d.stolen for d in self.placement.deques]
        self.stats.load_cap_skips = int(pst.get("load_cap_skips", 0))
        if self.tracer.enabled:
            self.stats.events = self.tracer.events()
            self.stats.trace_dropped = self.tracer.dropped
        rep = st.get("replay")
        if rep:
            self.stats.replay_iterations = rep["replay_iterations"]
            self.stats.replayed_tasks = rep["replayed_tasks"]
            self.stats.replay_invalidations = rep["invalidations"]
            self.stats.replay_cache_hits = rep["cache_hits"]
        scope_tasks = st.get("scope_tasks", {})
        for sc in self._scopes:
            entry = {"tasks": scope_tasks.get(sc.scope_id, 0),
                     "weight": sc.weight,
                     "iterations": sc.iterations,
                     "wall_s": sc.wall_s}
            entry.update(scope_rollup(self.placement, self.policy,
                                      sc.scope_id, scope=sc))
            if sc._expired_reason is not None:
                entry["expired"] = sc._expired_reason
                entry["budget_used_s"] = sc._budget_used
            self.stats.scopes[sc.name] = entry
        self.stats.task_retries += self._retry_count
        self.stats.tasks_poisoned += self._poisoned_count
        if self.metrics_enabled:
            self.stats.metrics = self.metrics()
        if err is not None:
            raise err

    # ------------------------------------------------------------------
    # ready pool / occupancy probes (delegated)
    def ready_count(self) -> int:
        return self.placement.ready_count()

    def in_graph_count(self) -> int:
        return self.policy.in_graph()

    def _pending_msgs(self) -> int:
        return self.policy.pending()

    def _sample_trace(self) -> None:
        if self.trace_enabled:
            self.stats.trace.append((time.perf_counter() - self._trace_t0,
                                     self.in_graph_count(),
                                     self.ready_count()))

    # ------------------------------------------------------------------
    # live metrics plane (core.metrics)
    def _register_probes(self) -> None:
        """Wire the sampler's derived series to read-only runtime
        probes. Every probe is lock-free (plain len()/int reads), so a
        sampling pass never contends with the task path."""
        s = self.sampler
        pl = self.placement
        hub = self.instruments
        W = self.num_workers

        def ready_depth():
            return {str(i): len(d) for i, d in enumerate(pl.deques)}

        s.add_probe("ready", pl.ready_count)
        s.add_probe("ready_depth", ready_depth)
        s.add_probe("pending_msgs", self.policy.pending)
        s.add_probe("in_graph", self.policy.in_graph)
        s.add_probe("busy_frac", lambda: hub.busy_fraction(W))
        if isinstance(pl, FairAdmission):
            s.add_probe("admission_backlog", pl.admission_backlog)
            s.add_probe("admission_waits", pl.admission_waits_total)
            s.add_probe("scope_inflight",
                        lambda: {str(k): v
                                 for k, v in pl.scope_inflight().items()})
        router = getattr(self.policy, "router", None) \
            or getattr(getattr(self.policy, "inner", None), "router", None)
        if router is not None:
            s.add_probe("delegated_portions",
                        lambda: router.delegated_portions)
            s.add_probe("combined_drains", lambda: router.combined_drains)

    def metrics(self) -> Dict[str, object]:
        """Structured live snapshot: instrument counters + latency
        histogram, point-in-time gauges, per-scope inflight/admission/
        SLO entries, and the sampler's time-series rings. Callable at
        any time — including while a run is in flight — and frozen into
        ``stats.metrics`` at shutdown."""
        snap: Dict[str, object] = dict(self.instruments.snapshot()) \
            if self.metrics_enabled else {"time_unit": "s"}
        pl = self.placement
        gauges: Dict[str, object] = {
            "ready": pl.ready_count(),
            "pending_msgs": self.policy.pending(),
            "in_graph": self.policy.in_graph(),
        }
        if self.metrics_enabled:
            gauges["busy_frac"] = \
                self.instruments.busy_fraction(self.num_workers)
        if isinstance(pl, FairAdmission):
            gauges["admission_backlog"] = pl.admission_backlog()
            gauges["admission_waits"] = pl.admission_waits_total()
        snap["gauges"] = gauges
        if self._scopes:
            inflight = pl.scope_inflight() \
                if isinstance(pl, FairAdmission) else {}
            entries: Dict[str, object] = {}
            for sc in self._scopes:
                e: Dict[str, object] = {
                    "inflight": inflight.get(sc.scope_id, 0),
                    "tasks_alive": sc.root.num_children_alive,
                }
                adm = getattr(pl, "scope_admission", None)
                if callable(adm):
                    try:
                        e["admission"] = adm(sc.scope_id)
                    except KeyError:    # pragma: no cover - defensive
                        pass
                slo = sc.slo_snapshot()
                if slo is not None:
                    e["slo"] = slo
                entries[sc.name] = e
            snap["scopes"] = entries
        if self.sampler is not None:
            snap["sampler"] = self.sampler.snapshot()
        return snap

    # ------------------------------------------------------------------
    # public task API
    def task(self, func: Callable[..., Any], *args,
             deps: Sequence[Tuple[Any, Union[str, DepMode]]] = (),
             label: str = "task", retries: int = 0,
             timeout: Optional[float] = None) -> WorkDescriptor:
        """Create + submit a task (life-cycle steps 1-2). ``retries=N``
        re-runs a body that raises up to N times before the error is
        recorded (at-least-once: retried bodies must be idempotent);
        exhausted retries surface as :class:`TaskFailed` at the owning
        scope's taskwait. ``timeout=`` is advisory under threads (a
        thread cannot be killed mid-body); the process backend enforces
        it by killing and respawning the stuck worker."""
        parent = getattr(_tls, "current", None) or self._root
        return self._submit_task(parent, func, args, deps, label,
                                 retries=retries, timeout=timeout)

    def _submit_task(self, parent: WorkDescriptor, func, args, deps,
                     label: str, retries: int = 0,
                     timeout: Optional[float] = None) -> WorkDescriptor:
        # the ONE keying shim (core.scopes): a task created under a
        # scope declares scope-qualified regions, so tenants can never
        # alias each other's keys anywhere downstream
        wd = WorkDescriptor(func=func, args=args,
                            deps=_parse_deps(scoped_deps(parent.scope,
                                                         deps)),
                            label=label, parent=parent,
                            retries=max(0, retries), timeout=timeout)
        wid = self._current_wid()
        if self.tracer.enabled:
            self.tracer.task_event(EV_CREATED, wd, wid)
        self.policy.submit(wd, wid)
        self._sample_trace()
        return wd

    def taskwait(self) -> None:
        """Block until all children of the current task completed. The
        blocked thread keeps working: executes ready tasks and runs the
        registered idle callbacks — the paper's idle-thread philosophy."""
        self._taskwait_on(getattr(_tls, "current", None) or self._root)

    def _taskwait_on(self, parent: WorkDescriptor) -> None:
        wid = self._current_wid()
        scope_root = getattr(parent, "is_scope_root", False)
        if scope_root:
            # a tenant quiescence edge flushes EVERY slot (cross-thread
            # flush is lock-protected in the batching policy, same as
            # drain_all): the scope's buffered submits may sit in a
            # departed client thread's buffer that no idle callback
            # will ever flush — without this, close()/shutdown() on an
            # abandoned scope would spin forever on its unshipped
            # children
            for s in range(self.num_workers + 1 + self.num_clients):
                self.policy.flush(s)
        else:
            self.policy.flush(wid)
        root = parent is self._root or scope_root
        sid = parent.scope if scope_root else None
        # Scoped waiters gate on their own subtree alone: every child —
        # including one whose Submit is still queued, buffered, or in a
        # replay divergence buffer — incremented num_children_alive at
        # CREATION and only decrements once its Done is fully processed,
        # so children == 0 already implies nothing of THIS scope is in
        # flight. Gating on the runtime-wide pending count here would
        # let a busy tenant delay another tenant's quiescence (and
        # replay freeze) unboundedly. The default (scope-less) context
        # keeps the global probe: its taskwait doubles as the runtime's
        # drain point at shutdown.
        scoped = parent.scope is not None
        while True:
            if parent.num_children_alive == 0 and \
                    (scoped or not self._pending_msgs()):
                # policy first (a replay wrapper freezes/validates its
                # recording here), then dispatcher callbacks (the tuner
                # may resize shards — legal only once the policy has
                # settled its iteration state). A scope quiescence is
                # NOT global quiescence, so it routes to the scope's
                # policy slot only and skips the dispatcher hooks.
                self.policy.notify_quiescent(root, scope_id=sid)
                if root and self.tracer.enabled:
                    # the boundary payload lets trace consumers tell
                    # replayed windows (manager-silent by design) from
                    # live ones
                    self.tracer.quiesce(
                        {"scope": sid,
                         "replay_iterations": replay_iterations_of(
                             self.policy, sid)})
                if not scope_root:
                    self.dispatcher.notify_quiescent(wid)
                if root:
                    self._raise_wait_errors(sid, scope_root)
                return
            wd = self.placement.pop(wid)
            if wd is not None:
                self._execute(wd, wid)
                continue
            self.dispatcher.notify_idle(wid)
            time.sleep(self.policy.idle_sleep_s)

    # ------------------------------------------------------------------
    # multi-tenant scope API (core.scopes)
    def open_scope(self, name: Optional[str] = None, *,
                   weight: float = 1.0,
                   max_inflight: Optional[int] = None,
                   deadline: Optional[float] = None,
                   budget: Optional[float] = None) -> JobScope:
        """Open an independent root context for one tenant. Requires a
        multi-tenant runtime (``num_clients >= 1``): client threads each
        own a submit slot there, and the scope layers (per-scope replay
        slots + fair admission) are in place.

        ``deadline=`` (wall seconds from open) and ``budget=`` (summed
        body-execution seconds) bound the scope: once either expires,
        FairAdmission drains the scope's queued tasks unrun and the
        scope's own taskwait raises :class:`ScopeExpired` — other
        tenants are untouched."""
        if self.num_clients <= 0:
            raise ValueError(
                "open_scope needs TaskRuntime(num_clients=N): client "
                "submit slots and the scope layers are sized at "
                "construction")
        slot = self._ensure_client_slot()
        sid = next(self._scope_seq)
        sc = JobScope(self, sid, name or f"scope{sid}",
                      weight=weight, max_inflight=max_inflight,
                      deadline=deadline, budget=budget)
        if slot > self.num_workers:     # an allocated client slot:
            sc._client_slot = slot      # returned once the owning
            with self._client_slot_lock:  # thread's last scope closes
                self._client_slot_refs[slot] = \
                    self._client_slot_refs.get(slot, 0) + 1
        self.policy.register_scope(sid)
        self.placement.register_scope(sid, weight, max_inflight,
                                      expired_fn=sc.is_expired)
        self._scopes.append(sc)
        self._scope_by_id[sid] = sc
        return sc

    def _release_client_slot(self, scope: JobScope) -> None:
        """A scope closed: when it was the owning client thread's last
        open scope, recycle the thread's submit slot so tenant-session
        churn (thread per session) is bounded by CONCURRENT clients,
        not total ones. Safe at close time: the scope quiesced, so the
        slot's queues and buffers hold nothing of it."""
        slot = getattr(scope, "_client_slot", None)
        if slot is None:
            return
        scope._client_slot = None
        with self._client_slot_lock:
            refs = self._client_slot_refs.get(slot, 0) - 1
            if refs > 0:
                self._client_slot_refs[slot] = refs
                return
            self._client_slot_refs.pop(slot, None)
            for ident, s in list(self._client_slot_of.items()):
                if s == slot:
                    del self._client_slot_of[ident]
            self._free_client_slots.append(slot)

    def _scope_task(self, scope: JobScope, func, args, deps,
                    label: str, retries: int = 0,
                    timeout: Optional[float] = None) -> WorkDescriptor:
        cur = getattr(_tls, "current", None)
        parent = (cur if cur is not None
                  and getattr(cur, "scope", None) == scope.scope_id
                  else scope.root)
        return self._submit_task(parent, func, args, deps, label,
                                 retries=retries, timeout=timeout)

    def _scope_taskwait(self, scope: JobScope) -> None:
        self._taskwait_on(scope.root)

    def _enter_scope(self, scope: JobScope) -> None:
        """``with scope:`` — the calling thread's submissions land in
        the scope until exit (per-thread stack, so scopes nest)."""
        stack = getattr(_tls, "scope_stack", None)
        if stack is None:
            stack = _tls.scope_stack = []
        stack.append(getattr(_tls, "current", None))
        _tls.current = scope.root

    def _exit_scope(self, scope: JobScope) -> None:
        del scope
        prev = _tls.scope_stack.pop()
        if prev is None:
            try:
                del _tls.current
            except AttributeError:  # pragma: no cover - defensive
                pass
        else:
            _tls.current = prev

    def _ensure_client_slot(self) -> int:
        """The calling thread's submit slot, allocating a client slot
        for threads the runtime doesn't already own (cold path: once
        per thread per runtime; recycled by ``_release_client_slot``)."""
        wid = self._client_slot_of.get(threading.get_ident())
        if wid is not None:
            return wid
        t = threading.current_thread()
        if t is self._main_thread or t in self._threads:
            return self._current_wid()  # already owns a slot
        with self._client_slot_lock:
            wid = self._client_slot_of.get(threading.get_ident())
            if wid is not None:
                return wid
            if not self._free_client_slots:
                raise RuntimeError(
                    f"no free client slot (num_clients={self.num_clients}"
                    f"): raise num_clients or reuse a registered thread")
            wid = self._free_client_slots.pop(0)
            self._client_slot_of[threading.get_ident()] = wid
        return wid

    def _current_wid(self) -> int:
        """This thread's worker id, clamped to this runtime's slots: the
        TLS is module-global, so a thread that last belonged to a larger
        runtime would otherwise index out of range here. Registered
        client threads (multi-tenant scopes) resolve through this
        runtime's slot map first (GIL-atomic dict read)."""
        wid = self._client_slot_of.get(threading.get_ident())
        if wid is not None:
            return wid
        wid = getattr(_tls, "worker_id", self.num_workers)
        return wid if wid <= self.num_workers else self.num_workers

    # ------------------------------------------------------------------
    # execution
    def _execute(self, wd: WorkDescriptor, worker_id: int) -> None:
        prev_task = getattr(_tls, "current", self._root)
        prev_wid = getattr(_tls, "worker_id", self.num_workers)
        _tls.current, _tls.worker_id = wd, worker_id
        wd.mark_running()
        tr = self.tracer
        m = self.instruments
        if m.enabled:
            m.task_start(worker_id)
        if tr.enabled:
            tr.task_event(EV_START, wd, worker_id)
        t0 = time.perf_counter()
        executed = False
        try:
            # a raising body must NOT kill the worker thread (that hung
            # every later taskwait): capture it, retry in place while
            # retries remain, then record it against the owning scope
            while wd.func is not None and not wd.cancelled:
                try:
                    wd.result = wd.func(*wd.args)
                    executed = True
                    break
                except Exception:
                    if wd.retries_left > 0:
                        # attempt history records RETRIED attempts only
                        # (the terminal failure is the traceback itself
                        # — same convention as the process backend)
                        wd.attempts.append(
                            {"worker": worker_id, "reason": "error",
                             "t": time.perf_counter() - self._trace_t0})
                        wd.retries_left -= 1
                        self._retry_count += 1
                        if tr.enabled:
                            tr.task_event(
                                EV_RETRY, wd, worker_id,
                                {"attempt": len(wd.attempts),
                                 "reason": "error"})
                        continue
                    self._poisoned_count += 1
                    with self._error_lock:
                        self._task_errors.setdefault(
                            wd.scope, []).append(
                                (wd.label, _tb.format_exc(),
                                 list(wd.attempts)))
                    break
        finally:
            # measured body time feeds the replay scheduler's cost EMA
            wd.exec_dur = time.perf_counter() - t0
            wd.mark_finished()
            _tls.current, _tls.worker_id = prev_task, prev_wid
        if m.enabled:
            m.task_end(worker_id, wd.exec_dur)
        self._charge_scope(wd, worker_id)
        if tr.enabled:
            # end BEFORE complete(): successors' ready events must sort
            # after their predecessor's end
            tr.task_event(EV_END, wd, worker_id)
        if executed or wd.func is None:
            self.stats.tasks_executed += 1
        self.placement.note_executed(wd, worker_id)
        self.policy.complete(wd, worker_id)
        self._sample_trace()

    def _charge_scope(self, wd: WorkDescriptor, slot: int = -1) -> None:
        """Charge a finished body against its scope's execution-time
        budget, record its SLO outcome (deadline scopes), and fire the
        expiry transition the first time the scope is seen expired."""
        if wd.scope is None:
            return
        sc = self._scope_by_id.get(wd.scope)
        if sc is None:
            return
        if not wd.cancelled:
            sc._budget_used += wd.exec_dur
        if sc.deadline is not None:
            sc.note_completion(slot,
                               time.perf_counter() - sc.opened_s,
                               cancelled=wd.cancelled)
        if sc.is_expired():
            self._note_expiry(sc)

    def _note_expiry(self, sc: JobScope) -> None:
        """Record a scope's deadline/budget expiry exactly once (stats
        counter + trace event); safe to call repeatedly."""
        if sc._expiry_traced:
            return
        sc._expiry_traced = True
        self.stats.scopes_expired += 1
        if self.tracer.enabled:
            self.tracer.mgr_event(
                EV_SCOPE_EXPIRED, self._current_wid(),
                {"scope": sc.scope_id, "name": sc.name,
                 "reason": sc._expired_reason})

    def _raise_wait_errors(self, sid: Optional[int],
                           scope_root: bool) -> None:
        """Surface failures at the owning wait only: a scope taskwait
        raises its own scope's errors (ScopeExpired once, then any
        TaskFailed); the default root taskwait raises only scope-less
        task errors. One tenant's failure never escapes into another
        tenant's — or the root's — wait."""
        if scope_root:
            sc = self._scope_by_id.get(sid)
            if sc is not None and sc.is_expired() \
                    and not sc._expiry_raised:
                sc._expiry_raised = True
                self._note_expiry(sc)
                with self._error_lock:
                    self._task_errors.pop(sid, None)
                raise ScopeExpired(
                    f"scope {sc.name!r} expired ({sc._expired_reason}); "
                    f"{sc.drained} queued task(s) drained unrun",
                    scope=sc.name, reason=sc._expired_reason,
                    drained=sc.drained)
        with self._error_lock:
            errors = self._task_errors.pop(sid, None)
        if not errors:
            return
        label, tb, attempts = errors[0]
        more = f" (+{len(errors) - 1} more)" if len(errors) > 1 else ""
        att = f" after {len(attempts)} attempt(s)" if attempts else ""
        where = "" if sid is None else " in its scope"
        raise TaskFailed(f"task {label!r} raised{where}{att}{more}:\n{tb}",
                         failures=errors)

    def _worker_loop(self, worker_id: int) -> None:
        _tls.current = self._root
        _tls.worker_id = worker_id
        while not self._stop.is_set():
            wd = self.placement.pop(worker_id)
            if wd is not None:
                self._execute(wd, worker_id)
                continue
            if self.dispatcher.notify_idle(worker_id):
                self._sample_trace()
            time.sleep(0)                   # yield (busy-wait analogue)

    def _manager_loop(self) -> None:
        """Dedicated manager thread (the authors' previous design [7]);
        spawned only when the policy asks for one."""
        while not self._stop.is_set():
            if self.policy.drain_all() == 0:
                time.sleep(1e-6)
