"""ScopedPolicy: per-scope dependence/replay multiplexer over ONE live
policy.

Every scope needs its own record-and-replay slot — scope A freezing its
recording at its own taskwait must not validate, reset, or retire scope
B's — but the live dependence machinery (graphs, shards, mailboxes,
managers) is exactly the shared resource multi-tenancy is about. So the
multiplexer keeps ONE wrapped :class:`DependencePolicy` and gives each
scope (plus the driver's default root context) its own
:class:`~repro.core.engine.replay.ReplayPolicy` wrapper *around that
same inner policy*. Routing is the ``WorkDescriptor.scope`` stamp,
inherited from the parent at creation: submit/complete go to the
owning scope's slot; ``notify_quiescent(root, scope_id=...)`` goes to
exactly one slot, so iteration boundaries are per-tenant.

Scope wrappers publish their bottom levels with a ``scope`` tag:
several frozen graphs share one placement, and their structural ids
index *per-scope* band tables that
:class:`~repro.core.sched.placement.CriticalPathPlacement` merges into
one shared set of band-occupancy counters (a fixed band universe), so
multi-tenant replay regains global longest-chain-first. Replayed ready
tasks still flow through the normal admission path (see
:class:`~repro.core.scopes.admission.FairAdmission`), which preserves
the band through its ring via the ``_replay_sid`` stash.

Manager-side behavior (idle callbacks, drain loops, flush, batching) is
scope-blind by design — a drained Submit message carries its WD, and
the graphs it lands in are already per-parent — so those calls forward
straight to the inner policy.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from ..engine.policy import DependencePolicy
from ..engine.replay import ReplayPolicy
from ..shards.steal_deque import AtomicCounter
from ..wd import WorkDescriptor


def scope_rollup(placement, policy, scope_id: int,
                 scope=None) -> Dict[str, object]:
    """One scope's per-tenant stats entry, shared by both drivers (the
    threaded RuntimeStats.scopes and the simulator SimResult.scopes):
    admission counters from the FairAdmission ring plus the scope's
    replay-slot counters — and, when the :class:`JobScope` itself is
    passed and carries a deadline, its SLO attainment snapshot."""
    entry: Dict[str, object] = dict(placement.scope_admission(scope_id))
    steals = getattr(placement, "scope_steals", {}).get(scope_id)
    entry["steals"] = steals.value if steals is not None else 0
    pol = policy.scope_policy(scope_id)
    entry["replay_iterations"] = getattr(pol, "replay_iterations", 0)
    entry["replayed_tasks"] = getattr(pol, "replayed_tasks", 0)
    # per-tenant drain share: dependence-analysis portions consumed on
    # this scope's behalf by the scope-fair drain rotation (ddast queue
    # quanta / sharded combiner buckets); 0 for policies without one
    share = getattr(policy, "scope_drain_share", None)
    entry["drained_portions"] = share(scope_id) if callable(share) else 0
    if scope is not None:
        slo = scope.slo_snapshot()
        if slo is not None:
            entry["slo"] = slo
    return entry


class ScopedPolicy(DependencePolicy):
    """Multiplex scope-tagged protocol calls over one inner policy."""

    def __init__(self, inner: DependencePolicy,
                 replay: bool = False) -> None:
        # deliberately NOT calling super().__init__: the wrapped policy
        # owns slots/params/placement/charge; we route and delegate.
        self.inner = inner
        self.replay = replay
        self.name = f"scoped({inner.name})"
        self._default: DependencePolicy = (
            ReplayPolicy(inner, publish_priorities=False) if replay
            else inner)
        self._slots: Dict[int, DependencePolicy] = {}
        # per-scope task tallies: nested children of one scope are
        # submitted by concurrent worker threads, so a plain int +=
        # would drop counts (dict.setdefault is GIL-atomic)
        self.scope_tasks: Dict[Optional[int], AtomicCounter] = {}

    # ------------------------------------------------------------------
    # delegation plumbing (same shape as ReplayPolicy's)
    def __getattr__(self, item: str):
        return getattr(object.__getattribute__(self, "inner"), item)

    @property
    def needs_manager_thread(self) -> bool:
        return self.inner.needs_manager_thread

    @property
    def uses_idle_managers(self) -> bool:
        return self.inner.uses_idle_managers

    @property
    def idle_sleep_s(self) -> float:
        return self.inner.idle_sleep_s

    @property
    def callback_entries(self) -> int:
        return self.inner.callback_entries

    @property
    def messages_processed(self) -> int:
        return self.inner.messages_processed

    # ------------------------------------------------------------------
    # scope registry
    def register_scope(self, scope_id: int) -> DependencePolicy:
        """Allocate the scope's policy slot: an independent replay
        wrapper when replay is on, the shared inner policy otherwise."""
        if scope_id in self._slots:
            raise ValueError(f"scope {scope_id} already registered")
        pol = (ReplayPolicy(self.inner, scope=scope_id)
               if self.replay else self.inner)
        self._slots[scope_id] = pol
        return pol

    def scope_policy(self, scope_id: Optional[int]) -> DependencePolicy:
        if scope_id is None:
            return self._default
        return self._slots.get(scope_id, self._default)

    def _wrappers(self) -> List[ReplayPolicy]:
        out = []
        if isinstance(self._default, ReplayPolicy):
            out.append(self._default)
        for p in self._slots.values():
            if isinstance(p, ReplayPolicy):
                out.append(p)
        return out

    # ------------------------------------------------------------------
    # routed protocol
    def submit(self, wd: WorkDescriptor, slot: int) -> None:
        sid = wd.scope
        self.scope_tasks.setdefault(sid, AtomicCounter(0)).add(1)
        self.scope_policy(sid).submit(wd, slot)

    def complete(self, wd: WorkDescriptor, slot: int) -> None:
        self.scope_policy(wd.scope).complete(wd, slot)

    def notify_quiescent(self, root: bool = True,
                         scope_id: Optional[int] = None) -> None:
        self.scope_policy(scope_id).notify_quiescent(root)

    # ------------------------------------------------------------------
    # scope-blind protocol: straight to the inner policy
    def idle_callback(self, worker_id: int) -> int:
        return self.inner.idle_callback(worker_id)

    def drain_all(self) -> int:
        return self.inner.drain_all()

    def flush(self, slot: int) -> None:
        self.inner.flush(slot)

    # ------------------------------------------------------------------
    # probes fold in every slot's replay-side state (computed against
    # the inner policy directly — the wrappers share it, so calling
    # their pending()/in_graph() would double-count it)
    def pending(self) -> int:
        n = self.inner.pending()
        for w in self._wrappers():
            n += w._div_buffered
        return n

    def in_graph(self) -> int:
        n = self.inner.in_graph()
        for w in self._wrappers():
            n += w._live.value
        return n

    @property
    def recording_live(self) -> bool:
        """True while ANY tenant is mid-recording — global
        reconfiguration (shard resize) must wait for all of them."""
        return any(w.recording_live for w in self._wrappers())

    def stats(self) -> Dict[str, object]:
        st = dict(self.inner.stats())
        if self.replay:
            agg = {"state": "scoped", "recordings": 0,
                   "replay_iterations": 0, "replayed_tasks": 0,
                   "invalidations": 0, "cache_hits": 0,
                   "cached_recordings": 0, "recorded_tasks": 0,
                   "recorded_edges": 0}
            for w in self._wrappers():
                rep = w.stats()["replay"]
                for k in ("recordings", "replay_iterations",
                          "replayed_tasks", "invalidations", "cache_hits",
                          "cached_recordings", "recorded_tasks",
                          "recorded_edges"):
                    agg[k] += rep[k]
            st["replay"] = agg
        st["scope_tasks"] = {k: c.value
                             for k, c in self.scope_tasks.items()}
        return st
