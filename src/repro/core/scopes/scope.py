"""JobScope: a first-class root context, plus the region-keying shim.

A scope is to the runtime what a tenant is to a service: its tasks form
an independent graph under the scope's own root WD, its ``taskwait()``
quiesces only that graph, and its regions live in a namespace no other
scope can alias. The namespace comes from ONE shim —
:func:`scoped_deps` wraps every declared region as
``ScopedRegion(scope, region)`` at the moment a task enters the policy
boundary — so every downstream consumer of region keys (the RAW/WAW/WAR
rules, the shard hash, the placement affinity map, the replay
structural keys) separates tenants for free, in all four policies.
"""
from __future__ import annotations

import time
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

from ..metrics import LogHistogram
from ..wd import TaskState, WorkDescriptor


class ScopedRegion(NamedTuple):
    """A region key qualified by the scope that declared it. Compares
    and hashes by value like any region tuple, and its ``repr`` is
    stable, so :func:`~repro.core.shards.stable_region_hash` spreads the
    same app region to *different* shards for different scopes."""
    scope: int
    region: Any


def scoped_deps(scope_id: Optional[int], deps: Sequence[Tuple[Any, Any]]
                ) -> Sequence[Tuple[Any, Any]]:
    """The keying shim: fold ``scope_id`` into every region key of a
    dependence list. Identity for the default (scope-less) context, so
    non-tenant code pays nothing."""
    if scope_id is None:
        return deps
    return tuple((ScopedRegion(scope_id, region), mode)
                 for region, mode in deps)


class JobScope:
    """One tenant's root context inside a shared ``TaskRuntime``.

    Created by ``TaskRuntime.open_scope(name, weight=, max_inflight=)``;
    usable as a context manager (``with rt.open_scope("a") as sc:``) —
    entering makes the scope root the calling thread's current task so
    plain ``rt.task(...)`` submissions land in the scope; exiting
    taskwaits and closes. ``task()``/``taskwait()`` also work
    explicitly, from the opening thread (each submitting thread owns
    one SPSC submit queue — the §3.1 single-producer discipline — so a
    scope's top-level tasks must come from one thread; *nested* tasks
    created by worker threads executing scope tasks inherit the scope
    through their parent and use the worker's own slot).

    ``weight`` and ``max_inflight`` parameterize the
    :class:`~repro.core.scopes.admission.FairAdmission` layer: weight
    is the scope's deficit-round-robin share of ready-task admission;
    ``max_inflight`` bounds how many of the scope's ready tasks may
    occupy the shared ready deques at once (backpressure — a flooding
    tenant queues in its own ring, not in the shared pool).
    """

    def __init__(self, runtime, scope_id: int, name: str,
                 weight: float = 1.0,
                 max_inflight: Optional[int] = None,
                 deadline: Optional[float] = None,
                 budget: Optional[float] = None) -> None:
        if weight <= 0:
            raise ValueError("weight must be > 0")
        if max_inflight is not None and max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        if deadline is not None and deadline <= 0:
            raise ValueError("deadline must be > 0 seconds")
        if budget is not None and budget <= 0:
            raise ValueError("budget must be > 0 seconds")
        self._rt = runtime
        self.scope_id = scope_id
        self.name = name
        self.weight = weight
        self.max_inflight = max_inflight
        # expiry bounds: wall-clock seconds from open, and summed
        # body-execution seconds (charged by the runtime per finished
        # task). Once either runs out, FairAdmission drains this
        # scope's queued tasks unrun and taskwait raises ScopeExpired.
        self.deadline = deadline
        self.budget = budget
        self._budget_used = 0.0
        self._expired_reason: Optional[str] = None
        self._expiry_traced = False     # counted/traced once (runtime)
        self._expiry_raised = False     # ScopeExpired raised once
        self.root = WorkDescriptor(func=None, label=f"scope:{name}",
                                   scope=scope_id)
        self.root.state = TaskState.RUNNING
        self.root.is_scope_root = True
        self.iterations = 0             # root taskwaits reached
        self.opened_s = time.perf_counter()
        self.closed_s: Optional[float] = None
        # the owning client thread's submit slot, when one was
        # allocated for it (recycled at close — see runtime)
        self._client_slot: Optional[int] = None
        # -- SLO accounting (deadline scopes only) ----------------------
        # Per-slot met/missed counters + slack histograms, written by
        # whichever worker finishes the task (single writer per slot —
        # GIL-atomic, exact, zero locks), merged at slo_snapshot() read
        # time. Built eagerly at open so there is no first-write race;
        # slots allocated later (on-demand client slots) clamp to the
        # trailing overflow slot.
        self._slo_met: Optional[list] = None
        self._slo_missed: Optional[list] = None
        self._slo_slack: Optional[list] = None
        if deadline is not None:
            n = (getattr(runtime, "num_workers", 0) + 1
                 + getattr(runtime, "num_clients", 0) + 1)  # +1 overflow
            self._slo_met = [0] * n
            self._slo_missed = [0] * n
            self._slo_slack = [LogHistogram(1e-6) for _ in range(n)]

    # -- SLO attainment -------------------------------------------------
    def note_completion(self, slot: int, elapsed_s: float,
                        cancelled: bool = False) -> None:
        """Record one task outcome against the scope deadline. Called
        by the finishing worker with ``elapsed_s`` = seconds since the
        scope opened; ``cancelled`` marks tasks drained unrun after
        expiry (always a miss, no slack sample — they never executed)."""
        if self.deadline is None:
            return
        n = len(self._slo_met)
        s = slot if 0 <= slot < n - 1 else n - 1
        slack = self.deadline - elapsed_s
        if cancelled or slack < 0:
            self._slo_missed[s] += 1
        else:
            self._slo_met[s] += 1
        if not cancelled:
            self._slo_slack[s].record(max(slack, 0.0))

    def slo_snapshot(self) -> Optional[dict]:
        """Aggregated SLO view, or ``None`` for deadline-less scopes:
        met/missed totals, attainment fraction, and the merged deadline-
        slack histogram (seconds of headroom at completion; late
        finishes land in the zero bucket)."""
        if self.deadline is None:
            return None
        met = sum(self._slo_met)
        missed = sum(self._slo_missed)
        total = met + missed
        return {"deadline_s": self.deadline,
                "met": met, "missed": missed,
                "attainment": (met / total) if total else None,
                "slack": LogHistogram.merge_all(
                    list(self._slo_slack)).snapshot()}

    def is_expired(self) -> bool:
        """True once the scope's wall deadline or execution budget ran
        out (sticky). This is the ``expired_fn`` FairAdmission polls:
        its drain path consults only this scope's state, so one
        tenant's expiry never touches another's admission."""
        if self._expired_reason is not None:
            return True
        if self.deadline is not None and \
                time.perf_counter() - self.opened_s > self.deadline:
            self._expired_reason = (
                f"deadline {self.deadline:.3f}s exceeded")
            return True
        if self.budget is not None and self._budget_used > self.budget:
            self._expired_reason = (
                f"budget {self.budget:.3f}s exhausted "
                f"({self._budget_used:.3f}s used)")
            return True
        return False

    @property
    def drained(self) -> int:
        """Tasks FairAdmission drained unrun after this scope expired."""
        adm = getattr(self._rt.placement, "scope_admission", None)
        if adm is None:
            return 0
        try:
            return adm(self.scope_id).get("drained", 0)
        except KeyError:                # pragma: no cover - defensive
            return 0

    # ------------------------------------------------------------------
    def task(self, func: Optional[Callable[..., Any]], *args,
             deps: Sequence[Tuple[Any, Any]] = (),
             label: str = "task", retries: int = 0,
             timeout: Optional[float] = None) -> WorkDescriptor:
        """Create + submit a task under this scope. The parent is the
        calling thread's current task when that task already belongs to
        this scope (nested creation), else the scope root. ``retries``/
        ``timeout`` behave as in :meth:`TaskRuntime.task`."""
        return self._rt._scope_task(self, func, args, deps, label,
                                    retries=retries, timeout=timeout)

    def taskwait(self) -> None:
        """Block until all of THIS scope's tasks completed; the blocked
        thread keeps working (any scope's ready tasks). Reaching
        quiescence is this scope's root iteration boundary — its replay
        recording freezes/validates here, independent of other
        tenants."""
        self._rt._scope_taskwait(self)
        self.iterations += 1

    def close(self) -> None:
        """Taskwait, stop accounting wall time, and recycle the owning
        thread's client slot once its last scope closes. The slot is
        released even when the final taskwait raises (an expired or
        failed scope must not leak its client slot)."""
        if self.closed_s is None:
            self.closed_s = time.perf_counter()
            try:
                self.taskwait()
            finally:
                self.closed_s = time.perf_counter()
                self._rt._release_client_slot(self)

    @property
    def wall_s(self) -> float:
        return (self.closed_s or time.perf_counter()) - self.opened_s

    # ------------------------------------------------------------------
    def __enter__(self) -> "JobScope":
        self._rt._enter_scope(self)
        return self

    def __exit__(self, *exc) -> bool:
        self._rt._exit_scope(self)
        self.close()
        return False

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"JobScope({self.scope_id}:{self.name!r} "
                f"w={self.weight} cap={self.max_inflight})")
