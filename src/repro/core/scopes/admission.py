"""FairAdmission: weighted deficit round-robin between scopes and the
shared ready pool.

Without it, ready-task production flows straight into the
:class:`~repro.core.sched.placement.PlacementPolicy`'s per-slot deques,
so a tenant that floods (a huge graph, a tight submit loop) owns the
workers and every other tenant starves behind it. FairAdmission sits
between the two: a ready task belonging to scope *s* first lands in
scope *s*'s **ready ring** (a plain ``collections.deque`` — append and
popleft are GIL-atomic, so producers on any thread and admitters on any
thread never corrupt it, and no lock is introduced); an **admission
pass** (run by every push and every pop — whichever thread is already
here) moves ring entries into the underlying placement by weighted
deficit round-robin: each visit grants a scope ``weight`` units of
deficit, each admitted task spends one, so over any contended window
scopes are served in weight proportion regardless of who floods.

Admission is bounded twice. A shared **window** (default two tasks per
slot) caps the total admitted-but-not-yet-popped population: the
placement deques only need about one ready task per worker to keep
everyone busy, and making the window the scarce resource is what turns
the deficit scheduler into *weighted* sharing — every freed slot is a
service opportunity granted to the largest-deficit backlogged scope,
so grants converge to the weight ratio (plain eager admission would
degenerate to FIFO-by-arrival). ``max_inflight`` is the per-scope
version of the same bound: a tenant-specific ceiling inside the
window. Both release at pop (execution start), so neither can deadlock
a blocked parent — a capped scope's surplus simply waits in its own
ring, invisible to other tenants' latency.

Bookkeeping races are deliberate and benign: deficit counters and the
admitted/wait counters are plain ints (a lost update skews fairness by
one task); the inflight gauge reuses the runtime's
:class:`~repro.core.shards.AtomicCounter` (per-scope, two touches per
task — the same reasoning as the per-WD join counters) because an
inflight leak, unlike a deficit skew, would throttle a scope forever.

Tasks with no scope stamp (``wd.scope is None`` — the driver's own root
context) bypass the rings entirely: the default context is not a
tenant.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional

from ..shards.steal_deque import AtomicCounter
from ..sched.placement import PlacementPolicy
from ..trace import EV_ADMIT_DEFER
from ..wd import WorkDescriptor


class _ScopeRing:
    __slots__ = ("scope_id", "weight", "max_inflight", "ring", "deficit",
                 "inflight", "admitted", "pushed", "admission_waits",
                 "max_queued", "expired_fn", "drained", "contended_grants")

    def __init__(self, scope_id: int, weight: float,
                 max_inflight: Optional[int],
                 expired_fn=None) -> None:
        self.scope_id = scope_id
        self.weight = weight
        self.max_inflight = max_inflight
        self.ring: deque = deque()
        self.deficit = 0.0
        self.inflight = AtomicCounter(0)
        self.admitted = 0
        self.pushed = 0
        #: tasks (not spin passes) that were NOT admitted at push time —
        #: each waited in the ring for at least one later admission pass
        self.admission_waits = 0
        self.max_queued = 0
        #: expiry probe (JobScope.is_expired): once it answers True the
        #: scope's queued tasks drain-and-fail instead of admitting
        self.expired_fn = expired_fn
        self.drained = 0
        #: grants taken while EVERY registered ring was backlogged —
        #: the only window where weighted fairness is defined (an
        #: uncontended grant is just work conservation). The per-scope
        #: ratio of these converges to the weight ratio; the fairness
        #: benches gate on it because exec-order ratios dilute whenever
        #: a tenant's readiness production, not admission, is the
        #: bottleneck.
        self.contended_grants = 0


class FairAdmission(PlacementPolicy):
    """Wraps any :class:`PlacementPolicy`; same surface, fair front."""

    #: shared admission window, in multiples of the slot count
    DEFAULT_WINDOW_SLOTS = 2

    def __init__(self, inner: PlacementPolicy,
                 window: Optional[int] = None) -> None:
        # deliberately NOT calling super().__init__: the wrapped
        # placement owns the deques; we own only the scope rings.
        self.inner = inner
        self._rings: Dict[int, _ScopeRing] = {}
        self._ring_list: List[_ScopeRing] = []   # stable visit order
        self._window = window if window is not None else \
            self.DEFAULT_WINDOW_SLOTS * max(len(inner.deques), 1)
        self._inflight = AtomicCounter(0)        # window occupancy

    # -- scope registry -------------------------------------------------
    def register_scope(self, scope_id: int, weight: float = 1.0,
                       max_inflight: Optional[int] = None,
                       expired_fn=None) -> None:
        if scope_id in self._rings:
            raise ValueError(f"scope {scope_id} already registered")
        r = _ScopeRing(scope_id, weight, max_inflight, expired_fn)
        self._rings[scope_id] = r
        self._ring_list.append(r)

    # -- forwarded surface ----------------------------------------------
    @property
    def deques(self):
        return self.inner.deques

    @property
    def charge(self):
        return self.inner.charge

    @charge.setter
    def charge(self, c) -> None:
        # the policy ctor wires its CostCharger through `placement.charge`
        self.inner.charge = c

    @property
    def tracer(self):
        return self.inner.tracer

    @tracer.setter
    def tracer(self, t) -> None:
        # same wiring path as `charge`: the inner placement stamps the
        # ready/steal events, this wrapper stamps admission deferrals
        self.inner.tracer = t

    @property
    def scope_steals(self):
        return self.inner.scope_steals

    @property
    def wants_replay_priorities(self) -> bool:
        return self.inner.wants_replay_priorities

    def set_replay_priorities(self, levels, scope=None) -> None:
        self.inner.set_replay_priorities(levels, scope=scope)

    def clear_replay_priorities(self, scope=None) -> None:
        self.inner.clear_replay_priorities(scope=scope)

    def note_executed(self, wd: WorkDescriptor, slot: int) -> None:
        self.inner.note_executed(wd, slot)

    def set_num_shards(self, num_shards: int) -> None:
        """Forwarded so an online shard-count retune
        (``ShardedPolicy.resize``) still re-keys a shard-affine inner
        placement through this wrapper."""
        rekey = getattr(self.inner, "set_num_shards", None)
        if rekey is not None:
            rekey(num_shards)

    def stats(self) -> Dict[str, int]:
        st = self.inner.stats()
        st["admission_waits"] = sum(r.admission_waits
                                    for r in self._ring_list)
        return st

    # -- admission ------------------------------------------------------
    def scope_admission(self, scope_id: int) -> Dict[str, int]:
        r = self._rings[scope_id]
        return {"admitted": r.admitted,
                "admission_waits": r.admission_waits,
                "max_queued": r.max_queued,
                "drained": r.drained,
                "contended_grants": r.contended_grants,
                "weight": r.weight}

    def _drain_one(self, r: _ScopeRing, wd: WorkDescriptor) -> None:
        """Route one task of an expired scope straight to the inner
        placement as a cancelled no-op: workers pop it and skip the
        body, so the scope's graph drains without executing — and
        without occupying a window slot (``_fair_admitted`` stays
        unset, so the pop-side release skips it too)."""
        wd.cancelled = True
        r.drained += 1
        self.inner.push(wd)

    def _drain_expired(self) -> None:
        for r in self._ring_list:
            if r.ring and r.expired_fn is not None and r.expired_fn():
                while True:
                    try:
                        wd = r.ring.popleft()
                    except IndexError:
                        break
                    self._drain_one(r, wd)

    def _admit(self) -> None:
        """Weighted-deficit drain of the scope rings into the inner
        placement, one window slot at a time: every grant lets each
        backlogged cap-eligible scope accrue ``weight`` deficit, the
        largest-deficit scope takes the slot and pays the round's total
        weight — so over any contended window grants converge to the
        weight ratio, even though slots free one pop at a time. Runs on
        whichever thread is already pushing or popping; concurrent
        passes interleave harmlessly (each ring entry is popped exactly
        once — deque atomicity — and deficit skew from racing += is
        bounded by one round)."""
        self._drain_expired()
        rings = self._ring_list
        while True:
            if self._inflight.value >= self._window:
                return                      # backlog waits for a pop
            best = None
            total_w = 0.0
            backlogged = 0
            for r in rings:
                if not r.ring:
                    r.deficit = 0.0
                    continue
                backlogged += 1
                cap = r.max_inflight
                if cap is not None and r.inflight.value >= cap:
                    continue                # capped: no opportunity
                r.deficit += r.weight
                total_w += r.weight
                if best is None or r.deficit > best.deficit:
                    best = r
            if best is None:
                return
            try:
                wd = best.ring.popleft()
            except IndexError:              # raced another admitter
                continue
            best.deficit -= total_w
            best.inflight.add(1)
            self._inflight.add(1)
            best.admitted += 1
            if backlogged == len(rings) and backlogged > 1:
                best.contended_grants += 1
            wd._fair_admitted = True    # pop releases only real grants
            sid = getattr(wd, "_replay_sid", None)
            if sid is not None:
                wd._replay_sid = None   # band preserved through the ring
                self.inner.push_replay(wd, sid)
            else:
                self.inner.push(wd)

    def push(self, wd: WorkDescriptor) -> None:
        r = self._rings.get(wd.scope) if wd.scope is not None else None
        if r is None:
            self.inner.push(wd)
            return
        if r.expired_fn is not None and r.expired_fn():
            self._drain_one(r, wd)      # expired: drain-and-fail, no
            return                      # ring residency, no admission
        r.ring.append(wd)
        r.pushed += 1
        seq = r.pushed
        if len(r.ring) > r.max_queued:
            r.max_queued = len(r.ring)
        self._admit()
        # this task deferred (window/cap/deficit) iff the admission
        # pass above did not reach it — one count per waiting TASK, so
        # the metric is comparable between spinning threads and the sim
        if r.admitted < seq:
            r.admission_waits += 1
            tr = self.inner.tracer
            if tr.enabled:
                tr.task_event(EV_ADMIT_DEFER, wd, -1,
                              data={"queued": len(r.ring)})

    def push_replay(self, wd: WorkDescriptor, sid: int) -> None:
        # A replayed ready task of a tenant still queues through the
        # fair ring, but its band must survive admission: the sid is
        # stashed on the WD so _admit (possibly on another thread, much
        # later) can re-enter the inner placement's priority path and
        # land the task in its tenant's band table.
        if wd.scope is not None and wd.scope in self._rings:
            wd._replay_sid = sid
            self.push(wd)
        else:
            self.inner.push_replay(wd, sid)

    def pop(self, slot: int) -> Optional[WorkDescriptor]:
        if self._ring_list:
            self._admit()
        wd = self.inner.pop(slot)
        if wd is not None and wd.scope is not None \
                and getattr(wd, "_fair_admitted", False):
            wd._fair_admitted = False
            r = self._rings.get(wd.scope)
            if r is not None:           # backpressure releases at pop
                r.inflight.add(-1)
                self._inflight.add(-1)
        return wd

    def ready_count(self) -> int:
        n = self.inner.ready_count()
        for r in self._ring_list:
            n += len(r.ring)
        return n

    # -- live-metrics probes (read-only, lock-free, approximate under
    # -- concurrency by the same argument as the bookkeeping counters) --
    def admission_backlog(self) -> int:
        """Tasks waiting in scope rings, not yet granted a window slot."""
        return sum(len(r.ring) for r in self._ring_list)

    def admission_waits_total(self) -> int:
        return sum(r.admission_waits for r in self._ring_list)

    def scope_inflight(self) -> Dict[int, int]:
        """Per-scope window occupancy (admitted, not yet popped)."""
        return {r.scope_id: r.inflight.value for r in self._ring_list}
