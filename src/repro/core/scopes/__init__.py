"""Multi-tenant job scopes: concurrent independent taskgraphs in ONE
shared runtime.

The paper's asynchronous organization — threads *request* dependence
actions and idle threads play distributed manager — was built for one
application graph, but nothing in the request/mailbox discipline
requires a single requester. This subsystem makes the requester
first-class: a :class:`~repro.core.scopes.scope.JobScope` is an
independent root context (own root WD, own ``taskwait()`` quiescence,
own dependence namespace, own record-and-replay slot) and any number of
them submit concurrently into the same workers, shards, and mailboxes.

Three pieces, each plugging into an existing layer:

  * :class:`~repro.core.scopes.scope.JobScope` + the
    :func:`~repro.core.scopes.scope.scoped_deps` keying shim — the ONE
    place scope identity enters the dependence system: every region a
    scope touches is wrapped as ``ScopedRegion(scope, region)`` at the
    policy boundary, so two scopes touching ``("A", 0, 0)`` can never
    create a cross-scope false dependence, hash to independent shards,
    and keep independent placement-affinity entries — in all four
    policies, with zero policy changes.
  * :class:`~repro.core.scopes.policy.ScopedPolicy` — a multiplexer
    over any live :class:`~repro.core.engine.policy.DependencePolicy`
    that gives each scope its own
    :class:`~repro.core.engine.replay.ReplayPolicy` recording slot (and
    LRU cache), routed by the ``WorkDescriptor.scope`` stamp, so each
    client's iterative workload records, freezes, and replays
    independently of every other tenant.
  * :class:`~repro.core.scopes.admission.FairAdmission` — a layer
    between ready-task production and the
    :class:`~repro.core.sched.placement.PlacementPolicy`: per-scope
    bounded GIL-atomic ready rings drained by weighted deficit
    round-robin with per-scope ``max_inflight`` backpressure. No new
    locks on the hot path.

Both drivers speak the same objects: ``TaskRuntime(num_clients=N)``
grows ``open_scope()``; ``RuntimeSimulator.run_scopes([...], ...)``
runs one virtual client core per scope.
"""
from .admission import FairAdmission
from .policy import ScopedPolicy, scope_rollup
from .scope import JobScope, ScopedRegion, scoped_deps

__all__ = ["FairAdmission", "JobScope", "ScopedPolicy", "ScopedRegion",
           "scope_rollup", "scoped_deps"]
