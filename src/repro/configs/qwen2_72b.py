"""qwen2-72b [dense]: 80L, d_model=8192, 64H (GQA kv=8), d_ff=29568,
vocab=152064, QKV bias. [arXiv:2407.10671]"""
from ..models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b", family="dense",
    d_model=8192, num_heads=64, num_kv_heads=8, d_ff=29568,
    vocab_size=152064,
    pattern=(BlockSpec(mixer="attn", ffn="mlp"),), repeats=80,
    qkv_bias=True, rope_theta=1_000_000.0,
)
