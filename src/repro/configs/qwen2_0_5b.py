"""qwen2-0.5b [dense]: 24L, d_model=896, 14H (GQA kv=2), d_ff=4864,
vocab=151936, QKV bias, tied embeddings. [arXiv:2407.10671]"""
from ..models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    d_model=896, num_heads=14, num_kv_heads=2, d_ff=4864,
    vocab_size=151936,
    pattern=(BlockSpec(mixer="attn", ffn="mlp"),), repeats=24,
    qkv_bias=True, tie_embeddings=True, rope_theta=1_000_000.0,
)
