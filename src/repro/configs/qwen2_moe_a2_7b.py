"""qwen2-moe-a2.7b [moe]: 24L, d_model=2048, 16H (kv=16), expert
d_ff=1408, 60 routed top-4 + 4 shared experts, vocab=151936.
60 experts don't divide a 16-way EP axis: routed experts pad to 64 with
router-logit masking (semantics unchanged). [hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from ..models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    d_model=2048, num_heads=16, num_kv_heads=16, d_ff=1408,
    vocab_size=151936,
    pattern=(BlockSpec(mixer="attn", ffn="moe"),), repeats=24,
    num_experts=60, experts_per_tok=4, num_shared_experts=4, moe_d_ff=1408,
    qkv_bias=True,
)
