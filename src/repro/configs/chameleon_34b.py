"""chameleon-34b [vlm]: 48L, d_model=8192, 64H (GQA kv=8), d_ff=22016,
vocab=65536 (early fusion: VQ image tokens live in the same vocab; the
image tokenizer frontend is a STUB — the backbone consumes tokens).
[arXiv:2405.09818]"""
from ..models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    d_model=8192, num_heads=64, num_kv_heads=8, d_ff=22016,
    vocab_size=65536,
    pattern=(BlockSpec(mixer="attn", ffn="mlp"),), repeats=48,
    frontend="vision",
)
