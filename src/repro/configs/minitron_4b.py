"""minitron-4b [dense]: 32L, d_model=3072, 24H (GQA kv=8), head_dim=128,
d_ff=9216, vocab=256000 (pruned Nemotron). [arXiv:2407.14679]"""
from ..models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense",
    d_model=3072, num_heads=24, num_kv_heads=8, head_dim=128,
    d_ff=9216, vocab_size=256000,
    pattern=(BlockSpec(mixer="attn", ffn="mlp"),), repeats=32,
)
