"""gemma2-27b [dense]: 46L alternating local(4096-window)/global
attention, d_model=4608, 32H (GQA kv=16), head_dim=128, d_ff=36864,
vocab=256000, attn softcap 50, logit softcap 30, pre+post norms, tied
embeddings. [arXiv:2408.00118]"""
from ..models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    d_model=4608, num_heads=32, num_kv_heads=16, head_dim=128,
    d_ff=36864, vocab_size=256000,
    pattern=(BlockSpec(mixer="attn_local", ffn="mlp"),
             BlockSpec(mixer="attn", ffn="mlp")),
    repeats=23,
    sliding_window=4096, attn_softcap=50.0, logits_softcap=30.0,
    post_norm=True, tie_embeddings=True, act="silu",  # gemma2 uses gated-GELU; silu-gated is the TPU-matmul-equivalent stand-in
)
