"""whisper-base [audio]: 6L enc + 6L dec, d_model=512, 8H (kv=8),
d_ff=2048, vocab=51865. Enc-dec; conv audio frontend is a STUB —
input_specs provides precomputed frame embeddings. [arXiv:2212.04356]"""
from ..models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    d_model=512, num_heads=8, num_kv_heads=8, d_ff=2048, vocab_size=51865,
    pattern=(BlockSpec(mixer="attn", ffn="mlp"),), repeats=6,
    encoder_layers=6, encoder_seq=1500,
    frontend="audio", frontend_dim=512,
    qkv_bias=True, norm="layernorm", act="gelu", tie_embeddings=True,
)
