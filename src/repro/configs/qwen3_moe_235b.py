"""qwen3-moe-235b-a22b [moe]: 94L, d_model=4096, 64H (GQA kv=4),
head_dim=128, MoE 128 experts top-8, expert d_ff=1536, vocab=151936.
[hf:Qwen/Qwen3 family]"""
from ..models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    d_model=4096, num_heads=64, num_kv_heads=4, head_dim=128,
    d_ff=1536, vocab_size=151936,
    pattern=(BlockSpec(mixer="attn", ffn="moe"),), repeats=94,
    num_experts=128, experts_per_tok=8, moe_d_ff=1536,
    rope_theta=1_000_000.0,
)
