"""xlstm-125m [ssm]: 12 blocks, d_model=768, 4H (head_dim=192),
vocab=50304, no separate FFN (d_ff=0: xLSTM blocks carry their own
projections). sLSTM at positions 1, 5, 9; mLSTM elsewhere.
Sub-quadratic -> runs long_500k. [arXiv:2405.04517]"""
from ..models.config import BlockSpec, ModelConfig

_PERIOD = (BlockSpec(mixer="mlstm", ffn="none"),
           BlockSpec(mixer="slstm", ffn="none"),
           BlockSpec(mixer="mlstm", ffn="none"),
           BlockSpec(mixer="mlstm", ffn="none"))

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    d_model=768, num_heads=4, num_kv_heads=4, head_dim=192, d_ff=0,
    vocab_size=50304,
    pattern=_PERIOD, repeats=3,
    tie_embeddings=True,
    subquadratic=True,
)
