"""jamba-v0.1-52b [hybrid]: 32L, period-8 blocks (1 attention : 7 Mamba,
attention at position 4), MoE (16 experts top-2) every second layer,
d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab=65536. Sub-quadratic
(mamba layers) -> runs long_500k. [arXiv:2403.19887]"""
from ..models.config import BlockSpec, ModelConfig

_PERIOD = tuple(
    BlockSpec(mixer="attn" if i == 4 else "mamba",
              ffn="moe" if i % 2 == 1 else "mlp")
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    d_model=4096, num_heads=32, num_kv_heads=8, d_ff=14336,
    vocab_size=65536,
    pattern=_PERIOD, repeats=4,
    num_experts=16, experts_per_tok=2, moe_d_ff=14336,
    ssm_d_state=16, ssm_d_conv=4, ssm_expand=2,
    subquadratic=True,
)
