"""Assigned-architecture configs (exact published dims) + tiny smoke
variants. Select with --arch <id>."""
from __future__ import annotations

from typing import Dict

from ..models.config import ModelConfig, ShapeSpec, SHAPES, get_shape

from .whisper_base import CONFIG as whisper_base
from .qwen3_moe_235b import CONFIG as qwen3_moe_235b
from .qwen2_moe_a2_7b import CONFIG as qwen2_moe_a2_7b
from .qwen2_0_5b import CONFIG as qwen2_0_5b
from .qwen2_72b import CONFIG as qwen2_72b
from .minitron_4b import CONFIG as minitron_4b
from .gemma2_27b import CONFIG as gemma2_27b
from .chameleon_34b import CONFIG as chameleon_34b
from .jamba_52b import CONFIG as jamba_52b
from .xlstm_125m import CONFIG as xlstm_125m

ARCHS: Dict[str, ModelConfig] = {c.name: c for c in [
    whisper_base, qwen3_moe_235b, qwen2_moe_a2_7b, qwen2_0_5b, qwen2_72b,
    minitron_4b, gemma2_27b, chameleon_34b, jamba_52b, xlstm_125m,
]}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def tiny_config(name: str) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests: small width/depth,
    few experts, tiny vocab — structure (pattern, family, flags) intact."""
    cfg = get_config(name)
    over = dict(
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=512,
        repeats=min(cfg.repeats, 2),
        sliding_window=16,
        encoder_seq=24 if cfg.is_encoder_decoder else cfg.encoder_seq,
    )
    if cfg.num_experts:
        over.update(num_experts=8, experts_per_tok=min(cfg.experts_per_tok, 2),
                    moe_d_ff=32)
    if cfg.family in ("ssm", "hybrid"):
        over.update(ssm_d_state=8)
    if cfg.is_encoder_decoder:
        # keep a 2-layer encoder: encoder_layers is an explicit field
        over.update(encoder_layers=2)
    # xlstm: pattern positions stay, repeats shrink
    if len(cfg.pattern) > 4:
        over["pattern"] = cfg.pattern[:4]
    return cfg.scaled(**over)


__all__ = ["ARCHS", "get_config", "tiny_config", "ModelConfig",
           "ShapeSpec", "SHAPES", "get_shape"]
