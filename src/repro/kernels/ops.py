"""Jit-ready wrappers that route each hot-spot either to its Pallas TPU
kernel or to the pure-jnp oracle. The models call ONLY these entry points,
so kernels are first-class but swappable (REPRO_FORCE_REF=1 forces the
oracle; REPRO_FORCE_PALLAS=1 forces the kernel in interpret mode for CPU
validation)."""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref as _ref


def _use_pallas() -> bool:
    if os.environ.get("REPRO_FORCE_REF"):
        return False
    if os.environ.get("REPRO_FORCE_PALLAS"):
        return True
    return jax.default_backend() == "tpu"


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = False, window: Optional[int] = None,
              kv_len: Optional[jax.Array] = None,
              softcap: Optional[float] = None) -> jax.Array:
    """GQA attention; see kernels.ref.attention_ref for the contract."""
    s = q.shape[1]
    if _use_pallas() and s > 1 and kv_len is None and q.shape[1] == k.shape[1]:
        from .flash_attention import flash_attention
        interpret = jax.default_backend() != "tpu"
        return flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, interpret=interpret)
    return _ref.attention_ref(q, k, v, causal=causal, window=window,
                              kv_len=kv_len, softcap=softcap)


def ssm_scan(a: jax.Array, bx: jax.Array,
             h0: Optional[jax.Array] = None) -> jax.Array:
    """Linear recurrence h_t = a_t h_{t-1} + bx_t over axis 1."""
    if _use_pallas():
        from .ssm_scan import ssm_scan_pallas
        interpret = jax.default_backend() != "tpu"
        return ssm_scan_pallas(a, bx, h0=h0, interpret=interpret)
    return _ref.ssm_scan_ref(a, bx, h0=h0)


def selective_scan(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                   b: jax.Array, c: jax.Array, d: jax.Array,
                   h0: Optional[jax.Array] = None):
    """Fused Mamba selective scan -> (y [B,S,D], h_last [B,D,N])."""
    if _use_pallas():
        from .ssm_scan import selective_scan_pallas
        interpret = jax.default_backend() != "tpu"
        return selective_scan_pallas(x, dt, a_log, b, c, d, h0=h0,
                                     interpret=interpret)
    return _ref.selective_scan_ref(x, dt, a_log, b, c, d, h0=h0)


def moe_gemm(x: jax.Array, w: jax.Array) -> jax.Array:
    """Grouped per-expert matmul [E,C,d]x[E,d,f]->[E,C,f]."""
    if _use_pallas():
        from .moe_gemm import moe_gemm_pallas
        interpret = jax.default_backend() != "tpu"
        return moe_gemm_pallas(x, w, interpret=interpret)
    return _ref.moe_gemm_ref(x, w)
