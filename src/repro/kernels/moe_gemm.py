"""Grouped (per-expert) matmul Pallas kernel: x [E,C,d] @ w [E,d,f].

The MoE dispatch packs tokens into per-expert buffers (models/moe.py);
this kernel is the compute hotardspot. TPU adaptation: one expert per major
grid step, classic MXU-tiled matmul inside with an f32 VMEM accumulator
carried across the contraction blocks (minor-most grid dim => sequential).

Oracle: kernels/ref.py::moe_gemm_ref.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _moe_gemm_kernel(x_ref, w_ref, o_ref, acc_scr, *, n_dblocks: int):
    db = pl.program_id(3)

    @pl.when(db == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0].astype(jnp.float32)          # [blk_c, blk_d]
    w = w_ref[0].astype(jnp.float32)          # [blk_d, blk_f]
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(db == n_dblocks - 1)
    def _done():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def moe_gemm_pallas(x: jax.Array, w: jax.Array, blk_c: int = 128,
                    blk_d: int = 256, blk_f: int = 256,
                    interpret: bool = False) -> jax.Array:
    """x [E,C,d] @ w [E,d,f] -> [E,C,f] with f32 accumulation."""
    e, c, d = x.shape
    f = w.shape[2]
    blk_c = min(blk_c, c)
    blk_d = min(blk_d, d)
    blk_f = min(blk_f, f)
    # pad to block multiples
    cp = math.ceil(c / blk_c) * blk_c
    dp = math.ceil(d / blk_d) * blk_d
    fp = math.ceil(f / blk_f) * blk_f
    if (cp, dp) != (c, d):
        x = jnp.pad(x, ((0, 0), (0, cp - c), (0, dp - d)))
    if (dp, fp) != (d, f):
        w = jnp.pad(w, ((0, 0), (0, dp - d), (0, fp - f)))
    grid = (e, cp // blk_c, fp // blk_f, dp // blk_d)
    kernel = functools.partial(_moe_gemm_kernel, n_dblocks=dp // blk_d)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_c, blk_d),
                         lambda ei, ci, fi, di: (ei, ci, di)),
            pl.BlockSpec((1, blk_d, blk_f),
                         lambda ei, ci, fi, di: (ei, di, fi)),
        ],
        out_specs=pl.BlockSpec((1, blk_c, blk_f),
                               lambda ei, ci, fi, di: (ei, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((e, cp, fp), x.dtype),
        scratch_shapes=[pltpu.VMEM((blk_c, blk_f), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return out[:, :c, :f]
