"""Flash attention (online softmax) as a Pallas TPU kernel.

TPU adaptation (not a CUDA port): the grid's minor-most dimension iterates
sequentially on a core, so the running max/denominator/accumulator live in
VMEM scratch that persists across KV blocks — no atomics, no shared-memory
banking games. Tiles are MXU-aligned (q/kv blocks x head_dim lanes).

Supports: GQA (q heads grouped onto kv heads), causal masking,
sliding-window locality (Gemma-2), attn-logit softcapping. Causal/window
block skipping is done with `pl.when` on block indices, so fully-masked
KV blocks cost nothing on TPU.

Oracle: kernels/ref.py::attention_ref (tests sweep shapes/dtypes in
interpret mode).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: Optional[int],
                 softcap: Optional[float], blk_q: int, blk_k: int,
                 seq_k: int):
    kb = pl.program_id(3)
    qb = pl.program_id(2)
    nkb = pl.num_programs(3)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qb * blk_q
    k_start = kb * blk_k

    # block-level skip: causal => kv block strictly after q block is dead;
    # window => kv block entirely before the window is dead
    live = True
    if causal:
        live = k_start <= q_start + blk_q - 1
    if window is not None:
        live = jnp.logical_and(live,
                               k_start + blk_k - 1 > q_start - window)

    @pl.when(live if not isinstance(live, bool) else True)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)       # [blk_q, hd]
        k = k_ref[0, 0].astype(jnp.float32)       # [blk_k, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (blk_q, blk_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (blk_q, blk_k), 1)
        mask = kpos < seq_k
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                        # [blk_q, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(kb == nkb - 1)
    def _done():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = False, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    blk_q: int = 128, blk_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q [B,S,nq,hd]; k/v [B,T,nkv,hd] -> [B,S,nq,hd]."""
    b, s, nq, hd = q.shape
    t, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    scale = hd ** -0.5
    blk_q = min(blk_q, s)
    blk_k = min(blk_k, t)
    s_pad = math.ceil(s / blk_q) * blk_q
    t_pad = math.ceil(t / blk_k) * blk_k
    qt = jnp.moveaxis(q, 2, 1)                    # [B,nq,S,hd]
    kt = jnp.moveaxis(k, 2, 1)                    # [B,nkv,T,hd]
    vt = jnp.moveaxis(v, 2, 1)
    if s_pad != s:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    if t_pad != t:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, t_pad - t), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, t_pad - t), (0, 0)))

    grid = (b, nq, s_pad // blk_q, t_pad // blk_k)
    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, blk_q=blk_q, blk_k=blk_k, seq_k=t)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, hd),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, blk_k, hd),
                         lambda bi, hi, qi, ki, g_=g: (bi, hi // g_, ki, 0)),
            pl.BlockSpec((1, 1, blk_k, hd),
                         lambda bi, hi, qi, ki, g_=g: (bi, hi // g_, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, hd),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nq, s_pad, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :, :s]
    return jnp.moveaxis(out, 1, 2)
