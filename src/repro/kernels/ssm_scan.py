"""Selective-scan (Mamba) and generic linear-recurrence Pallas kernels.

TPU adaptation: the recurrence is sequential in time, so the grid puts the
time-block index minor-most (sequential on a TPU core) and carries the
state h [blk_d, N] in VMEM scratch across time blocks. The channel
dimension D is the parallel grid axis — each (batch, d-block) recurs
independently. This mirrors how the original CUDA kernel splits channels
over thread blocks, re-thought for VMEM residency: all per-step tensors
(x/dt tiles [blk_t, blk_d], B/C tiles [blk_t, N]) stay in VMEM, and the
inner fori walks blk_t steps with [blk_d, N] updates on the VPU.

Oracles: kernels/ref.py::{selective_scan_ref, ssm_scan_ref}.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ------------------------------------------------------ selective scan
def _sel_scan_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, d_ref, h0_ref,
                     y_ref, hout_ref, h_scr, *, blk_t: int, blk_d: int,
                     n: int):
    tb = pl.program_id(2)

    @pl.when(tb == 0)
    def _init():
        h_scr[...] = h0_ref[0]                       # [blk_d, N]

    a = -jnp.exp(alog_ref[...].astype(jnp.float32))  # [blk_d, N]
    dvec = d_ref[...].astype(jnp.float32)            # [1, blk_d]
    x = x_ref[0].astype(jnp.float32)                 # [blk_t, blk_d]
    dt = dt_ref[0].astype(jnp.float32)
    bmat = b_ref[0].astype(jnp.float32)              # [blk_t, N]
    cmat = c_ref[0].astype(jnp.float32)

    def step(i, carry):
        h, ys = carry
        dt_i = dt[i][:, None]                        # [blk_d, 1]
        x_i = x[i][:, None]
        da = jnp.exp(dt_i * a)                       # [blk_d, N]
        h = da * h + (dt_i * x_i) * bmat[i][None, :]
        y = jnp.sum(h * cmat[i][None, :], axis=1)    # [blk_d]
        ys = jax.lax.dynamic_update_index_in_dim(ys, y, i, 0)
        return h, ys

    h, ys = jax.lax.fori_loop(
        0, blk_t, step,
        (h_scr[...], jnp.zeros((blk_t, blk_d), jnp.float32)))
    h_scr[...] = h
    y_ref[0] = (ys + x * dvec).astype(y_ref.dtype)
    hout_ref[0] = h


def selective_scan_pallas(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                          b: jax.Array, c: jax.Array, d: jax.Array,
                          h0: Optional[jax.Array] = None,
                          blk_t: int = 256, blk_d: int = 256,
                          interpret: bool = False
                          ) -> Tuple[jax.Array, jax.Array]:
    """x/dt [B,S,D]; a_log [D,N]; b/c [B,S,N]; d [D] -> (y, h_last)."""
    bsz, s, dd = x.shape
    n = a_log.shape[1]
    blk_t = min(blk_t, s)
    blk_d = min(blk_d, dd)
    assert s % blk_t == 0 and dd % blk_d == 0, (s, dd, blk_t, blk_d)
    if h0 is None:
        h0 = jnp.zeros((bsz, dd, n), jnp.float32)
    grid = (bsz, dd // blk_d, s // blk_t)
    kernel = functools.partial(_sel_scan_kernel, blk_t=blk_t, blk_d=blk_d,
                               n=n)
    y, h_last = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_t, blk_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, blk_t, blk_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((blk_d, n), lambda bi, di, ti: (di, 0)),
            pl.BlockSpec((1, blk_t, n), lambda bi, di, ti: (bi, ti, 0)),
            pl.BlockSpec((1, blk_t, n), lambda bi, di, ti: (bi, ti, 0)),
            pl.BlockSpec((1, blk_d), lambda bi, di, ti: (0, di)),
            pl.BlockSpec((1, blk_d, n), lambda bi, di, ti: (bi, di, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk_t, blk_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, blk_d, n), lambda bi, di, ti: (bi, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, dd), x.dtype),
            jax.ShapeDtypeStruct((bsz, dd, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((blk_d, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a_log, b, c, d.reshape(1, dd), h0)
    return y, h_last


# ------------------------------------------------- generic linear scan
def _lin_scan_kernel(a_ref, bx_ref, h0_ref, y_ref, h_scr, *, blk_t: int):
    tb = pl.program_id(2)

    @pl.when(tb == 0)
    def _init():
        h_scr[...] = h0_ref[...]                     # [1, blk_d]

    a = a_ref[0].astype(jnp.float32)                 # [blk_t, blk_d]
    bx = bx_ref[0].astype(jnp.float32)

    def step(i, carry):
        h, ys = carry
        h = a[i][None, :] * h + bx[i][None, :]
        ys = jax.lax.dynamic_update_index_in_dim(ys, h[0], i, 0)
        return h, ys

    h, ys = jax.lax.fori_loop(
        0, blk_t, step,
        (h_scr[...], jnp.zeros_like(a)))
    h_scr[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)


def ssm_scan_pallas(a: jax.Array, bx: jax.Array,
                    h0: Optional[jax.Array] = None,
                    blk_t: int = 256, blk_d: int = 512,
                    interpret: bool = False) -> jax.Array:
    """Linear recurrence h_t = a_t*h_{t-1} + bx_t over axis 1.
    a/bx [B,S,D] -> h [B,S,D]."""
    bsz, s, dd = a.shape
    blk_t = min(blk_t, s)
    blk_d = min(blk_d, dd)
    assert s % blk_t == 0 and dd % blk_d == 0
    if h0 is None:
        h0 = jnp.zeros((bsz, dd), jnp.float32)
    grid = (bsz, dd // blk_d, s // blk_t)
    kernel = functools.partial(_lin_scan_kernel, blk_t=blk_t)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_t, blk_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, blk_t, blk_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, blk_d), lambda bi, di, ti: (bi, di)),
        ],
        out_specs=pl.BlockSpec((1, blk_t, blk_d),
                               lambda bi, di, ti: (bi, ti, di)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, dd), bx.dtype),
        scratch_shapes=[pltpu.VMEM((1, blk_d), jnp.float32)],
        interpret=interpret,
    )(a, bx, h0)
