"""Pure-jnp oracles for every Pallas kernel. These define the numerical
contract: kernels must match these within tolerance across the shape/dtype
sweeps in tests/test_kernels.py."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = False, window: Optional[int] = None,
                  kv_len: Optional[jax.Array] = None,
                  softcap: Optional[float] = None,
                  scale: Optional[float] = None) -> jax.Array:
    """GQA attention oracle.

    q [B,S,nq,hd]; k/v [B,T,nkv,hd] with nq % nkv == 0.
    causal     — standard causal mask (queries at positions T-S..T-1)
    window     — additionally restrict to a trailing sliding window
    kv_len     — scalar or [B]: only keys < kv_len are valid (decode)
    softcap    — tanh softcapping of attention logits (Gemma-2)
    """
    b, s, nq, hd = q.shape
    t, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    scale = hd ** -0.5 if scale is None else scale
    qg = q.reshape(b, s, nkv, g, hd)
    # operands stay bf16 (collectives move the narrow copy); the MXU-style
    # f32 accumulation comes from preferred_element_type
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        scores = jnp.tanh(scores / softcap) * softcap
    kpos = jnp.arange(t)
    if kv_len is not None:
        # decode: query position is kv_len-1 (cache padded to t)
        kv = jnp.asarray(kv_len)
        if kv.ndim == 0:
            kv = kv[None]
        valid = kpos[None, :] < kv[:, None]          # [B,T]
        if window is not None:
            valid &= kpos[None, :] > (kv[:, None] - 1) - window
        m5 = valid[:, None, None, None, :]           # [B,1,1,1,T]
    else:
        qpos = jnp.arange(s) + (t - s)   # align query block to seq end
        mask = jnp.ones((s, t), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        m5 = mask[None, None, None]
    scores = jnp.where(m5, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgst,btkh->bskgh", w.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, s, nq, hd).astype(q.dtype)


def ssm_scan_ref(a: jax.Array, bx: jax.Array,
                 h0: Optional[jax.Array] = None) -> jax.Array:
    """Linear recurrence oracle: h_t = a_t * h_{t-1} + bx_t, returns all
    h_t. a/bx [B, S, ...] (elementwise)."""
    if h0 is None:
        h0 = jnp.zeros_like(bx[:, 0])

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                         (jnp.moveaxis(a, 1, 0).astype(jnp.float32),
                          jnp.moveaxis(bx, 1, 0).astype(jnp.float32)))
    return jnp.moveaxis(hs, 0, 1).astype(bx.dtype)


def selective_scan_ref(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                       b: jax.Array, c: jax.Array, d: jax.Array,
                       h0: Optional[jax.Array] = None
                       ) -> tuple[jax.Array, jax.Array]:
    """Fused Mamba selective scan oracle (never materializes [B,S,D,N]).

    x/dt [B,S,D]; a_log [D,N] (A = -exp(a_log)); b/c [B,S,N]; d [D].
    h_t = exp(dt_t A) h_{t-1} + dt_t b_t x_t ;  y_t = h_t c_t + d x_t.
    Returns (y [B,S,D], h_last [B,D,N]).
    """
    bsz = x.shape[0]
    n = a_log.shape[1]
    dd = x.shape[2]
    if h0 is None:
        h0 = jnp.zeros((bsz, dd, n), jnp.float32)
    a = -jnp.exp(a_log.astype(jnp.float32))

    def step(h, inp):
        xt, dtt, bt, ct = inp                       # [B,D],[B,D],[B,N],[B,N]
        da = jnp.exp(dtt[..., None] * a[None])      # [B,D,N]
        h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(b, 1, 0).astype(jnp.float32),
          jnp.moveaxis(c, 1, 0).astype(jnp.float32))
    s = x.shape[1]
    chunk = 128
    if s % chunk == 0 and s > chunk:
        # chunked remat: backward stores only chunk-boundary carries,
        # never the [B,D,N] state trail for every step
        nc = s // chunk
        xs = jax.tree.map(
            lambda t: t.reshape((nc, chunk) + t.shape[1:]), xs)

        @jax.checkpoint
        def chunk_body(h, xc):
            return jax.lax.scan(step, h, xc)

        h_last, ys = jax.lax.scan(chunk_body, h0, xs)
        ys = ys.reshape((s,) + ys.shape[2:])
    else:
        h_last, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + x.astype(jnp.float32) * d[None, None]
    return y.astype(x.dtype), h_last


def moe_gemm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Grouped (per-expert) matmul oracle: x [E,C,d] @ w [E,d,f] -> [E,C,f],
    accumulating in f32. Inputs stay in their dtype (bf16 on the wire) —
    casting BEFORE the einsum would make SPMD collectives move f32 copies
    (dry-run measured 2x MoE exchange bytes)."""
    return jnp.einsum("ecd,edf->ecf", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)
