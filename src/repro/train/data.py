"""Data pipeline: deterministic synthetic LM batches + a prefetcher that
runs through the Functionality Dispatcher — idle host threads fill the
prefetch queue exactly the way idle workers drain DDAST queues (the
paper's idle-resource philosophy applied to the framework's own I/O)."""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from ..core.dispatcher import FunctionalityDispatcher
from ..models.config import ModelConfig


@dataclass
class DataConfig:
    batch: int = 8
    seq_len: int = 128
    seed: int = 0
    prefetch_depth: int = 4


class SyntheticLM:
    """Deterministic synthetic corpus: Zipf-ish token draws with a simple
    Markov structure so the loss actually decreases during training."""

    def __init__(self, cfg: ModelConfig, dcfg: DataConfig):
        self.cfg = cfg
        self.dcfg = dcfg
        v = cfg.vocab_size
        rng = np.random.RandomState(dcfg.seed)
        probs = 1.0 / np.arange(1, min(v, 4096) + 1) ** 1.1
        self._probs = probs / probs.sum()
        self._shift = rng.randint(1, min(v, 4096))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState(self.dcfg.seed + 7919 * step)
        b, s = self.dcfg.batch, self.dcfg.seq_len
        base = rng.choice(len(self._probs), size=(b, s), p=self._probs)
        # Markov structure: next token correlated with current
        tok = base.copy()
        tok[:, 1::2] = (tok[:, 0::2][:, :tok[:, 1::2].shape[1]]
                        + self._shift) % min(self.cfg.vocab_size, 4096)
        labels = np.roll(tok, -1, axis=1)
        return {"tokens": tok.astype(np.int32),
                "labels": labels.astype(np.int32)}


class Prefetcher:
    """Registered as a dispatcher callback: whenever a host thread is idle
    it tops up the prefetch deque. `get(step)` blocks only if the pipeline
    is behind (and then fills synchronously — never deadlocks)."""

    def __init__(self, dataset: SyntheticLM,
                 dispatcher: Optional[FunctionalityDispatcher] = None,
                 depth: int = 4):
        self.ds = dataset
        self.depth = depth
        self._buf: deque = deque()
        self._next = 0
        self._lock = threading.Lock()
        self.fills_async = 0
        self.fills_sync = 0
        if dispatcher is not None:
            dispatcher.register("data-prefetch", self._callback, priority=5)

    def _callback(self, worker_id: int) -> None:
        del worker_id
        while True:
            with self._lock:
                if len(self._buf) >= self.depth:
                    return
                step = self._next
                self._next += 1
            batch = self.ds.batch_at(step)
            with self._lock:
                self._buf.append((step, batch))
                self.fills_async += 1

    def get(self, step: int) -> Dict[str, np.ndarray]:
        with self._lock:
            while self._buf:
                s, b = self._buf.popleft()
                if s == step:
                    return b
                # stale entries (after restore/rewind): drop
        self.fills_sync += 1
        with self._lock:
            self._next = max(self._next, step + 1)
        return self.ds.batch_at(step)
