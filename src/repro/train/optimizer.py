"""AdamW + global-norm clipping + warmup-cosine schedule, built in-repo
(no optax). Optimizer state is f32 and shards exactly like the params
(ZeRO: same rules-engine shardings apply to m/v)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.peak_lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: Params) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(lambda z: z.copy(), zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> Tuple[Params, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(cfg: OptConfig, grads: Params, opt: Dict[str, Any],
                 params: Params) -> Tuple[Params, Dict[str, Any], jax.Array]:
    step = opt["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return m, v, (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt["m"])
    flat_v = treedef.flatten_up_to(opt["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_p = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, lr
