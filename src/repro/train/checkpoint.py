"""Fault-tolerant checkpointing.

* double-buffered: writes go to `<dir>/tmp-<step>`, then atomic rename to
  `<dir>/step-<step>`; the previous checkpoint survives any crash.
* asynchronous: `save()` snapshots device arrays to host numpy and
  enqueues the write; the actual disk I/O runs in idle host time through
  the Functionality Dispatcher (the DDAST organization applied to
  checkpoint flushing), or synchronously via `flush()`.
* integrity: every leaf gets a crc; a manifest with tree structure,
  shapes and step is written LAST so a torn write is detectable.
* restore: newest complete+valid checkpoint wins; torn/corrupt ones are
  skipped — together with the data pipeline's determinism this gives
  exact resume (checkpoint/restart node-failure recovery).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from ..core.dispatcher import FunctionalityDispatcher


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str,
                 dispatcher: Optional[FunctionalityDispatcher] = None,
                 keep: int = 2):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: list = []
        self._lock = threading.Lock()
        self.async_writes = 0
        if dispatcher is not None:
            dispatcher.register("ckpt-flush", self._callback, priority=1)

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        leaves, treedef = _flatten(tree)
        host = [np.asarray(x) for x in leaves]      # device -> host snapshot
        with self._lock:
            self._pending.append((step, host, str(treedef)))
        if blocking:
            self.flush()

    def _callback(self, worker_id: int) -> None:
        del worker_id
        self.flush(limit=1)
        if self._pending:
            return
        return

    def flush(self, limit: Optional[int] = None) -> int:
        done = 0
        while True:
            with self._lock:
                if not self._pending or (limit is not None and done >= limit):
                    return done
                step, host, treedef_str = self._pending.pop(0)
            self._write(step, host, treedef_str)
            done += 1
            if limit is None:
                continue

    def _write(self, step: int, host: list, treedef_str: str) -> None:
        tmp = os.path.join(self.dir, f"tmp-{step}")
        final = os.path.join(self.dir, f"step-{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest: Dict[str, Any] = {"step": step, "treedef": treedef_str,
                                    "leaves": []}
        for i, arr in enumerate(host):
            path = os.path.join(tmp, f"leaf{i}.npy")
            dtype = str(arr.dtype)
            store = arr.view(np.uint16) if dtype == "bfloat16" else arr
            np.save(path, store)
            manifest["leaves"].append({
                "i": i, "shape": list(arr.shape), "dtype": dtype,
                "crc": zlib.crc32(np.ascontiguousarray(store).tobytes()),
            })
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self.async_writes += 1
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step-{s}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def steps(self) -> list:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step-"):
                out.append(int(name.split("-", 1)[1]))
        return sorted(out)

    def restore(self, like: Any) -> Optional[Tuple[int, Any]]:
        """Restore into the structure of `like` from the newest VALID
        checkpoint. Returns (step, tree) or None."""
        leaves_like, treedef = _flatten(like)
        for step in sorted(self.steps(), reverse=True):
            d = os.path.join(self.dir, f"step-{step}")
            try:
                with open(os.path.join(d, "manifest.json")) as f:
                    manifest = json.load(f)
                assert len(manifest["leaves"]) == len(leaves_like)
                leaves = []
                for ent, ref in zip(manifest["leaves"], leaves_like):
                    arr = np.load(os.path.join(d, f"leaf{ent['i']}.npy"))
                    if zlib.crc32(np.ascontiguousarray(arr).tobytes()) \
                            != ent["crc"]:
                        raise ValueError("crc mismatch")
                    if ent["dtype"] == "bfloat16":
                        import ml_dtypes
                        arr = arr.view(ml_dtypes.bfloat16)
                    assert tuple(arr.shape) == tuple(ref.shape)
                    leaves.append(arr)
                tree = jax.tree_util.tree_unflatten(treedef, leaves)
                return step, tree
            except Exception:  # torn/corrupt -> try older  # noqa: BLE001
                continue
        return None
