"""Fault tolerance & elasticity for 1000+-node runs.

Host-level machinery (works with any number of real hosts; exercised in
tests with simulated clocks):

* HeartbeatMonitor — per-host heartbeats; a host is DEAD after `timeout`,
  a STRAGGLER when its step latency exceeds `straggler_factor` x the
  cluster median (straggler mitigation = flag + plan around it).
* ElasticPlanner — given the surviving host set, proposes the largest
  valid (pod, data, model) mesh <= the original, plus the resharding plan
  (which checkpoint shards each new host loads). Recovery = restore from
  the newest checkpoint under the new mesh; the data pipeline is
  deterministic in `step`, so resume is exact.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


@dataclass
class HostState:
    last_beat: float
    last_step: int = 0
    step_times: List[float] = field(default_factory=list)


class HeartbeatMonitor:
    def __init__(self, hosts: List[str], timeout: float = 60.0,
                 straggler_factor: float = 2.0,
                 clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.timeout = timeout
        self.straggler_factor = straggler_factor
        now = clock()
        self.hosts: Dict[str, HostState] = {
            h: HostState(last_beat=now) for h in hosts}

    def beat(self, host: str, step: int, step_time: float) -> None:
        st = self.hosts[host]
        st.last_beat = self.clock()
        st.last_step = step
        st.step_times.append(step_time)
        if len(st.step_times) > 20:
            st.step_times.pop(0)

    def dead(self) -> List[str]:
        now = self.clock()
        return [h for h, st in self.hosts.items()
                if now - st.last_beat > self.timeout]

    def stragglers(self) -> List[str]:
        med = self._median_step_time()
        if med is None:
            return []
        out = []
        for h, st in self.hosts.items():
            if st.step_times and \
                    st.step_times[-1] > self.straggler_factor * med:
                out.append(h)
        return out

    def _median_step_time(self) -> Optional[float]:
        times = sorted(st.step_times[-1] for st in self.hosts.values()
                       if st.step_times)
        if not times:
            return None
        return times[len(times) // 2]


@dataclass
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    hosts: List[str]
    note: str = ""


class ElasticPlanner:
    """Largest valid mesh from surviving hosts. Chips per host fixed;
    the model axis is preserved (TP degree is a property of the model
    layout), the data/pod axes shrink — so restored FSDP shards reshard
    only along the data axis (cheap all-gather plan)."""

    def __init__(self, chips_per_host: int = 4, model_axis: int = 16):
        self.chips_per_host = chips_per_host
        self.model_axis = model_axis

    def plan(self, alive_hosts: List[str],
             pods: Optional[int] = None) -> MeshPlan:
        chips = len(alive_hosts) * self.chips_per_host
        model = self.model_axis
        if chips < model:
            raise RuntimeError(
                f"{chips} chips cannot host a {model}-way model axis")
        data = chips // model
        # prefer a pod axis when the surviving set still spans pods
        if pods and pods > 1 and data % pods == 0:
            return MeshPlan(shape=(pods, data // pods, model),
                            axes=("pod", "data", "model"),
                            hosts=list(alive_hosts),
                            note=f"elastic: {chips} chips, {pods} pods")
        return MeshPlan(shape=(data, model), axes=("data", "model"),
                        hosts=list(alive_hosts),
                        note=f"elastic: {chips} chips, single pod")

    def reshard_plan(self, old_data: int, new_data: int
                     ) -> List[Tuple[int, List[int]]]:
        """Which old FSDP shards each new data-rank must read: contiguous
        block mapping old_data -> new_data (they divide in elastic steps)."""
        out = []
        for nd in range(new_data):
            lo = nd * old_data // new_data
            hi = (nd + 1) * old_data // new_data
            out.append((nd, list(range(lo, max(hi, lo + 1)))))
        return out
