"""Train / prefill / serve step builders.

Gradient accumulation over microbatches uses a `lax.scan` whose iteration
order is the DDAST static schedule's discovery order (core/sched):
each microbatch's grad reduce-scatter is released as soon as its backward
finishes, so XLA's latency-hiding scheduler overlaps the collective of
µbatch i with compute of µbatch i+1. Optional gradient compression casts
the accumulated grads to bf16 for the cross-pod all-reduce with an f32
error-feedback buffer kept sharded (optimizer-state-like).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.sched import DagNode, ddast_schedule
from ..models.registry import ModelAPI
from .optimizer import OptConfig, adamw_update, clip_by_global_norm

Params = Any


@dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    num_microbatches: int = 1
    aux_loss_weight: float = 0.01
    grad_compress: bool = False      # bf16 grads + error feedback
    z_loss: float = 1e-4


def microbatch_schedule(n: int) -> list:
    """DDAST-simulated order for n microbatch (fwd,bwd,reduce) chains —
    the static adaptation of the paper's manager (DESIGN.md §2)."""
    nodes = []
    for i in range(n):
        nodes.append(DagNode(name=("fwd", i), cost=2.0))
        nodes.append(DagNode(name=("bwd", i), cost=4.0, deps=[("fwd", i)]))
        nodes.append(DagNode(name=("rs", i), cost=1.0, deps=[("bwd", i)],
                             kind="collective"))
    order = ddast_schedule(nodes, num_units=2)
    return [nm[1] for nm in order if nm[0] == "fwd"]


def make_loss_fn(model: ModelAPI, tcfg: TrainConfig) -> Callable:
    def loss_fn(params: Params, batch: Dict[str, jax.Array]):
        logits, aux = model.forward(params, batch)
        labels = batch["labels"]
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
        loss = jnp.mean(nll)
        # z-loss stabilizes the softmax normalizer at scale
        zl = jnp.mean(jax.scipy.special.logsumexp(
            logits.astype(jnp.float32), axis=-1) ** 2)
        total = loss + tcfg.aux_loss_weight * aux + tcfg.z_loss * zl
        return total, {"loss": loss, "aux": aux}
    return loss_fn


def make_train_step(model: ModelAPI, tcfg: TrainConfig) -> Callable:
    loss_fn = make_loss_fn(model, tcfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    nmb = tcfg.num_microbatches

    def train_step(params: Params, opt: Dict[str, Any],
                   batch: Dict[str, jax.Array]):
        if nmb <= 1:
            (_, metrics), grads = grad_fn(params, batch)
        else:
            order = microbatch_schedule(nmb)     # static permutation

            def split(x):
                b = x.shape[0]
                x = x.reshape((nmb, b // nmb) + x.shape[1:])
                return x[jnp.asarray(order)]     # DDAST discovery order
            mbs = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                g_acc, l_acc = carry
                (_, m), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + m["loss"]), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(
                    p.shape,
                    jnp.bfloat16 if tcfg.grad_compress else jnp.float32),
                params)
            (grads, lsum), _ = jax.lax.scan(acc_fn, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: (g / nmb).astype(jnp.float32),
                                 grads)
            metrics = {"loss": lsum / nmb, "aux": jnp.zeros(())}
        grads, gnorm = clip_by_global_norm(grads, tcfg.opt.clip_norm)
        params, opt, lr = adamw_update(tcfg.opt, grads, opt, params)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return params, opt, metrics

    return train_step


def make_prefill_step(model: ModelAPI) -> Callable:
    def prefill_step(params: Params, batch: Dict[str, jax.Array]):
        logits, _ = model.forward(params, batch)
        return logits
    return prefill_step


def make_serve_step(model: ModelAPI) -> Callable:
    def serve_step(params: Params, cache: Params, tokens: jax.Array,
                   pos: jax.Array):
        logits, cache = model.decode_step(params, cache, tokens, pos)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), logits, cache
    return serve_step
