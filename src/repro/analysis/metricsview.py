"""Render a saved metrics snapshot for humans and scrapers.

Consumes the JSON written by ``repro.core.metrics.save_metrics`` (or
any ``rt.metrics()`` / ``SimResult.metrics`` /
``ServeEngine.metrics_snapshot()`` dict dumped to disk) and renders it
either as Prometheus text exposition (default — pipe it to a pushgateway
or diff it in CI) or as a Perfetto/Chrome-trace counter-track document
(``--perfetto`` — load it next to a ``traceview`` export, or merge both
with ``traceview --counters``).

CLI::

    python -m repro.analysis.metricsview run.metrics.json [-o out]
        [--perfetto] [--prefix repro]
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Optional, Sequence

from repro.core.metrics import (counter_track_events, load_metrics,
                                prometheus_text)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Render a saved repro metrics snapshot as "
                    "Prometheus text or Perfetto counter tracks")
    ap.add_argument("metrics",
                    help="JSON written by core.metrics.save_metrics")
    ap.add_argument("-o", "--out", default=None,
                    help="output path (default: stdout)")
    ap.add_argument("--perfetto", action="store_true",
                    help="emit Chrome-trace counter tracks instead of "
                         "Prometheus text")
    ap.add_argument("--prefix", default="repro",
                    help="Prometheus metric-name prefix")
    args = ap.parse_args(argv)

    snap = load_metrics(args.metrics)
    if args.perfetto:
        series = (snap.get("sampler") or {}).get("series") or {}
        doc = {"traceEvents": counter_track_events(
                   series, snap.get("time_unit") or "s"),
               "displayTimeUnit": "ms"}
        text = json.dumps(doc)
    else:
        text = prometheus_text(snap, prefix=args.prefix)

    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print(args.out)
    else:
        sys.stdout.write(text)
    return 0


if __name__ == "__main__":          # pragma: no cover
    raise SystemExit(main())
