"""Export runtime traces to Perfetto / Chrome-trace JSON.

Consumes the event timeline recorded by ``repro.core.trace`` (either a
``TraceRecorder.save`` file or an in-memory event list) and emits the
Trace Event Format that ``ui.perfetto.dev`` and ``chrome://tracing``
load directly:

  * one lane per worker slot (pid 0) with a complete-event ("X") slice
    per task body, colored by scope so tenants are visually separable;
  * instant events ("i") on the owning lane for the pre-execution
    lifecycle (``created`` / ``deps_resolved`` / ``ready``), steals
    (thief lane, victim in args) and admission deferrals;
  * one counter lane per message queue / shard mailbox (pid 1): the
    running backlog rebuilt from ``msg_enqueued`` / ``msg_drained``
    payloads ``(kind, where, n)``, keyed by ``where``;
  * vertical ``quiesce`` markers carrying the replay iteration count,
    so replayed (manager-silent) windows are visible at a glance.

CLI::

    python -m repro.analysis.traceview run.trace [-o out.json] [--detect]
        [--counters metrics.json]

``--detect`` additionally runs the detrimental-pattern detectors and
prints their findings to stderr (exit status stays 0 — detection is
reporting, not a gate). ``--counters`` merges the sampled series of a
saved metrics snapshot (``repro.core.metrics.save_metrics``) as
Perfetto counter tracks under the task slices.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Sequence

from repro.core.trace import (EV_ADMIT_DEFER, EV_COMBINE, EV_CREATED,
                              EV_DELEGATE, EV_DEPS, EV_END, EV_MSG_DRAIN,
                              EV_MSG_ENQ, EV_QUIESCE, EV_READY, EV_START,
                              EV_STEAL, TraceEvent, detect_all,
                              load_trace)

# chrome://tracing reserved color names, cycled per scope (None = the
# driver's own root context gets the first entry)
_SCOPE_COLORS = ("thread_state_running", "thread_state_iowait",
                 "thread_state_runnable", "light_memory_dump",
                 "detailed_memory_dump", "vsync_highlight_color",
                 "generic_work", "good", "bad", "terrible")

_WORKERS_PID = 0
_QUEUES_PID = 1


def _scale(time_unit: str) -> float:
    """Trace Event timestamps are microseconds."""
    return 1e6 if time_unit == "s" else 1.0


def _scope_color(scope) -> str:
    if scope is None:
        return _SCOPE_COLORS[0]
    return _SCOPE_COLORS[1 + hash(scope) % (len(_SCOPE_COLORS) - 1)]


def to_chrome_trace(events: Sequence[TraceEvent],
                    time_unit: str = "s") -> dict:
    """Build the Trace Event Format document (``{"traceEvents": [...]}``)
    from a merged event list. Start/end pairing is by ``wd_id`` (a
    body runs on one slot), so the sim's early-visibility timestamps
    cannot mis-nest slices."""
    k = _scale(time_unit)
    out: List[dict] = []
    slots_seen: set = set()
    queues_seen: set = set()
    open_start: Dict[int, TraceEvent] = {}   # wd_id -> start event
    backlog: Dict[object, int] = {}          # queue key -> depth

    for e in events:
        if e.slot >= 0:
            slots_seen.add(e.slot)
        if e.ev == EV_START:
            open_start[e.wd_id] = e
        elif e.ev == EV_END:
            s = open_start.pop(e.wd_id, None)
            if s is None:
                continue                     # start dropped by the ring
            out.append({"name": e.label or f"wd{e.wd_id}", "ph": "X",
                        "pid": _WORKERS_PID, "tid": e.slot,
                        "ts": s.t * k, "dur": max((e.t - s.t) * k, 0.0),
                        "cat": "task", "cname": _scope_color(e.scope),
                        "args": {"wd_id": e.wd_id, "scope": e.scope}})
        elif e.ev in (EV_CREATED, EV_DEPS, EV_READY, EV_STEAL,
                      EV_ADMIT_DEFER):
            args = {"wd_id": e.wd_id, "scope": e.scope}
            if e.data is not None:
                args["data"] = e.data
            out.append({"name": e.ev, "ph": "i", "s": "t",
                        "pid": _WORKERS_PID,
                        "tid": e.slot if e.slot >= 0 else 0,
                        "ts": e.t * k, "cat": "lifecycle", "args": args})
        elif e.ev in (EV_MSG_ENQ, EV_MSG_DRAIN, EV_DELEGATE):
            # delegated publications are backlog like mailbox entries;
            # the combiner's per-message msg_drained events balance them
            d = e.data
            if isinstance(d, (tuple, list)) and len(d) >= 3:
                key, n = d[1], int(d[2])
            else:
                key, n = -1, 1
            backlog[key] = backlog.get(key, 0) \
                + (-n if e.ev == EV_MSG_DRAIN else n)
            queues_seen.add(key)
            out.append({"name": f"mailbox {key}", "ph": "C",
                        "pid": _QUEUES_PID, "tid": 0, "ts": e.t * k,
                        "args": {"backlog": max(backlog[key], 0)}})
        elif e.ev == EV_COMBINE:
            d = e.data
            n = int(d[2]) if isinstance(d, (tuple, list)) \
                and len(d) >= 3 else 1
            out.append({"name": "combine", "ph": "i", "s": "t",
                        "pid": _QUEUES_PID, "tid": 0, "ts": e.t * k,
                        "cat": "sync", "args": {"portions": n}})
        elif e.ev == EV_QUIESCE:
            args = dict(e.data) if isinstance(e.data, dict) else {}
            out.append({"name": "quiesce", "ph": "i", "s": "g",
                        "pid": _WORKERS_PID, "tid": 0, "ts": e.t * k,
                        "cat": "boundary", "args": args})

    meta: List[dict] = [
        {"name": "process_name", "ph": "M", "pid": _WORKERS_PID,
         "args": {"name": "workers"}},
        {"name": "process_name", "ph": "M", "pid": _QUEUES_PID,
         "args": {"name": "queues"}},
    ]
    for s in sorted(slots_seen):
        meta.append({"name": "thread_name", "ph": "M",
                     "pid": _WORKERS_PID, "tid": s,
                     "args": {"name": f"worker {s}"}})
    return {"traceEvents": meta + out,
            "displayTimeUnit": "ms",
            "otherData": {"time_unit": time_unit,
                          "queues": sorted(queues_seen, key=str)}}


def export(trace_path: str, out_path: Optional[str] = None,
           detect: bool = False,
           counters: Optional[str] = None) -> str:
    """Convert a saved trace file; returns the output path.
    ``counters=`` merges the sampled series of a saved metrics
    snapshot (``core.metrics.save_metrics``) as Perfetto counter
    ("C") tracks on their own pid, under the task slices."""
    events, meta = load_trace(trace_path)
    doc = to_chrome_trace(events, meta.get("time_unit") or "s")
    if counters:
        from repro.core.metrics import (counter_track_events,
                                        load_metrics)
        snap = load_metrics(counters)
        series = (snap.get("sampler") or {}).get("series") or {}
        doc["traceEvents"] += counter_track_events(
            series, snap.get("time_unit") or meta.get("time_unit")
            or "s")
    out_path = out_path or trace_path + ".json"
    with open(out_path, "w") as f:
        json.dump(doc, f)
    if detect:
        for fd in detect_all(events):
            print(f"{fd.kind}: [{fd.t0:.6g}, {fd.t1:.6g}] slot={fd.slot} "
                  f"count={fd.count} {fd.detail}", file=sys.stderr)
    return out_path


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Export a repro runtime trace to Perfetto/Chrome "
                    "trace JSON")
    ap.add_argument("trace", help="file written by TraceRecorder.save")
    ap.add_argument("-o", "--out", default=None,
                    help="output path (default: <trace>.json)")
    ap.add_argument("--detect", action="store_true",
                    help="also run the detrimental-pattern detectors "
                         "and print findings to stderr")
    ap.add_argument("--counters", default=None, metavar="METRICS_JSON",
                    help="merge a saved metrics snapshot's sampled "
                         "series as counter tracks")
    args = ap.parse_args(argv)
    out = export(args.trace, args.out, detect=args.detect,
                 counters=args.counters)
    print(out)
    return 0


if __name__ == "__main__":          # pragma: no cover
    raise SystemExit(main())
