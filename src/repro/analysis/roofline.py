"""Roofline analysis from the compiled dry-run artifact.

XLA's `cost_analysis()` counts while-loop bodies ONCE, so scanned layers
and SSM time-chunks are undercounted by their trip counts. This module
parses the post-SPMD HLO text instead:

  * computation blocks and the while call graph (condition/body names),
  * trip counts recovered from each while condition's `constant(N)`,
  * per-block dot FLOPs (from shapes + contracting dims), dot operand
    bytes (HBM-traffic proxy) and collective operand bytes by kind,
  * totals = per-block values x product of enclosing trip counts.
    (This also counts remat recompute correctly — the double-compute的
    while bodies multiply out.)

Terms (per device, seconds):
  compute    = dot_flops / PEAK_FLOPS
  memory     = hbm_bytes / HBM_BW
  collective = ici_bytes / ICI_BW
Hardware: TPU v5e-class constants (197 bf16 TFLOP/s, 819 GB/s HBM,
~50 GB/s/link ICI) per the assignment.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
                "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
                "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
          "collective-permute")


def _shape_elems(dt: str, dims: str) -> Tuple[int, int]:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n, n * _DTYPE_BYTES.get(dt, 4)


def _all_shape_bytes(text: str) -> int:
    return sum(_shape_elems(m.group(1), m.group(2))[1]
               for m in _SHAPE_RE.finditer(text))


@dataclass
class BlockStats:
    dot_flops: float = 0.0
    dot_bytes: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    children: List[Tuple[str, str]] = field(default_factory=list)
    # (body_name, cond_name) for each while in this block
    calls: List[str] = field(default_factory=list)   # called computations


@dataclass
class RooflineTerms:
    flops: float
    hbm_bytes: float
    coll_bytes: Dict[str, float]
    devices: int

    @property
    def total_coll(self) -> float:
        return sum(self.coll_bytes.values())

    def seconds(self) -> Dict[str, float]:
        return {
            "compute": self.flops / self.devices / PEAK_FLOPS,
            "memory": self.hbm_bytes / self.devices / HBM_BW,
            "collective": self.total_coll / self.devices / ICI_BW,
        }

    def dominant(self) -> str:
        s = self.seconds()
        return max(s, key=s.get)


# ---------------------------------------------------------------- parsing
def _split_blocks(text: str) -> Dict[str, str]:
    """computation name -> body text."""
    blocks: Dict[str, str] = {}
    cur_name, cur_lines = None, []
    for line in text.splitlines():
        m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{", line)
        if m:
            if cur_name:
                blocks[cur_name] = "\n".join(cur_lines)
            cur_name = m.group(2)
            cur_lines = [line]
        elif cur_name is not None:
            cur_lines.append(line)
            if line.startswith("}"):
                blocks[cur_name] = "\n".join(cur_lines)
                cur_name = None
                cur_lines = []
    if cur_name:
        blocks[cur_name] = "\n".join(cur_lines)
    return blocks


_DEF_RE = re.compile(r"^\s*%?([\w.\-]+)\s*=\s*(\w+)\[([\d,]*)\]")
_PARAM_RE = re.compile(r"%?([\w.\-]+):\s*(\w+)\[([\d,]*)\]")


def _symbols(body: str) -> Dict[str, Tuple[str, str]]:
    """name -> (dtype, dims) for every op defined in the block + header
    params (tuple params resolve via their get-tuple-element lines)."""
    syms: Dict[str, Tuple[str, str]] = {}
    lines = body.splitlines()
    if lines:
        for m in _PARAM_RE.finditer(lines[0]):
            syms.setdefault(m.group(1), (m.group(2), m.group(3)))
    for line in lines[1:]:
        m = _DEF_RE.match(line)
        if m:
            syms[m.group(1)] = (m.group(2), m.group(3))
    return syms


def _dot_flops_bytes(line: str,
                     syms: Dict[str, Tuple[str, str]]) -> Tuple[float, float]:
    """FLOPs + operand/result bytes of one dot line (operand shapes
    resolved through the block symbol table)."""
    m = re.match(r"\s*%?[\w.\-]+\s*=\s*(\w+)\[([\d,]*)\][^=]*dot\(", line)
    if not m:
        return 0.0, 0.0
    out_elems, out_bytes = _shape_elems(m.group(1), m.group(2))
    mo = re.search(r"dot\(%?([\w.\-]+),\s*%?([\w.\-]+)\)", line)
    lhs_shape = syms.get(mo.group(1)) if mo else None
    rhs_shape = syms.get(mo.group(2)) if mo else None
    opnd_bytes = 0.0
    for sh in (lhs_shape, rhs_shape):
        if sh:
            opnd_bytes += _shape_elems(sh[0], sh[1])[1]
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    k = 1
    if mc and lhs_shape:
        lhs_dims = lhs_shape[1].split(",") if lhs_shape[1] else []
        for idx in mc.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                k *= int(lhs_dims[int(idx)])
    return 2.0 * out_elems * k, opnd_bytes + out_bytes


def _conv_flops(line: str) -> float:
    m = re.match(r"\s*%?[\w.\-]+\s*=\s*(\w+)\[([\d,]*)\][^=]*convolution\(",
                 line)
    if not m:
        return 0.0
    out_elems, _ = _shape_elems(m.group(1), m.group(2))
    shapes = _SHAPE_RE.findall(line)
    if len(shapes) >= 3:
        k_elems, _ = _shape_elems(shapes[2][0], shapes[2][1])
        # rough: 2 * out * (kernel elems / out-channels)
        return 2.0 * out_elems * max(k_elems, 1) ** 0.5
    return 0.0


def _block_stats(body: str) -> BlockStats:
    st = BlockStats()
    syms = _symbols(body)
    for line in body.splitlines():
        if " dot(" in line:
            f, b = _dot_flops_bytes(line, syms)
            st.dot_flops += f
            st.dot_bytes += b
        for c in _COLLS:
            if f" {c}(" in line or f"{c}-start(" in line:
                m = re.match(r"\s*%?[\w.\-]+\s*=\s*((?:\([^)]*\))|(?:\S+))\s",
                             line)
                if m:
                    st.coll_bytes[c] = st.coll_bytes.get(c, 0.0) + \
                        _all_shape_bytes(m.group(1))
        mw = re.search(r"while\(.*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)",
                       line)
        if not mw:
            mw2 = re.search(r"while\(.*?body=%?([\w.\-]+).*?condition=%?([\w.\-]+)",
                            line)
            if mw2:
                st.children.append((mw2.group(1), mw2.group(2)))
        else:
            st.children.append((mw.group(2), mw.group(1)))
        mc = re.search(r"(?:call|fusion)\(.*?(?:to_apply|calls)=%?([\w.\-]+)",
                       line)
        if mc:
            st.calls.append(mc.group(1))
    return st


def _trip_count(cond_body: str) -> int:
    """Recover the while trip count from its condition computation: the
    compare against a constant."""
    consts = [int(m.group(1)) for m in
              re.finditer(r"constant\((\d+)\)", cond_body)]
    if consts:
        return max(consts)
    return 1


def analyze_hlo(text: str, devices: int) -> RooflineTerms:
    blocks = _split_blocks(text)
    stats = {name: _block_stats(body) for name, body in blocks.items()}
    entry = None
    for name in blocks:
        if "ENTRY" in blocks[name].splitlines()[0] or name.startswith("main"):
            entry = name
            break
    if entry is None:
        entry = next(iter(blocks))

    memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}

    def visit(name: str, depth: int = 0) -> Tuple[float, float,
                                                  Dict[str, float]]:
        if name in memo:
            return memo[name]
        if name not in stats or depth > 50:
            return 0.0, 0.0, {}
        st = stats[name]
        f, b = st.dot_flops, st.dot_bytes
        c = dict(st.coll_bytes)
        for callee in st.calls:
            cf, cb, cc = visit(callee, depth + 1)
            f += cf
            b += cb
            for k, v in cc.items():
                c[k] = c.get(k, 0) + v
        for body_name, cond_name in st.children:
            trips = _trip_count(blocks.get(cond_name, ""))
            bf, bb, bc = visit(body_name, depth + 1)
            f += trips * bf
            b += trips * bb
            for k, v in bc.items():
                c[k] = c.get(k, 0) + trips * v
        memo[name] = (f, b, c)
        return memo[name]

    f, b, c = visit(entry)
    # parsed values are PER-DEVICE (post-SPMD module is the per-device
    # program); scale to global for the report
    return RooflineTerms(flops=f * devices, hbm_bytes=b * devices,
                         coll_bytes={k: v * devices for k, v in c.items()},
                         devices=devices)


# ------------------------------------------------------- analytic check
def model_flops(cfg, shape) -> float:
    """6*N(active)*D for train, 2*N*D for inference."""
    n = cfg.active_param_count()
    d = shape.global_batch * (shape.seq_len if shape.kind in
                              ("train", "prefill") else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * d
