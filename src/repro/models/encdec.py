"""Whisper-style encoder-decoder. The audio conv frontend is a STUB:
`input_specs` provides precomputed frame embeddings [B, enc_seq, d_model]
(the backbone is what the assignment specifies). Sinusoidal positions are
computed on the fly so the assigned 32k decode shapes lower cleanly."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn
from .config import ModelConfig
from .layers import (apply_mlp, apply_norm, embed_tokens, init_embed,
                     init_mlp, init_norm, unembed)

Params = Dict[str, Any]


def sinusoidal(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(10000.0) / (half - 1)))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_enc_layer(rng: jax.Array, cfg: ModelConfig) -> Params:
    k = jax.random.split(rng, 2)
    return {"norm1": init_norm(cfg), "attn": attn.init_attention(k[0], cfg),
            "norm2": init_norm(cfg), "mlp": init_mlp(k[1], cfg)}


def _init_dec_layer(rng: jax.Array, cfg: ModelConfig) -> Params:
    k = jax.random.split(rng, 3)
    return {"norm1": init_norm(cfg),
            "self_attn": attn.init_attention(k[0], cfg),
            "norm2": init_norm(cfg),
            "cross_attn": attn.init_attention(k[1], cfg, cross=True),
            "norm3": init_norm(cfg), "mlp": init_mlp(k[2], cfg)}


def init_params(rng: jax.Array, cfg: ModelConfig) -> Params:
    ke, kenc, kdec = jax.random.split(rng, 3)
    enc_keys = jax.random.split(kenc, cfg.encoder_layers)
    dec_keys = jax.random.split(kdec, cfg.num_layers)
    return {
        "embed": init_embed(ke, cfg),
        "encoder": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_final_norm": init_norm(cfg),
        "decoder": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "final_norm": init_norm(cfg),
    }


def encode(cfg: ModelConfig, params: Params, frames: jax.Array) -> jax.Array:
    """frames [B, enc_seq, d_model] (stub frontend output)."""
    s = frames.shape[1]
    x = frames + sinusoidal(jnp.arange(s), cfg.d_model)[None].astype(
        frames.dtype)

    @jax.checkpoint
    def layer_fn(x, p):
        h = apply_norm(cfg, p["norm1"], x)
        x = x + attn.attention_train(cfg, p["attn"], h, use_rope=False,
                                     causal=False)
        h = apply_norm(cfg, p["norm2"], x)
        return x + apply_mlp(cfg, p["mlp"], h), None

    x, _ = jax.lax.scan(layer_fn, x, params["encoder"])
    return apply_norm(cfg, params["enc_final_norm"], x)


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array,
            frames: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """Teacher-forced decoder over encoder memory -> (logits, aux=0)."""
    b, s = tokens.shape
    if frames is None:
        frames = jnp.zeros((b, cfg.encoder_seq, cfg.d_model), cfg.jnp_dtype)
    memory = encode(cfg, params, frames)
    x = embed_tokens(cfg, params["embed"], tokens)
    x = x + sinusoidal(jnp.arange(s), cfg.d_model)[None].astype(x.dtype)

    @jax.checkpoint
    def layer_fn(x, p):
        h = apply_norm(cfg, p["norm1"], x)
        x = x + attn.attention_train(cfg, p["self_attn"], h, use_rope=False)
        h = apply_norm(cfg, p["norm2"], x)
        x = x + attn.attention_train(cfg, p["cross_attn"], h,
                                     use_rope=False, memory=memory)
        h = apply_norm(cfg, p["norm3"], x)
        return x + apply_mlp(cfg, p["mlp"], h), None

    x, _ = jax.lax.scan(layer_fn, x, params["decoder"])
    x = apply_norm(cfg, params["final_norm"], x)
    return unembed(cfg, params["embed"], x), jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    """Self-attn KV caches + precomputed cross-KV slots, stacked [L,...]."""
    kv = attn.init_kv_cache(cfg, batch, max_len)
    hd = cfg.resolved_head_dim
    cross_shape = (cfg.num_layers, batch, cfg.encoder_seq,
                   cfg.num_kv_heads, hd)
    return {
        "self": jax.tree.map(
            lambda a: jnp.broadcast_to(
                a, (cfg.num_layers,) + a.shape).copy(), kv),
        "cross_k": jnp.zeros(cross_shape, cfg.jnp_dtype),
        "cross_v": jnp.zeros(cross_shape, cfg.jnp_dtype),
    }


def fill_cross_cache(cfg: ModelConfig, params: Params, cache: Params,
                     frames: jax.Array) -> Params:
    """Run the encoder once and precompute every layer's cross-KV."""
    memory = encode(cfg, params, frames)

    def per_layer(p):
        kv = attn.precompute_cross_kv(cfg, p["cross_attn"], memory)
        return kv["k"], kv["v"]

    ck, cv = jax.vmap(per_layer)(params["decoder"])
    return {**cache, "cross_k": ck, "cross_v": cv}


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                tokens: jax.Array, pos: jax.Array
                ) -> Tuple[jax.Array, Params]:
    b = tokens.shape[0]
    x = embed_tokens(cfg, params["embed"], tokens[:, None])
    pos_b = jnp.broadcast_to(pos, (b,))
    x = x + sinusoidal(pos_b, cfg.d_model)[:, None, :].astype(x.dtype)

    def layer_fn(x, slices):
        p, kv, ck, cv = slices
        h = apply_norm(cfg, p["norm1"], x)
        h, kv = attn.attention_decode(cfg, p["self_attn"], h, kv, pos,
                                      use_rope=False)
        x = x + h
        h = apply_norm(cfg, p["norm2"], x)
        h, _ = attn.attention_decode(cfg, p["cross_attn"], h, kv, pos,
                                     memory_kv={"k": ck, "v": cv})
        x = x + h
        h = apply_norm(cfg, p["norm3"], x)
        x = x + apply_mlp(cfg, p["mlp"], h)
        return x, kv

    x, new_self = jax.lax.scan(
        layer_fn, x,
        (params["decoder"], cache["self"], cache["cross_k"],
         cache["cross_v"]))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params["embed"], x)
    return logits[:, 0], {**cache, "self": new_self}
