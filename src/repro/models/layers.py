"""Shared layers: norms, RoPE, MLPs, embeddings. Pure-functional:
`init_*` returns a param pytree, `apply`-style functions are stateless."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..parallel.collectives import constrain
from .config import ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------- norms
def init_norm(cfg: ModelConfig, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), cfg.jnp_dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), cfg.jnp_dtype)
    return p


def apply_norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------- rope
def rope_cos_sin(positions: jax.Array, head_dim: int,
                 theta: float) -> tuple[jax.Array, jax.Array]:
    """positions [...,] int32 -> cos/sin [..., head_dim/2] f32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [..., n_heads, head_dim]; cos/sin broadcastable [..., hd/2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[..., None, :]          # broadcast over heads
    sin = sin[..., None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------- mlp
def init_mlp(rng: jax.Array, cfg: ModelConfig, d_ff: Optional[int] = None,
             d_in: Optional[int] = None) -> Params:
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    dt = cfg.jnp_dtype
    k = jax.random.split(rng, 3)
    s_in = (2.0 / (d + f)) ** 0.5
    if cfg.act == "silu":  # gated
        return {"w_gate": jax.random.normal(k[0], (d, f), dt) * s_in,
                "w_up": jax.random.normal(k[1], (d, f), dt) * s_in,
                "w_down": jax.random.normal(k[2], (f, d), dt) * s_in}
    return {"w_up": jax.random.normal(k[0], (d, f), dt) * s_in,
            "b_up": jnp.zeros((f,), dt),
            "w_down": jax.random.normal(k[1], (f, d), dt) * s_in,
            "b_down": jnp.zeros((d,), dt)}


def apply_mlp(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    nd = x.ndim
    mid = ("dp",) + (None,) * (nd - 2) + ("model",)
    out = ("dp",) + (None,) * (nd - 1)
    if cfg.act == "silu":
        h = constrain(jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"]), *mid)
        return constrain(h @ p["w_down"], *out)
    h = constrain(jax.nn.gelu(x @ p["w_up"] + p["b_up"]), *mid)
    return constrain(h @ p["w_down"] + p["b_down"], *out)


# ---------------------------------------------------------------- embed
def padded_vocab(cfg: ModelConfig, multiple: int = 256) -> int:
    """Vocab padded so the embedding table shards on any mesh axis we use
    (whisper's 51865 is prime-ish; everything shards once padded)."""
    v = cfg.vocab_size
    return ((v + multiple - 1) // multiple) * multiple


def init_embed(rng: jax.Array, cfg: ModelConfig) -> Params:
    v = padded_vocab(cfg)
    dt = cfg.jnp_dtype
    k1, k2 = jax.random.split(rng)
    p = {"embedding": jax.random.normal(k1, (v, cfg.d_model), dt) * 0.02}
    if not cfg.tie_embeddings:
        p["unembed"] = jax.random.normal(k2, (cfg.d_model, v), dt) * 0.02
    return p


def embed_tokens(cfg: ModelConfig, p: Params, tokens: jax.Array) -> jax.Array:
    x = p["embedding"][tokens]
    if cfg.name.startswith("gemma2"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def unembed(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = x @ p["embedding"].T
    else:
        logits = x @ p["unembed"]
    if cfg.logits_softcap:
        c = cfg.logits_softcap
        logits = jnp.tanh(logits / c) * c
    return logits


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap
