"""Mixture-of-Experts with GROUP-LOCAL sort-based capacity dispatch.

TPU/SPMD adaptation: a single global argsort over all B*S*k assignments
would be partitioned as a *global* sort — XLA SPMD lowers that to full
rematerialization (replicate + resort), which dry-run analysis showed to
be the dominant collective cost. Instead, routing/sorting/packing happen
independently per batch row (the batch dim is the sharded data dim), so
every sort/cumsum is device-local; tokens then meet the expert-sharded
weights in one grouped matmul whose input layout change IS the all-to-all
(E-major), which GSPMD lowers to the canonical MoE token exchange.

Supports routed top-k + shared experts (Qwen2-MoE) and router-logit
masking for padded experts (expert counts that don't divide the EP axis,
e.g. 60, pad to a shardable count WITHOUT changing routing semantics).

Capacity is per (row, expert): C = ceil(S*k/E * capacity_factor)
(overflow tokens drop — standard TPU MoE).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops as kops
from ..parallel.collectives import constrain, moe_mode
from .config import ModelConfig
from .layers import init_mlp

Params = Dict[str, Any]


def padded_experts(cfg: ModelConfig, multiple: int = 16) -> int:
    e = cfg.num_experts
    return ((e + multiple - 1) // multiple) * multiple


def init_moe(rng: jax.Array, cfg: ModelConfig) -> Params:
    d, f = cfg.d_model, cfg.moe_d_ff
    ep = padded_experts(cfg)
    dt = cfg.jnp_dtype
    k = jax.random.split(rng, 5)
    s = (2.0 / (d + f)) ** 0.5
    p = {
        "router": jax.random.normal(k[0], (d, ep), jnp.float32) * 0.02,
        "w_gate": jax.random.normal(k[1], (ep, d, f), dt) * s,
        "w_up": jax.random.normal(k[2], (ep, d, f), dt) * s,
        "w_down": jax.random.normal(k[3], (ep, f, d), dt) * s,
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(k[4], cfg, d_ff=f * cfg.num_shared_experts)
    return p


def _capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    ep = padded_experts(cfg)
    c = int(tokens_per_group * cfg.experts_per_tok
            * cfg.capacity_factor / ep)
    return max(4, ((c + 3) // 4) * 4)


def apply_moe(cfg: ModelConfig, p: Params, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """x [B,S,d] -> (y [B,S,d], aux_loss). All dispatch ops are local to
    each batch row (see module docstring)."""
    b, s, d = x.shape
    e_real, e_pad = cfg.num_experts, padded_experts(cfg)
    k = cfg.experts_per_tok
    cap = _capacity(cfg, s)
    nk = s * k

    logits = x.astype(jnp.float32) @ p["router"]          # [B,S,E]
    if e_pad > e_real:
        pad_mask = jnp.arange(e_pad) >= e_real
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                # [B,S,k]
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # Switch-style load-balance aux loss (global means are cheap scalars)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(top_i[..., 0], e_pad), axis=(0, 1))
    aux = jnp.sum(me * ce) * e_real

    # ---- group-local dispatch (everything [B, ...] => local) ----------
    flat_e = top_i.reshape(b, nk)                         # expert per slot
    flat_t = jnp.broadcast_to(jnp.arange(s)[:, None], (s, k)).reshape(nk)
    flat_w = top_w.reshape(b, nk)
    order = jnp.argsort(flat_e, axis=1)                   # local sort
    se = jnp.take_along_axis(flat_e, order, axis=1)       # [B,nk]
    st = flat_t[order]                                    # token idx [B,nk]
    sw = jnp.take_along_axis(flat_w, order, axis=1)
    onehot = jax.nn.one_hot(se, e_pad, dtype=jnp.int32)   # [B,nk,E]
    rank = jnp.take_along_axis(
        jnp.cumsum(onehot, axis=1), se[..., None], axis=2)[..., 0] - 1
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, e_pad * cap)  # drop bin last

    def pack_row(xr, str_, slotr):                        # per batch row
        buf = jnp.zeros((e_pad * cap + 1, d), xr.dtype)
        return buf.at[slotr].set(xr[str_])[:-1]

    # 2D-shard the packed buffer immediately: batch on data AND the E*C
    # slot dim on model. The scatter handles the slot dim shard-locally
    # (bounds masking), and the later [B,E,C,d]->[E,B,C,d] transpose then
    # never migrates data between mesh axes — this XLA's SPMD lowers
    # data<->model migration as full rematerialization (b/433785288).
    # Decode-sized buffers (s==1: a few slots per row) skip the slot-dim
    # sharding — 2D-sharding tiny buffers only adds resharding churn
    # (measured: jamba decode regressed 2.3x with it).
    # decode (s==1) with SMALL buffers: replicate them fully (one cheap
    # gather) so the subsequent E-sharding is a local slice, never a
    # data<->model migration. Large-expert decode (qwen3: 128e) keeps the
    # sharded path — replication there costs 4x (measured).
    decode = s == 1 and b * e_pad * cap * d < (1 << 26)
    # slot-dim 2D sharding only pays off for big (train/prefill) buffers
    slot_ax = "model" if (moe_mode() == "ep" and not decode
                          and e_pad * cap >= 4096) else None
    batch_ax = None if decode else "dp"
    grouped = constrain(jax.vmap(pack_row)(x, st, slot),
                        batch_ax, slot_ax, None)      # [B,E*C,d]
    # E-major layout change == the MoE all-to-all (B-shard -> E-shard).
    # Constrain the 4D [E,B,cap,d] form BEFORE merging (B,cap): with B its
    # own sharded dim the reshard is a clean all-to-all; merging first
    # made GSPMD fall back to full all-gathers (10.7 GB/op, dry-run
    # measured). Experts pin to model, batch stays on data, so the
    # grouped matmuls gather only the small FSDP weight shards.
    # MoE dataflow choice (EXPERIMENTS.md §Perf): "ep" pins experts on the
    # model axis and moves token buffers; "gather" keeps tokens where
    # their batch rows live and lets GSPMD gather the (smaller) weight
    # shards instead — optimal when per-layer expert weights are smaller
    # than the k-times-replicated token buffers.
    e_ax = "model" if moe_mode() == "ep" else None
    tok_ax = None if decode else "dp"
    grouped4 = grouped.reshape(b, e_pad, cap, d).transpose(1, 0, 2, 3)
    grouped4 = constrain(grouped4, e_ax, tok_ax, None, None)
    grouped = constrain(grouped4.reshape(e_pad, b * cap, d),
                        e_ax, tok_ax, None)

    h = constrain(kops.moe_gemm(grouped, p["w_gate"]), e_ax, tok_ax, None)
    hu = constrain(kops.moe_gemm(grouped, p["w_up"]), e_ax, tok_ax, None)
    out = constrain(kops.moe_gemm(jax.nn.silu(h) * hu, p["w_down"]),
                    e_ax, tok_ax, None)               # [E,B*C,d]

    # ---- combine (inverse all-to-all, then local gather/scatter) ------
    out4 = constrain(out.reshape(e_pad, b, cap, d), e_ax, tok_ax, None,
                     None)
    # symmetric 2D constraint: keep E on model through the transpose so
    # the reshard is an axis-preserving all-to-all, not a migration.
    # decode: replicate (tiny) then re-shard batch — both local-ish.
    slot_back = None if decode else e_ax
    outb = constrain(out4.transpose(1, 0, 2, 3), batch_ax, slot_back,
                     None, None).reshape(b, e_pad * cap, d)
    outb = constrain(outb, "dp", slot_back, None)

    def combine_row(outr, slotr, str_, swr, keepr):
        # combine stays in the activation dtype: an f32 combine would
        # make the whole 10x-capacity exchange buffer (and its gradient)
        # f32 — dry-run measured that as 2x the MoE collective bytes
        vals = outr[jnp.where(keepr, slotr, 0)]           # [nk,d]
        vals = jnp.where(keepr[:, None], vals, 0.0)
        yr = jnp.zeros((s, d), outr.dtype)
        return yr.at[str_].add(vals * swr[:, None].astype(outr.dtype))

    yf = constrain(jax.vmap(combine_row)(outb, slot, st, sw, keep),
                   "dp", None, None)                  # [B,S,d]

    if cfg.num_shared_experts:
        from .layers import apply_mlp
        yf = yf + apply_mlp(cfg, p["shared"],
                            x.reshape(b * s, d)).reshape(b, s, d)
    return yf.astype(x.dtype), aux
