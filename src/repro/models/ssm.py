"""State-space / recurrent mixers: Mamba (Jamba's SSM layers) and
xLSTM's mLSTM + sLSTM blocks. All are O(seq) — these are the mixers that
make the long_500k shape runnable.

Memory discipline: the recurrences NEVER materialize [B,S,D,N] (or the
[B,S,H,hd,hd] matrix-memory trail). Scans carry the state and emit only
y_t; `chunked_scan` wraps the inner scan in jax.checkpoint so the backward
pass stores chunk-boundary carries only.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops as kops
from .config import ModelConfig

Params = Dict[str, Any]


# ------------------------------------------------------------------ util
def chunked_scan(step, carry, xs_time_major, chunk: int = 128):
    """lax.scan over time split into remat'd chunks. xs leaves [S, ...]."""
    s = jax.tree_util.tree_leaves(xs_time_major)[0].shape[0]
    if s % chunk == 0 and s > chunk:
        nc = s // chunk
        xs_c = jax.tree.map(
            lambda x: x.reshape((nc, chunk) + x.shape[1:]), xs_time_major)

        @jax.checkpoint
        def chunk_body(c, xc):
            return jax.lax.scan(step, c, xc)

        carry, ys = jax.lax.scan(chunk_body, carry, xs_c)
        ys = jax.tree.map(
            lambda y: y.reshape((s,) + y.shape[2:]), ys)
        return carry, ys
    return jax.lax.scan(step, carry, xs_time_major)


def _dt_rank(cfg: ModelConfig) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


# ================================================================= Mamba
def init_mamba(rng: jax.Array, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_d_state
    dr = _dt_rank(cfg)
    dt = cfg.jnp_dtype
    k = jax.random.split(rng, 6)
    s = (1.0 / d) ** 0.5
    return {
        "in_proj": jax.random.normal(k[0], (d, 2 * di), dt) * s,
        "conv_w": jax.random.normal(k[1], (cfg.ssm_d_conv, di), dt) * 0.2,
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": jax.random.normal(k[2], (di, dr + 2 * n), dt) * s,
        "dt_proj": jax.random.normal(k[3], (dr, di), dt) * (dr ** -0.5),
        "dt_bias": jnp.zeros((di,), dt),
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))),
        "d": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(k[4], (di, d), dt) * s,
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d: x [B,S,D], w [K,D]."""
    k = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(k):
        shift = k - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xi * w[i][None, None, :]
    return out + b[None, None, :]


def mamba_train(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    """x [B,S,d] -> [B,S,d] (full-sequence selective scan)."""
    b, s, d = x.shape
    di = cfg.ssm_expand * d
    n = cfg.ssm_d_state
    dr = _dt_rank(cfg)
    xz = x @ p["in_proj"]
    xin, z = xz[..., :di], xz[..., di:]
    xin = jax.nn.silu(_causal_conv(xin, p["conv_w"], p["conv_b"]))
    dbc = xin @ p["x_proj"]
    dt_r = dbc[..., :dr]
    bmat = dbc[..., dr:dr + n]
    cmat = dbc[..., dr + n:]
    dt = jax.nn.softplus(dt_r @ p["dt_proj"] + p["dt_bias"])
    y, _ = kops.selective_scan(xin, dt, p["a_log"], bmat, cmat, p["d"])
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"]


def init_mamba_state(cfg: ModelConfig, batch: int) -> Params:
    di = cfg.ssm_expand * cfg.d_model
    return {"h": jnp.zeros((batch, di, cfg.ssm_d_state), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm_d_conv - 1, di),
                              cfg.jnp_dtype)}


def mamba_decode(cfg: ModelConfig, p: Params, x: jax.Array,
                 state: Params) -> Tuple[jax.Array, Params]:
    """One-step recurrence. x [B,1,d]."""
    b = x.shape[0]
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_d_state
    dr = _dt_rank(cfg)
    xz = x[:, 0] @ p["in_proj"]
    xin, z = xz[..., :di], xz[..., di:]
    # conv over buffered history
    hist = jnp.concatenate([state["conv"],
                            xin[:, None, :].astype(state["conv"].dtype)],
                           axis=1)                     # [B,K,di]
    conv = jnp.einsum("bkd,kd->bd", hist.astype(jnp.float32),
                      p["conv_w"].astype(jnp.float32)) + p["conv_b"]
    xin = jax.nn.silu(conv).astype(x.dtype)
    dbc = xin @ p["x_proj"]
    dt_r, bmat, cmat = (dbc[..., :dr], dbc[..., dr:dr + n],
                        dbc[..., dr + n:])
    dt = jax.nn.softplus(dt_r @ p["dt_proj"] + p["dt_bias"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = jnp.exp(dt.astype(jnp.float32)[..., None] * a[None])
    h = da * state["h"] + (dt * xin).astype(jnp.float32)[..., None] \
        * bmat.astype(jnp.float32)[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, cmat.astype(jnp.float32)) \
        + xin.astype(jnp.float32) * p["d"][None]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = (y @ p["out_proj"])[:, None, :]
    return out, {"h": h, "conv": hist[:, 1:]}


# ================================================================= mLSTM
def init_mlstm(rng: jax.Array, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    h = cfg.num_heads
    hd = cfg.resolved_head_dim
    dt = cfg.jnp_dtype
    k = jax.random.split(rng, 6)
    s = (1.0 / d) ** 0.5
    return {
        "wq": jax.random.normal(k[0], (d, h * hd), dt) * s,
        "wk": jax.random.normal(k[1], (d, h * hd), dt) * s,
        "wv": jax.random.normal(k[2], (d, h * hd), dt) * s,
        "w_i": jax.random.normal(k[3], (d, h), jnp.float32) * s,
        "w_f": jax.random.normal(k[4], (d, h), jnp.float32) * s,
        "b_i": jnp.zeros((h,), jnp.float32),
        "b_f": jnp.ones((h,), jnp.float32) * 3.0,   # open forget gates
        "out_proj": jax.random.normal(k[5], (h * hd, d), dt) * s,
    }


def _mlstm_step(carry, inp):
    """carry: (C [B,H,hd,hd], n [B,H,hd], m [B,H]); inp per-step tensors."""
    c, n, m = carry
    qt, kt, vt, it, ft = inp        # [B,H,hd] x3, [B,H] x2
    log_f = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(log_f + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c = f_p[..., None, None] * c + i_p[..., None, None] * \
        (vt[..., :, None] * kt[..., None, :])         # [B,H,hd,hd]
    n = f_p[..., None] * n + i_p[..., None] * kt
    num = jnp.einsum("bhvk,bhk->bhv", c, qt)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)), 1.0)
    y = num / den[..., None]
    return (c, n, m_new), y


def mlstm_train(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, h, hd).astype(jnp.float32) * hd ** -0.5
    k = (x @ p["wk"]).reshape(b, s, h, hd).astype(jnp.float32)
    v = (x @ p["wv"]).reshape(b, s, h, hd).astype(jnp.float32)
    ig = x.astype(jnp.float32) @ p["w_i"] + p["b_i"]
    fg = x.astype(jnp.float32) @ p["w_f"] + p["b_f"]
    carry = (jnp.zeros((b, h, hd, hd), jnp.float32),
             jnp.zeros((b, h, hd), jnp.float32),
             jnp.zeros((b, h), jnp.float32))
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, ig, fg))
    _, ys = chunked_scan(_mlstm_step, carry, xs, chunk=128)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h * hd).astype(x.dtype)
    return y @ p["out_proj"]


def init_mlstm_state(cfg: ModelConfig, batch: int) -> Params:
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    return {"c": jnp.zeros((batch, h, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, h, hd), jnp.float32),
            "m": jnp.zeros((batch, h), jnp.float32)}


def mlstm_decode(cfg: ModelConfig, p: Params, x: jax.Array,
                 state: Params) -> Tuple[jax.Array, Params]:
    b = x.shape[0]
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    xt = x[:, 0]
    q = (xt @ p["wq"]).reshape(b, h, hd).astype(jnp.float32) * hd ** -0.5
    k = (xt @ p["wk"]).reshape(b, h, hd).astype(jnp.float32)
    v = (xt @ p["wv"]).reshape(b, h, hd).astype(jnp.float32)
    ig = xt.astype(jnp.float32) @ p["w_i"] + p["b_i"]
    fg = xt.astype(jnp.float32) @ p["w_f"] + p["b_f"]
    (c, n, m), y = _mlstm_step((state["c"], state["n"], state["m"]),
                               (q, k, v, ig, fg))
    out = (y.reshape(b, h * hd).astype(x.dtype) @ p["out_proj"])[:, None]
    return out, {"c": c, "n": n, "m": m}


# ================================================================= sLSTM
def init_slstm(rng: jax.Array, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    h = cfg.num_heads
    hd = cfg.resolved_head_dim
    dt = cfg.jnp_dtype
    k = jax.random.split(rng, 3)
    s = (1.0 / d) ** 0.5
    return {
        # input weights for i,f,z,o stacked: [d, 4*H*hd]
        "w_x": jax.random.normal(k[0], (d, 4 * h * hd), dt) * s,
        # block-diagonal recurrent weights per head: [4, H, hd, hd]
        "w_r": jax.random.normal(k[1], (4, h, hd, hd), jnp.float32)
        * (hd ** -0.5),
        "bias": jnp.zeros((4, h, hd), jnp.float32),
        "out_proj": jax.random.normal(k[2], (h * hd, d), dt) * s,
    }


def _slstm_step(p_wr, p_b):
    def step(carry, xt):
        c, n, hprev, m = carry                   # [B,H,hd] x3, [B,H,hd]
        # xt: [B,4,H,hd] pre-activations from input
        rec = jnp.einsum("khvw,bhw->bkhv", p_wr, hprev)
        pre = xt + rec + p_b[None]
        it, ft, zt, ot = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
        log_f = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(log_f + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(log_f + m - m_new)
        c = f_p * c + i_p * jnp.tanh(zt)
        n = f_p * n + i_p
        hnew = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1.0)
        return (c, n, hnew, m_new), hnew
    return step


def slstm_train(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    pre = (x @ p["w_x"]).reshape(b, s, 4, h, hd).astype(jnp.float32)
    carry = tuple(jnp.zeros((b, h, hd), jnp.float32) for _ in range(4))
    xs = jnp.moveaxis(pre, 1, 0)
    _, ys = chunked_scan(_slstm_step(p["w_r"], p["bias"]), carry, xs,
                         chunk=128)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h * hd).astype(x.dtype)
    return y @ p["out_proj"]


def init_slstm_state(cfg: ModelConfig, batch: int) -> Params:
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    z = jnp.zeros((batch, h, hd), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z}


def slstm_decode(cfg: ModelConfig, p: Params, x: jax.Array,
                 state: Params) -> Tuple[jax.Array, Params]:
    b = x.shape[0]
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    pre = (x[:, 0] @ p["w_x"]).reshape(b, 4, h, hd).astype(jnp.float32)
    step = _slstm_step(p["w_r"], p["bias"])
    (c, n, hn, m), y = step((state["c"], state["n"], state["h"],
                             state["m"]), pre)
    out = (y.reshape(b, h * hd).astype(x.dtype) @ p["out_proj"])[:, None]
    return out, {"c": c, "n": n, "h": hn, "m": m}
