"""Grouped-query attention with the features the assigned pool needs:
GQA (any nq/nkv ratio), optional QKV bias (Qwen2), sliding-window local
attention + attn-logit softcapping (Gemma-2), cross-attention (Whisper),
RoPE or NoPE. Train path and single-token decode path with KV cache.

The inner attention math routes through `repro.kernels.ops.attention`,
which dispatches to the Pallas flash kernel on TPU and to the pure-jnp
reference elsewhere — the kernel and this module share one contract
(structured causal/window/kv_len arguments, never materialized masks, so
the flash kernel can exploit them for block skipping).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops as kops
from ..parallel.collectives import constrain
from .config import ModelConfig
from .layers import apply_rope, rope_cos_sin

Params = Dict[str, Any]


def init_attention(rng: jax.Array, cfg: ModelConfig,
                   cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    dt = cfg.jnp_dtype
    k = jax.random.split(rng, 4)
    s = (1.0 / d) ** 0.5
    p = {"wq": jax.random.normal(k[0], (d, nq * hd), dt) * s,
         "wk": jax.random.normal(k[1], (d, nkv * hd), dt) * s,
         "wv": jax.random.normal(k[2], (d, nkv * hd), dt) * s,
         "wo": jax.random.normal(k[3], (nq * hd, d), dt) * s}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq * hd,), dt)
        p["bk"] = jnp.zeros((nkv * hd,), dt)
        p["bv"] = jnp.zeros((nkv * hd,), dt)
    _ = cross
    return p


def _project_q(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    b, s, _ = x.shape
    q = constrain(x @ p["wq"], "dp", None, "model")
    if cfg.qkv_bias:
        q = q + p["bq"]
    return q.reshape(b, s, cfg.num_heads, cfg.resolved_head_dim)


def _project_kv(cfg: ModelConfig, p: Params,
                x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    b, s, _ = x.shape
    k = constrain(x @ p["wk"], "dp", None, "model")
    v = constrain(x @ p["wv"], "dp", None, "model")
    if cfg.qkv_bias:
        k = k + p["bk"]
        v = v + p["bv"]
    hd = cfg.resolved_head_dim
    return (k.reshape(b, s, cfg.num_kv_heads, hd),
            v.reshape(b, s, cfg.num_kv_heads, hd))


def attention_train(cfg: ModelConfig, p: Params, x: jax.Array,
                    local: bool = False, use_rope: bool = True,
                    memory: Optional[jax.Array] = None,
                    causal: bool = True) -> jax.Array:
    """Full-sequence attention. `memory` given -> cross-attention (no
    causal mask, no rope). `causal=False` + no memory -> bidirectional
    self-attention (whisper encoder)."""
    b, s, _ = x.shape
    q = _project_q(cfg, p, x)
    kv_src = memory if memory is not None else x
    k, v = _project_kv(cfg, p, kv_src)
    if memory is None and use_rope:
        pos = jnp.arange(s)
        cos, sin = rope_cos_sin(pos, cfg.resolved_head_dim, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    is_causal = causal and memory is None
    o = kops.attention(q, k, v, causal=is_causal,
                       window=cfg.sliding_window if (local and is_causal) else None,
                       softcap=cfg.attn_softcap)
    return constrain(o.reshape(b, s, -1) @ p["wo"], "dp", None, None)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    hd = cfg.resolved_head_dim
    shape = (batch, max_len, cfg.num_kv_heads, hd)
    return {"k": jnp.zeros(shape, cfg.jnp_dtype),
            "v": jnp.zeros(shape, cfg.jnp_dtype)}


def attention_decode(cfg: ModelConfig, p: Params, x: jax.Array,
                     cache: Params, pos: jax.Array, local: bool = False,
                     use_rope: bool = True,
                     memory_kv: Optional[Params] = None
                     ) -> Tuple[jax.Array, Params]:
    """One-token decode. x [B,1,d]; cache k/v [B,L,nkv,hd]; pos scalar.
    `memory_kv` given -> cross-attention against precomputed encoder KV
    (cache passes through unchanged)."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    q = _project_q(cfg, p, x)                        # [B,1,nq,hd]
    # decode activations are replicated on the model axis: the cache is
    # context-parallel (length on "model"), so attention reduces over the
    # sharded length with per-step psums — head-sharded activations would
    # misalign with GQA head counts and gather the cache instead
    q = constrain(q, "dp", None, None, None)
    if memory_kv is not None:
        o = kops.attention(q, memory_kv["k"], memory_kv["v"],
                           softcap=cfg.attn_softcap)
        return o.reshape(b, 1, -1) @ p["wo"], cache
    kn, vn = _project_kv(cfg, p, x)                  # [B,1,nkv,hd]
    pos_b = jnp.broadcast_to(pos, (b,))              # scalar or per-slot [B]
    if use_rope:
        cos, sin = rope_cos_sin(pos_b[:, None], hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)                  # cos/sin [B,1,hd/2]
        kn = apply_rope(kn, cos, sin)

    if jnp.ndim(pos) == 0:
        # uniform position (the large-scale serving path): a single
        # dynamic_update_slice keeps the batch-sharded cache update local.
        # The vmap'd per-slot variant lowers to a scatter that SPMD can
        # only realize by replicating the cache (dry-run measured ~cache-
        # sized all-gathers per step).
        k = jax.lax.dynamic_update_slice(
            cache["k"], kn.astype(cache["k"].dtype), (0, pos, 0, 0))
        v = jax.lax.dynamic_update_slice(
            cache["v"], vn.astype(cache["v"].dtype), (0, pos, 0, 0))
    else:
        def _ins(c, upd, p_):
            return jax.lax.dynamic_update_slice(c, upd.astype(c.dtype),
                                                (p_, 0, 0))

        k = jax.vmap(_ins)(cache["k"], kn, pos_b)
        v = jax.vmap(_ins)(cache["v"], vn, pos_b)
    o = kops.attention(q, k, v, kv_len=pos_b + 1,
                       window=cfg.sliding_window if local else None,
                       softcap=cfg.attn_softcap)
    return o.reshape(b, 1, -1) @ p["wo"], {"k": k, "v": v}


def precompute_cross_kv(cfg: ModelConfig, p: Params,
                        memory: jax.Array) -> Params:
    k, v = _project_kv(cfg, p, memory)
    return {"k": k, "v": v}
