"""Uniform model API over the decoder-only family and the enc-dec family,
plus `input_specs` — the ShapeDtypeStruct stand-ins every dry-run cell
lowers against (weak-type-correct, shardable, no device allocation)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import encdec, transformer
from .config import ModelConfig, ShapeSpec

Params = Dict[str, Any]


@dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init_params: Callable[[jax.Array], Params]
    forward: Callable[..., Tuple[jax.Array, jax.Array]]
    decode_step: Callable[..., Tuple[jax.Array, Params]]
    init_cache: Callable[[int, int], Params]


def get_model(cfg: ModelConfig) -> ModelAPI:
    if cfg.is_encoder_decoder:
        return ModelAPI(
            cfg=cfg,
            init_params=lambda rng: encdec.init_params(rng, cfg),
            forward=lambda params, batch: encdec.forward(
                cfg, params, batch["tokens"], frames=batch.get("frames")),
            decode_step=lambda params, cache, tokens, pos:
                encdec.decode_step(cfg, params, cache, tokens, pos),
            init_cache=lambda batch, max_len:
                encdec.init_cache(cfg, batch, max_len),
        )
    return ModelAPI(
        cfg=cfg,
        init_params=lambda rng: transformer.init_params(rng, cfg),
        forward=lambda params, batch: transformer.forward(
            cfg, params, batch["tokens"], embeds=batch.get("embeds")),
        decode_step=lambda params, cache, tokens, pos:
            transformer.decode_step(cfg, params, cache, tokens, pos),
        init_cache=lambda batch, max_len:
            transformer.init_cache(cfg, batch, max_len),
    )


def param_specs(cfg: ModelConfig) -> Params:
    """ShapeDtypeStruct pytree of the parameters (no allocation)."""
    model = get_model(cfg)
    return jax.eval_shape(model.init_params, jax.random.key(0))


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    model = get_model(cfg)
    return jax.eval_shape(lambda: model.init_cache(batch, max_len))


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Stand-ins for every model input of the given shape cell.

    train/prefill -> {tokens, labels[, frames]}
    decode        -> {tokens [B], pos scalar, cache pytree}
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        specs: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
        if cfg.is_encoder_decoder:
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), cfg.jnp_dtype)
        return specs
    # decode: one new token against a seq_len KV cache
    return {
        "tokens": jax.ShapeDtypeStruct((b,), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
        "cache": cache_specs(cfg, b, s),
    }
