"""Pattern-based decoder-only LM covering dense / MoE / hybrid / SSM / VLM
families. Layers = `cfg.pattern` repeated `cfg.repeats` times; parameters
for each pattern position are stacked over repeats so the whole stack is a
single `lax.scan` (small HLO even at 94 layers), with jax.checkpoint remat
per period.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel.collectives import constrain
from . import attention as attn
from . import ssm
from .config import BlockSpec, ModelConfig
from .layers import (apply_norm, embed_tokens, init_embed, init_mlp,
                     init_norm, apply_mlp, unembed)
from .moe import apply_moe, init_moe

Params = Dict[str, Any]


# ------------------------------------------------------------ block init
def _init_mixer(rng: jax.Array, cfg: ModelConfig, kind: str) -> Params:
    if kind in ("attn", "attn_local"):
        return attn.init_attention(rng, cfg)
    if kind == "mamba":
        return ssm.init_mamba(rng, cfg)
    if kind == "mlstm":
        return ssm.init_mlstm(rng, cfg)
    if kind == "slstm":
        return ssm.init_slstm(rng, cfg)
    raise ValueError(f"unknown mixer {kind!r}")


def init_block(rng: jax.Array, cfg: ModelConfig, bspec: BlockSpec) -> Params:
    k = jax.random.split(rng, 4)
    p: Params = {"norm_mixer": init_norm(cfg),
                 "mixer": _init_mixer(k[0], cfg, bspec.mixer)}
    if cfg.post_norm:
        p["post_norm_mixer"] = init_norm(cfg)
    if bspec.ffn == "mlp":
        p["norm_ffn"] = init_norm(cfg)
        p["ffn"] = init_mlp(k[1], cfg)
    elif bspec.ffn == "moe":
        p["norm_ffn"] = init_norm(cfg)
        p["ffn"] = init_moe(k[1], cfg)
    if cfg.post_norm and bspec.ffn != "none":
        p["post_norm_ffn"] = init_norm(cfg)
    return p


def init_params(rng: jax.Array, cfg: ModelConfig) -> Params:
    """Full parameter pytree; per-position leaves stacked over repeats."""
    k_embed, k_layers, k_final = jax.random.split(rng, 3)
    layers = []
    for pos, bspec in enumerate(cfg.pattern):
        keys = jax.random.split(jax.random.fold_in(k_layers, pos),
                                cfg.repeats)
        stacked = jax.vmap(lambda kk: init_block(kk, cfg, bspec))(keys)
        layers.append(stacked)
    return {"embed": init_embed(k_embed, cfg),
            "layers": tuple(layers),
            "final_norm": init_norm(cfg)}


# ------------------------------------------------------------ train path
def apply_block_train(cfg: ModelConfig, bspec: BlockSpec, p: Params,
                      x: jax.Array, aux: jax.Array
                      ) -> Tuple[jax.Array, jax.Array]:
    h = apply_norm(cfg, p["norm_mixer"], x)
    kind = bspec.mixer
    if kind in ("attn", "attn_local"):
        h = attn.attention_train(cfg, p["mixer"], h,
                                 local=(kind == "attn_local"))
    elif kind == "mamba":
        h = ssm.mamba_train(cfg, p["mixer"], h)
    elif kind == "mlstm":
        h = ssm.mlstm_train(cfg, p["mixer"], h)
    else:
        h = ssm.slstm_train(cfg, p["mixer"], h)
    if cfg.post_norm:
        h = apply_norm(cfg, p["post_norm_mixer"], h)
    x = x + h
    if bspec.ffn != "none":
        h = apply_norm(cfg, p["norm_ffn"], x)
        if bspec.ffn == "moe":
            h, a = apply_moe(cfg, p["ffn"], h)
            aux = aux + a
        else:
            h = apply_mlp(cfg, p["ffn"], h)
        if cfg.post_norm:
            h = apply_norm(cfg, p["post_norm_ffn"], h)
        x = x + h
    return x, aux


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array,
            embeds: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """tokens [B,S] (or `embeds` [B,S,d] from a modality frontend stub)
    -> (logits [B,S,V], moe aux loss)."""
    x = embeds if embeds is not None else \
        embed_tokens(cfg, params["embed"], tokens)
    x = constrain(x, "dp", None, None)
    aux0 = jnp.zeros((), jnp.float32)

    @jax.checkpoint
    def period_fn(carry, layer_slice):
        x, aux = carry
        for pos, bspec in enumerate(cfg.pattern):
            x, aux = apply_block_train(cfg, bspec, layer_slice[pos], x, aux)
            x = constrain(x, "dp", None, None)
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(period_fn, (x, aux0), params["layers"])
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params["embed"], x)
    return logits, aux


# ----------------------------------------------------------- decode path
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    """Per pattern position, stacked over repeats (so decode also scans)."""
    caches = []
    for bspec in cfg.pattern:
        if bspec.mixer in ("attn", "attn_local"):
            one = attn.init_kv_cache(cfg, batch, max_len)
        elif bspec.mixer == "mamba":
            one = ssm.init_mamba_state(cfg, batch)
        elif bspec.mixer == "mlstm":
            one = ssm.init_mlstm_state(cfg, batch)
        else:
            one = ssm.init_slstm_state(cfg, batch)
        caches.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.repeats,) + a.shape).copy(),
            one))
    return tuple(caches)


def apply_block_decode(cfg: ModelConfig, bspec: BlockSpec, p: Params,
                       x: jax.Array, cache: Params, pos: jax.Array
                       ) -> Tuple[jax.Array, Params]:
    h = apply_norm(cfg, p["norm_mixer"], x)
    kind = bspec.mixer
    if kind in ("attn", "attn_local"):
        h, cache = attn.attention_decode(cfg, p["mixer"], h, cache, pos,
                                         local=(kind == "attn_local"))
    elif kind == "mamba":
        h, cache = ssm.mamba_decode(cfg, p["mixer"], h, cache)
    elif kind == "mlstm":
        h, cache = ssm.mlstm_decode(cfg, p["mixer"], h, cache)
    else:
        h, cache = ssm.slstm_decode(cfg, p["mixer"], h, cache)
    if cfg.post_norm:
        h = apply_norm(cfg, p["post_norm_mixer"], h)
    x = x + h
    if bspec.ffn != "none":
        h = apply_norm(cfg, p["norm_ffn"], x)
        if bspec.ffn == "moe":
            h, _ = apply_moe(cfg, p["ffn"], h)
        else:
            h = apply_mlp(cfg, p["ffn"], h)
        if cfg.post_norm:
            h = apply_norm(cfg, p["post_norm_ffn"], h)
        x = x + h
    return x, cache


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                tokens: jax.Array, pos: jax.Array
                ) -> Tuple[jax.Array, Params]:
    """One decode step. tokens [B]; pos scalar int32 (current position).
    Returns (logits [B,V], new cache)."""
    x = embed_tokens(cfg, params["embed"], tokens[:, None])

    def step_fn(x, slices):
        layer_slice, cache_slice = slices
        new_cache = []
        for p_, bspec in enumerate(cfg.pattern):
            x, c = apply_block_decode(cfg, bspec, layer_slice[p_], x,
                                      cache_slice[p_], pos)
            new_cache.append(c)
        return x, tuple(new_cache)

    x, new_cache = jax.lax.scan(step_fn, x, (params["layers"], cache))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params["embed"], x)
    return logits[:, 0], new_cache


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array
            ) -> Tuple[jax.Array, jax.Array]:
    """Prefill = teacher-forced forward over the prompt; returns logits.
    (Cache-filling prefill exists in serve/serve_step.py; for the
    prefill_32k dry-run cell the compute-equivalent forward is lowered.)"""
    return forward(cfg, params, tokens)
