"""Model configuration shared by all 10 assigned architectures.

A config fully describes one architecture: the block pattern (periodic,
so heterogeneous stacks like Gemma-2 local/global or Jamba 1:7
attention:mamba scan cleanly with `lax.scan` over repeats), attention
flavor, MoE, SSM and frontend details.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class BlockSpec:
    """One position inside the repeating layer pattern."""
    mixer: str = "attn"      # attn | attn_local | mamba | mlstm | slstm
    ffn: str = "mlp"         # mlp | moe | none  (xLSTM blocks carry no FFN)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec | vlm
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    # layer pattern: `pattern` repeated `repeats` times = all layers
    pattern: Tuple[BlockSpec, ...] = (BlockSpec(),)
    repeats: int = 1
    head_dim: Optional[int] = None   # default: d_model // num_heads
    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 4096       # for attn_local mixers
    attn_softcap: Optional[float] = None     # gemma2: 50.0
    logits_softcap: Optional[float] = None   # gemma2: 30.0
    # MoE
    num_experts: int = 0
    experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    capacity_factor: float = 1.25
    # SSM (mamba / xlstm)
    ssm_d_state: int = 16
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500          # whisper: fixed 30 s of audio frames
    # modality frontend stub: None | "audio" | "vision"
    frontend: Optional[str] = None
    frontend_dim: int = 0            # dim of precomputed frame/patch embeds
    # misc
    post_norm: bool = False          # gemma2: extra norm after sublayers
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu"                # silu | gelu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # sub-quadratic? (drives the long_500k skip policy)
    subquadratic: bool = False

    # ------------------------------------------------------------------
    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.repeats

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    def param_count(self) -> int:
        """Approximate parameter count (for 6·N·D roofline checks)."""
        d, hd = self.d_model, self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        n = self.vocab_size * d                       # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d                  # unembed
        per = {}
        for bs in self.pattern:
            if bs.mixer in ("attn", "attn_local"):
                a = d * (nq * hd) + 2 * d * (nkv * hd) + (nq * hd) * d
                if self.qkv_bias:
                    a += (nq + 2 * nkv) * hd
            elif bs.mixer == "mamba":
                di = self.ssm_expand * d
                a = d * 2 * di + di * self.ssm_d_conv + \
                    di * (2 * self.ssm_d_state + 1) + di * d + di * self.ssm_d_state
            else:  # mlstm / slstm
                di = self.ssm_expand * d
                a = d * 4 * di + di * d
            if bs.ffn == "mlp":
                f = 3 * d * self.d_ff if self.act == "silu" else 2 * d * self.d_ff
            elif bs.ffn == "moe":
                f = self.num_experts * 3 * d * self.moe_d_ff + d * self.num_experts
                f += self.num_shared_experts * 3 * d * self.moe_d_ff
            else:
                f = 0
            per[bs] = a + f
        n += sum(per[bs] for bs in self.pattern) * self.repeats
        if self.is_encoder_decoder:
            n += self.num_layers * 4 * d * d          # decoder cross-attn
            n += self.encoder_layers * (4 * d * d + 2 * d * self.d_ff)
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed-to experts)."""
        if self.num_experts == 0:
            return self.param_count()
        full = self.param_count()
        d = self.d_model
        moe_blocks = sum(1 for b in self.pattern if b.ffn == "moe") * self.repeats
        all_routed = moe_blocks * self.num_experts * 3 * d * self.moe_d_ff
        act_routed = moe_blocks * self.experts_per_tok * 3 * d * self.moe_d_ff
        return full - all_routed + act_routed

    def scaled(self, **overrides) -> "ModelConfig":
        """A reduced copy for smoke tests."""
        return dataclasses.replace(self, **overrides)


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str      # train | prefill | decode


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)


def get_shape(name: str) -> ShapeSpec:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; have {[s.name for s in SHAPES]}")
