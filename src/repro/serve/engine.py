"""Continuous-batching serving engine with the paper's asynchronous
organization at the request layer.

Clients NEVER touch the engine's scheduling structures (the paper's "no
direct mutation" rule): `submit()` pushes a request message into the
calling client's own SPSC queue (core.queues). The engine loop plays the
DDAST manager: it drains client queues — round-robin, up to
MAX_OPS_THREAD per client, stopping early once MIN_READY (free-slot fill)
is reached — admits requests into batch slots, and every engine step
advances ALL active slots by one token with a single batched
`decode_step` (prompt tokens are teacher-forced through the decode path;
generated tokens continue it). Slots free as requests finish => true
continuous batching with per-slot positions.

With ``runtime=`` (a multi-tenant ``TaskRuntime(num_clients>=1)``) each
client queue becomes a :class:`~repro.core.scopes.JobScope` on the REAL
runtime instead of the engine's private drain loop: every drained
request is submitted as a scope task chained per client (region
``("reqchain",)`` INOUT under the scope's namespace — client FIFO for
free), the scopes' weighted-fair admission layer decides which client's
requests reach the admission buffer first, and per-client
``max_inflight`` backpressure bounds a flooding client's presence in
the shared pool. Request ids are per-engine (stamped at submit), so two
engines number their requests independently.
"""
from __future__ import annotations

import itertools
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.ddast import DDASTParams
from ..core.metrics import LogHistogram, prometheus_text
from ..core.queues import WorkerQueues
from ..core.sched import DagNode, bottom_levels, build_arrays
from ..models.registry import ModelAPI


@dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    # stamped by the owning engine at submit time (per-engine counter —
    # a module-global here would leak numbering across engines/tests)
    req_id: Optional[int] = None
    # stamped at submit: which client queue carried this request (the
    # per-tenant latency histogram's key; -1 = never submitted)
    client_id: int = -1
    output: List[int] = field(default_factory=list)
    done_event: threading.Event = field(default_factory=threading.Event)
    admitted_step: int = -1
    finished_step: int = -1


@dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                    # next cache position
    prompt_left: int = 0

    @property
    def free(self) -> bool:
        return self.req is None


class ServeEngine:
    def __init__(self, model: ModelAPI, params: Any, *, batch_slots: int = 4,
                 max_len: int = 256, num_clients: int = 4,
                 ddast: Optional[DDASTParams] = None, eos_id: int = -1,
                 runtime: Any = None,
                 client_weights: Optional[Sequence[float]] = None,
                 client_max_inflight: Optional[Sequence[Optional[int]]]
                 = None,
                 client_deadlines: Optional[Sequence[Optional[float]]]
                 = None):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.ddast = ddast or DDASTParams()
        self.client_queues = [WorkerQueues(i) for i in range(num_clients)]
        self._req_ids = itertools.count()
        # runtime-backed request layer: one JobScope per client queue
        self.runtime = runtime
        self._scopes: List[Any] = []
        self._admitq: deque = deque()   # GIL-atomic: filled by scope
        #   task bodies on worker threads, drained by the engine step
        if runtime is not None:
            ws = (list(client_weights) if client_weights is not None
                  else [1.0] * num_clients)
            caps = (list(client_max_inflight)
                    if client_max_inflight is not None
                    else [None] * num_clients)
            dls = (list(client_deadlines)
                   if client_deadlines is not None
                   else [None] * num_clients)
            if len(ws) != num_clients or len(caps) != num_clients \
                    or len(dls) != num_clients:
                raise ValueError("client_weights/client_max_inflight/"
                                 "client_deadlines must have "
                                 "num_clients entries")
            for c in range(num_clients):
                # deadline= makes the client scope an SLO tenant: the
                # scope records per-task met/missed + slack (exported
                # by metrics_snapshot), and hard-expires past the wall
                # deadline — tenant SLOs are wall-time promises here
                self._scopes.append(runtime.open_scope(
                    f"client{c}", weight=ws[c], max_inflight=caps[c],
                    deadline=dls[c]))
        self.slots = [_Slot() for _ in range(self.B)]
        self.cache = model.init_cache(self.B, max_len)
        self._tokens = np.zeros((self.B,), np.int32)
        self._pos = np.zeros((self.B,), np.int32)
        from ..train.train_step import make_serve_step
        self._step_fn = jax.jit(make_serve_step(model))
        self.steps = 0
        self.completed: List[Request] = []
        self.stats = {"admitted": 0, "drained_msgs": 0, "callback_passes": 0}
        # per-client admitted->finished latency in engine steps (the
        # serving-layer unit: one step = one batched decode); recorded
        # only on the engine-step thread, so plain histograms suffice
        self._client_latency = [LogHistogram(1.0)
                                for _ in range(num_clients)]

    # ------------------------------------------------------- client API
    def submit(self, req: Request, client_id: int = 0) -> Request:
        """Lock-free from the caller's perspective: single-producer push
        into the client's own queue (the Submit Task Message analogue)."""
        if req.req_id is None:
            req.req_id = next(self._req_ids)
        req.client_id = client_id
        self.client_queues[client_id].submit.push(req)
        return req

    # ---------------------------------------------------- manager logic
    def _free_slots(self) -> int:
        return sum(1 for s in self.slots if s.free)

    def _pump_to_scopes(self) -> None:
        """Runtime-backed request layer: move drained client-queue
        entries onto the REAL runtime as per-client scope tasks. The
        per-client ``("reqchain",) INOUT`` chain (scope-qualified by the
        keying shim, so clients never alias) keeps each client FIFO;
        WHICH client's chain advances first is the scope layer's
        weighted-fair admission, replacing the engine's private
        round-robin. Task bodies append to the GIL-atomic admission
        buffer the engine step admits from.

        The pumping thread first claims its own runtime submit slot:
        scope submissions ride per-thread SPSC queues, so a serving
        thread that differs from the engine's constructing thread must
        not share the main slot with a concurrently-submitting main
        thread (size ``num_clients`` one larger when stepping from a
        dedicated thread)."""
        self.runtime._ensure_client_slot()
        for cid, q in enumerate(self.client_queues):
            if not q.acquire_submit():
                continue
            try:
                while True:
                    req = q.submit.pop()
                    if req is None:
                        break
                    self._scopes[cid].task(
                        self._admitq.append, req,
                        deps=[(("reqchain",), "inout")],
                        label=f"req{req.req_id}")
                    self.stats["drained_msgs"] += 1
            finally:
                q.release_submit()

    def scope_admission(self) -> Dict[str, dict]:
        """Per-client fairness counters from the runtime's admission
        layer (runtime-backed engines only)."""
        return {sc.name:
                self.runtime.placement.scope_admission(sc.scope_id)
                for sc in self._scopes}

    def _admit_requests(self) -> None:
        """DDAST callback port: round-robin client queues, up to
        MAX_OPS_THREAD per queue, early-exit once MIN_READY slots filled
        (ready tasks == occupied slots waiting to run). Each drain pass
        admits its batch longest-remaining-chain first (the scheduling
        subsystem's bottom levels over the request DAG) so a long
        request starts decoding before short ones fill the slots.

        Runtime-backed engines skip the private drain discipline: the
        scope layer already ordered requests into the admission buffer;
        this just fills free slots from it."""
        if self.runtime is not None:
            self._pump_to_scopes()
            batch: List[Request] = []
            while self._free_slots() - len(batch) > 0:
                try:
                    batch.append(self._admitq.popleft())
                except IndexError:
                    break
            for req in self._admission_order(batch):
                self._admit(req)
            return
        p = self.ddast
        self.stats["callback_passes"] += 1
        spins = max(p.max_spins, 1)
        while self._free_slots() > 0 and spins > 0:
            total = 0
            batch: List[Request] = []
            for q in self.client_queues:
                if self._free_slots() - len(batch) == 0:
                    break
                cnt = 0
                if q.acquire_submit():
                    try:
                        while cnt < p.max_ops_thread and \
                                self._free_slots() - len(batch) > 0:
                            req = q.submit.pop()
                            if req is None:
                                break
                            batch.append(req)
                            cnt += 1
                    finally:
                        q.release_submit()
                total += cnt
            for req in self._admission_order(batch):
                self._admit(req)
            self.stats["drained_msgs"] += total
            spins = spins - 1 if total == 0 else spins
            if total == 0:
                break

    @staticmethod
    def _admission_order(batch: List[Request]) -> List[Request]:
        """Order one drain pass's admissions by descending bottom level
        of each request's prefill->decode chain (shared DAG core,
        core/sched — the serving analogue of the runtime's critical-path
        placement). Stable: equal chains keep their FIFO order."""
        if len(batch) < 2:
            return batch
        nodes = []
        for req in batch:
            nodes.append(DagNode(("prefill", req.req_id),
                                 cost=max(len(req.prompt), 1)))
            nodes.append(DagNode(("decode", req.req_id),
                                 cost=max(req.max_new_tokens, 1),
                                 deps=[("prefill", req.req_id)]))
        idx, succs, _ = build_arrays(nodes)
        levels = bottom_levels(succs, [n.cost for n in nodes])
        return sorted(batch, reverse=True,
                      key=lambda r: levels[idx[("prefill", r.req_id)]])

    def _admit(self, req: Request) -> None:
        for i, slot in enumerate(self.slots):
            if slot.free:
                slot.req = req
                slot.pos = 0
                slot.prompt_left = len(req.prompt)
                req.admitted_step = self.steps
                self._tokens[i] = req.prompt[0]
                self._pos[i] = 0
                self._reset_slot_cache(i)
                self.stats["admitted"] += 1
                return
        raise RuntimeError("no free slot")

    def _reset_slot_cache(self, i: int) -> None:
        """Zero slot i's cache lanes (batch index i across the pytree)."""
        def zero(c):
            if c.ndim >= 2 and c.shape[1] == self.B:
                return c.at[:, i].set(0)
            return c
        self.cache = jax.tree.map(zero, self.cache)

    # ----------------------------------------------------------- stepping
    def step(self) -> int:
        """One engine iteration: drain client queues (manager), then one
        batched decode step. Returns number of active slots advanced."""
        self._admit_requests()
        active = [i for i, s in enumerate(self.slots) if not s.free]
        if not active:
            return 0
        next_tok, _, self.cache = self._step_fn(
            self.params, self.cache, jnp.asarray(self._tokens),
            jnp.asarray(self._pos))
        next_tok = np.asarray(next_tok)
        self.steps += 1
        for i in active:
            slot = self.slots[i]
            req = slot.req
            slot.pos += 1
            slot.prompt_left -= 1
            if slot.prompt_left > 0:
                self._tokens[i] = req.prompt[slot.pos]      # teacher-force
            else:
                tok = int(next_tok[i])
                req.output.append(tok)
                self._tokens[i] = tok
                if len(req.output) >= req.max_new_tokens or \
                        tok == self.eos_id or slot.pos + 1 >= self.max_len:
                    req.finished_step = self.steps
                    if 0 <= req.client_id < len(self._client_latency):
                        self._client_latency[req.client_id].record(
                            req.finished_step - req.admitted_step)
                    req.done_event.set()
                    self.completed.append(req)
                    slot.req = None
                    continue
            self._pos[i] = slot.pos
        return len(active)

    def _backlog(self) -> int:
        """Requests not yet in a batch slot: client queues, plus (when
        runtime-backed) in-flight scope tasks and the admission buffer."""
        n = sum(len(q.submit) for q in self.client_queues)
        n += len(self._admitq)
        for sc in self._scopes:
            n += sc.root.num_children_alive
        return n

    # ----------------------------------------------------- observability
    def metrics_snapshot(self) -> Dict[str, Any]:
        """JSON-friendly serving metrics: engine gauges plus one entry
        per client — request-latency histogram (in engine steps) and,
        for runtime-backed engines, the scope layer's admission
        counters and SLO attainment (``client_deadlines=``)."""
        clients: Dict[str, Any] = {}
        for cid in range(len(self.client_queues)):
            entry: Dict[str, Any] = {}
            hist = self._client_latency[cid]
            if hist.count:
                entry["latency_steps"] = hist.snapshot()
            if self._scopes:
                sc = self._scopes[cid]
                entry["admission"] = \
                    self.runtime.placement.scope_admission(sc.scope_id)
                slo = sc.slo_snapshot()
                if slo is not None:
                    entry["slo"] = slo
            clients[f"client{cid}"] = entry
        return {
            "time_unit": "s",
            "gauges": {"steps": self.steps,
                       "admitted": self.stats["admitted"],
                       "backlog": self._backlog(),
                       "free_slots": self._free_slots()},
            "clients": clients,
        }

    def metrics_text(self) -> str:
        return prometheus_text(self.metrics_snapshot())

    def serve_metrics(self, port: int = 0):
        """Start a Prometheus scrape endpoint (text format 0.0.4) on
        localhost in a daemon thread; ``port=0`` picks a free port.
        Returns ``(server, port)`` — call ``server.shutdown()`` when
        done. Every GET /metrics renders a fresh snapshot, so scrapes
        observe the run in flight."""
        import http.server
        engine = self

        class _Handler(http.server.BaseHTTPRequestHandler):
            def do_GET(self):          # noqa: N802 (stdlib API name)
                if self.path.split("?")[0] not in ("/metrics", "/"):
                    self.send_error(404)
                    return
                body = engine.metrics_text().encode("utf-8")
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):
                pass                   # scrapes must not spam stderr

        srv = http.server.ThreadingHTTPServer(("127.0.0.1", port),
                                              _Handler)
        threading.Thread(target=srv.serve_forever,
                         name="metrics-scrape", daemon=True).start()
        return srv, srv.server_address[1]

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        idle = 0
        for _ in range(max_steps):
            n = self.step()
            if n == 0:
                if self._backlog() == 0:
                    idle += 1
                    if idle > 2:
                        return
            else:
                idle = 0
