"""Continuous-batching serving engine with the paper's asynchronous
organization at the request layer.

Clients NEVER touch the engine's scheduling structures (the paper's "no
direct mutation" rule): `submit()` pushes a request message into the
calling client's own SPSC queue (core.queues). The engine loop plays the
DDAST manager: it drains client queues — round-robin, up to
MAX_OPS_THREAD per client, stopping early once MIN_READY (free-slot fill)
is reached — admits requests into batch slots, and every engine step
advances ALL active slots by one token with a single batched
`decode_step` (prompt tokens are teacher-forced through the decode path;
generated tokens continue it). Slots free as requests finish => true
continuous batching with per-slot positions.
"""
from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.ddast import DDASTParams
from ..core.queues import WorkerQueues
from ..core.sched import DagNode, bottom_levels, build_arrays
from ..models.registry import ModelAPI

_req_ids = itertools.count()


@dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    req_id: int = field(default_factory=lambda: next(_req_ids))
    output: List[int] = field(default_factory=list)
    done_event: threading.Event = field(default_factory=threading.Event)
    admitted_step: int = -1
    finished_step: int = -1


@dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                    # next cache position
    prompt_left: int = 0

    @property
    def free(self) -> bool:
        return self.req is None


class ServeEngine:
    def __init__(self, model: ModelAPI, params: Any, *, batch_slots: int = 4,
                 max_len: int = 256, num_clients: int = 4,
                 ddast: Optional[DDASTParams] = None, eos_id: int = -1):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.ddast = ddast or DDASTParams()
        self.client_queues = [WorkerQueues(i) for i in range(num_clients)]
        self.slots = [_Slot() for _ in range(self.B)]
        self.cache = model.init_cache(self.B, max_len)
        self._tokens = np.zeros((self.B,), np.int32)
        self._pos = np.zeros((self.B,), np.int32)
        from ..train.train_step import make_serve_step
        self._step_fn = jax.jit(make_serve_step(model))
        self.steps = 0
        self.completed: List[Request] = []
        self.stats = {"admitted": 0, "drained_msgs": 0, "callback_passes": 0}

    # ------------------------------------------------------- client API
    def submit(self, req: Request, client_id: int = 0) -> Request:
        """Lock-free from the caller's perspective: single-producer push
        into the client's own queue (the Submit Task Message analogue)."""
        self.client_queues[client_id].submit.push(req)
        return req

    # ---------------------------------------------------- manager logic
    def _free_slots(self) -> int:
        return sum(1 for s in self.slots if s.free)

    def _admit_requests(self) -> None:
        """DDAST callback port: round-robin client queues, up to
        MAX_OPS_THREAD per queue, early-exit once MIN_READY slots filled
        (ready tasks == occupied slots waiting to run). Each drain pass
        admits its batch longest-remaining-chain first (the scheduling
        subsystem's bottom levels over the request DAG) so a long
        request starts decoding before short ones fill the slots."""
        p = self.ddast
        self.stats["callback_passes"] += 1
        spins = max(p.max_spins, 1)
        while self._free_slots() > 0 and spins > 0:
            total = 0
            batch: List[Request] = []
            for q in self.client_queues:
                if self._free_slots() - len(batch) == 0:
                    break
                cnt = 0
                if q.acquire_submit():
                    try:
                        while cnt < p.max_ops_thread and \
                                self._free_slots() - len(batch) > 0:
                            req = q.submit.pop()
                            if req is None:
                                break
                            batch.append(req)
                            cnt += 1
                    finally:
                        q.release_submit()
                total += cnt
            for req in self._admission_order(batch):
                self._admit(req)
            self.stats["drained_msgs"] += total
            spins = spins - 1 if total == 0 else spins
            if total == 0:
                break

    @staticmethod
    def _admission_order(batch: List[Request]) -> List[Request]:
        """Order one drain pass's admissions by descending bottom level
        of each request's prefill->decode chain (shared DAG core,
        core/sched — the serving analogue of the runtime's critical-path
        placement). Stable: equal chains keep their FIFO order."""
        if len(batch) < 2:
            return batch
        nodes = []
        for req in batch:
            nodes.append(DagNode(("prefill", req.req_id),
                                 cost=max(len(req.prompt), 1)))
            nodes.append(DagNode(("decode", req.req_id),
                                 cost=max(req.max_new_tokens, 1),
                                 deps=[("prefill", req.req_id)]))
        idx, succs, _ = build_arrays(nodes)
        levels = bottom_levels(succs, [n.cost for n in nodes])
        return sorted(batch, reverse=True,
                      key=lambda r: levels[idx[("prefill", r.req_id)]])

    def _admit(self, req: Request) -> None:
        for i, slot in enumerate(self.slots):
            if slot.free:
                slot.req = req
                slot.pos = 0
                slot.prompt_left = len(req.prompt)
                req.admitted_step = self.steps
                self._tokens[i] = req.prompt[0]
                self._pos[i] = 0
                self._reset_slot_cache(i)
                self.stats["admitted"] += 1
                return
        raise RuntimeError("no free slot")

    def _reset_slot_cache(self, i: int) -> None:
        """Zero slot i's cache lanes (batch index i across the pytree)."""
        def zero(c):
            if c.ndim >= 2 and c.shape[1] == self.B:
                return c.at[:, i].set(0)
            return c
        self.cache = jax.tree.map(zero, self.cache)

    # ----------------------------------------------------------- stepping
    def step(self) -> int:
        """One engine iteration: drain client queues (manager), then one
        batched decode step. Returns number of active slots advanced."""
        self._admit_requests()
        active = [i for i, s in enumerate(self.slots) if not s.free]
        if not active:
            return 0
        next_tok, _, self.cache = self._step_fn(
            self.params, self.cache, jnp.asarray(self._tokens),
            jnp.asarray(self._pos))
        next_tok = np.asarray(next_tok)
        self.steps += 1
        for i in active:
            slot = self.slots[i]
            req = slot.req
            slot.pos += 1
            slot.prompt_left -= 1
            if slot.prompt_left > 0:
                self._tokens[i] = req.prompt[slot.pos]      # teacher-force
            else:
                tok = int(next_tok[i])
                req.output.append(tok)
                self._tokens[i] = tok
                if len(req.output) >= req.max_new_tokens or \
                        tok == self.eos_id or slot.pos + 1 >= self.max_len:
                    req.finished_step = self.steps
                    req.done_event.set()
                    self.completed.append(req)
                    slot.req = None
                    continue
            self._pos[i] = slot.pos
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        idle = 0
        for _ in range(max_steps):
            n = self.step()
            if n == 0:
                if all(len(q.submit) == 0 for q in self.client_queues):
                    idle += 1
                    if idle > 2:
                        return
            else:
                idle = 0
