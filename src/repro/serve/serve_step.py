"""Batched serving steps: cache-filling prefill (decode scan over the
prompt) + sampling decode. These are the jit'd device functions the
engine and the decode dry-run cells lower."""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from ..models.registry import ModelAPI


def prefill_into_cache(model: ModelAPI, params: Any, cache: Any,
                       prompt: jax.Array) -> Tuple[jax.Array, Any]:
    """Teacher-force the prompt through the decode path to fill the cache.
    prompt [B, P] -> (logits of last position [B, V], cache)."""
    p_len = prompt.shape[1]

    def body(carry, t):
        cache, _ = carry
        logits, cache = model.decode_step(params, cache, prompt[:, t], t)
        return (cache, logits.astype(jnp.float32)), None

    (cache, logits), _ = jax.lax.scan(
        body, (cache, jnp.zeros((prompt.shape[0],
                                 _vocab(model, params)), jnp.float32)),
        jnp.arange(p_len))
    return logits, cache


def _vocab(model: ModelAPI, params: Any) -> int:
    emb = params["embed"]["embedding"]
    return emb.shape[0]


def greedy_decode(model: ModelAPI, params: Any, prompt: jax.Array,
                  max_new: int, max_len: int) -> jax.Array:
    """prompt [B,P] -> generated tokens [B,max_new] (greedy)."""
    b, p_len = prompt.shape
    cache = model.init_cache(b, max_len)
    logits, cache = prefill_into_cache(model, params, cache, prompt)
    tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def body(carry, t):
        cache, tok = carry
        logits, cache = model.decode_step(params, cache, tok, p_len + t)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (cache, nxt), tok

    (_, _), toks = jax.lax.scan(body, (cache, tok0), jnp.arange(max_new))
    return jnp.moveaxis(toks, 0, 1)
