import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ must precede every other import (see dryrun.py)

# §Perf hillclimb driver: lower+compile named variants of the three chosen
# cells and record roofline terms to experiments/perf/<tag>.json.
#
#   PYTHONPATH=src python -m repro.launch.perf --iter moe_local_dispatch

import argparse
import json
import time

from repro.launch.dryrun import lower_cell

# iteration registry: tag -> (arch, shape, lower_cell kwargs)
ITERATIONS = {
    # --- cell 1: qwen3-moe train_4k (paper-representative) -------------
    "moe_baseline": ("qwen3-moe-235b-a22b", "train_4k", {}),
    "moe_local_dispatch": ("qwen3-moe-235b-a22b", "train_4k", {}),
    "moe_weight_gather": ("qwen3-moe-235b-a22b", "train_4k",
                          {"moe": "gather"}),
    "moe_grad_compress": ("qwen3-moe-235b-a22b", "train_4k",
                          {"grad_compress": True, "microbatches": 4}),
    "moe_microbatch4": ("qwen3-moe-235b-a22b", "train_4k",
                        {"microbatches": 4}),
    # --- cell 2: qwen2-72b decode_32k (most collective-bound) ----------
    "decode_baseline": ("qwen2-72b", "decode_32k", {}),
    "decode_no_fsdp": ("qwen2-72b", "decode_32k", {"fsdp": False}),
    # --- cell 3: qwen2-0.5b train_4k (worst compute fraction) ----------
    "small_baseline": ("qwen2-0.5b", "train_4k", {}),
    "small_pure_dp": ("qwen2-0.5b", "train_4k", {"tp": False}),
    "small_pure_dp_nofsdp": ("qwen2-0.5b", "train_4k",
                             {"tp": False, "fsdp": False}),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iter", required=True,
                    help="comma-separated iteration tags, or 'all'")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()
    tags = list(ITERATIONS) if args.iter == "all" else args.iter.split(",")
    os.makedirs(args.out, exist_ok=True)
    for tag in tags:
        arch, shape, kw = ITERATIONS[tag]
        t0 = time.time()
        try:
            rec = lower_cell(arch, shape, multi_pod=False, **kw)
            rec["iteration"] = tag
            rec["kwargs"] = {k: str(v) for k, v in kw.items()}
        except Exception as e:  # noqa: BLE001
            rec = {"iteration": tag, "error": f"{type(e).__name__}: {e}"}
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
        t = rec.get("terms_s", {})
        print(f"[{tag}] {rec.get('error') or ''} "
              f"comp={t.get('compute', 0):.3g}s mem={t.get('memory', 0):.3g}s "
              f"coll={t.get('collective', 0):.3g}s "
              f"({time.time()-t0:.0f}s)", flush=True)


if __name__ == "__main__":
    main()
