"""End-to-end trainer. The host side runs the paper's runtime: a
TaskRuntime in ddast mode whose idle workers execute the registered
callbacks — DDAST message handling, data prefetch and async checkpoint
flushing — so the main thread only dispatches device steps.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --tiny \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, tiny_config
from repro.core import TaskRuntime
from repro.models.registry import get_model
from repro.train.checkpoint import CheckpointManager
from repro.train.data import DataConfig, Prefetcher, SyntheticLM
from repro.train.fault import HeartbeatMonitor
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import TrainConfig, make_train_step


def train(arch: str, tiny: bool, steps: int, batch: int, seq: int,
          ckpt_dir: str, microbatches: int = 1, resume: bool = True,
          log_every: int = 10, schedule_steps: int = 0) -> dict:
    cfg = tiny_config(arch) if tiny else get_config(arch)
    model = get_model(cfg)
    tcfg = TrainConfig(opt=OptConfig(peak_lr=1e-3, warmup_steps=20,
                                     total_steps=schedule_steps or steps),
                       num_microbatches=microbatches)
    step_fn = jax.jit(make_train_step(model, tcfg))

    params = model.init_params(jax.random.key(0))
    opt = init_opt_state(params)

    # host runtime: idle threads do prefetch + checkpoint I/O (DDAST org)
    rt = TaskRuntime(num_workers=2, mode="ddast")
    ds = SyntheticLM(cfg, DataConfig(batch=batch, seq_len=seq))
    prefetch = Prefetcher(ds, rt.dispatcher, depth=4)
    ckpt = CheckpointManager(ckpt_dir, rt.dispatcher)
    hb = HeartbeatMonitor(hosts=[f"host{i}" for i in range(1)])

    start_step = 0
    if resume:
        restored = ckpt.restore({"params": params, "opt": opt})
        if restored is not None:
            start_step, tree = restored
            params, opt = tree["params"], tree["opt"]
            print(f"[train] resumed from step {start_step}")

    losses = []
    rt.start()
    try:
        t0 = time.time()
        for step in range(start_step, steps):
            batch_np = prefetch.get(step)
            batch_dev = {k: jnp.asarray(v) for k, v in batch_np.items()}
            if cfg.is_encoder_decoder:
                batch_dev["frames"] = jnp.zeros(
                    (batch, cfg.encoder_seq, cfg.d_model), cfg.jnp_dtype)
            st = time.time()
            params, opt, metrics = step_fn(params, opt, batch_dev)
            loss = float(metrics["loss"])
            losses.append(loss)
            hb.beat("host0", step, time.time() - st)
            if step % log_every == 0 or step == steps - 1:
                print(f"[train] step {step} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f}")
            if step and step % 20 == 0:
                ckpt.save(step, {"params": params, "opt": opt})
        ckpt.save(steps, {"params": params, "opt": opt}, blocking=True)
        wall = time.time() - t0
    finally:
        ckpt.flush()
        rt._stop.set()
        for t in rt._threads:
            t.join(timeout=2)
    return {"losses": losses, "wall_s": wall,
            "prefetch_async": prefetch.fills_async,
            "ckpt_writes": ckpt.async_writes,
            "final_loss": losses[-1] if losses else None}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen2-0.5b")
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--full", dest="tiny", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()
    out = train(args.arch, args.tiny, args.steps, args.batch, args.seq,
                args.ckpt_dir, args.microbatches)
    print(f"[train] done: final loss {out['final_loss']:.4f} "
          f"({out['wall_s']:.1f}s, {out['prefetch_async']} async prefetches, "
          f"{out['ckpt_writes']} ckpt writes)")


if __name__ == "__main__":
    main()
