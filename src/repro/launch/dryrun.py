import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede every other import: jax locks the device count on first
# backend init. 512 host devices exist ONLY in this process — smoke tests
# and benches see the real single device.

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
# record memory/cost/collective analysis for the roofline.
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
#       --shape train_4k --mesh multipod
#   PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun

import argparse
import json
import re
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_production_mesh
from repro.models.config import SHAPES, ShapeSpec, get_shape
from repro.models.registry import get_model, input_specs, param_specs
from repro.parallel.sharding import (batch_specs, make_rules,
                                     shard_cache_tree, shard_tree)
from repro.train.optimizer import init_opt_state
from repro.train.train_step import (TrainConfig, make_prefill_step,
                                    make_serve_step, make_train_step)

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^=]*?=\s*(\([^)]*\)|\S+?)\s", re.S)


def should_skip(arch: str, shape: ShapeSpec) -> Optional[str]:
    cfg = get_config(arch)
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("full-attention arch: long_500k needs sub-quadratic "
                "attention (DESIGN.md skip policy)")
    return None


# --------------------------------------------------------------- analysis
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}


def _shape_bytes(stext: str) -> int:
    """bytes of an HLO shape string like 'bf16[4,128]{1,0}' or a tuple."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", stext):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective op in the (post-SPMD) HLO."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(
            r".*=\s*((?:\([^)]*\))|(?:\S+))\s+"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)", line)
        if not m:
            continue
        kind = m.group(2)
        out[kind] = out.get(kind, 0) + _shape_bytes(m.group(1))
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               compile_: bool = True, fsdp: bool = True,
               tp: bool = True, microbatches: int = 1,
               grad_compress: bool = False,
               moe: str = "ep") -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    skip = should_skip(arch, shape)
    if skip:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multipod" if multi_pod else "pod", "skip": skip}
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh, fsdp=fsdp, tp=tp)
    model = get_model(cfg)
    pspecs = param_specs(cfg)
    pshard = shard_tree(pspecs, rules)
    specs = input_specs(cfg, shape)
    t0 = time.time()
    from repro.parallel.collectives import strategy
    # also enter the abstract mesh so it is visible at trace time —
    # parallel/collectives.constrain resolves axis names through it
    with mesh, jax.sharding.use_abstract_mesh(mesh.abstract_mesh), \
            strategy(tp=tp, moe=moe):
        if shape.kind == "train":
            ospecs = jax.eval_shape(init_opt_state, pspecs)
            oshard = shard_tree(ospecs, rules)
            bshard = batch_specs(specs, rules)
            step = make_train_step(model, TrainConfig(
                num_microbatches=microbatches, grad_compress=grad_compress))
            lowered = jax.jit(
                step,
                in_shardings=(pshard, oshard, bshard),
                out_shardings=(pshard, oshard, None),
            ).lower(pspecs, ospecs, specs)
        elif shape.kind == "prefill":
            bshard = batch_specs(specs, rules)
            step = make_prefill_step(model)
            lowered = jax.jit(
                step, in_shardings=(pshard, bshard), out_shardings=None,
            ).lower(pspecs, specs)
        else:  # decode
            cshard = shard_cache_tree(specs["cache"], rules)
            tshard = batch_specs(
                {"tokens": specs["tokens"], "pos": specs["pos"]}, rules)
            step = make_serve_step(model)
            lowered = jax.jit(
                step,
                in_shardings=(pshard, cshard, tshard["tokens"],
                              tshard["pos"]),
                out_shardings=(None, None, cshard),
            ).lower(pspecs, specs["cache"], specs["tokens"], specs["pos"])
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "multipod" if multi_pod else "pod",
        "kind": shape.kind,
        "devices": int(mesh.devices.size),
        "lower_s": round(time.time() - t0, 1),
    }
    if not compile_:
        return rec
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 1)
    mem = compiled.memory_analysis()
    for key in ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes"):
        try:
            rec[key] = int(getattr(mem, key))
        except Exception:
            pass
    cost = compiled.cost_analysis() or {}
    rec["xla_flops_raw"] = float(cost.get("flops", 0.0))
    rec["xla_bytes_raw"] = float(cost.get("bytes accessed", 0.0))
    from repro.analysis.roofline import analyze_hlo, model_flops
    terms = analyze_hlo(compiled.as_text(), int(mesh.devices.size))
    rec["flops"] = terms.flops
    rec["hbm_bytes"] = terms.hbm_bytes
    rec["collectives"] = terms.coll_bytes
    rec["terms_s"] = terms.seconds()
    rec["dominant"] = terms.dominant()
    rec["model_flops"] = model_flops(cfg, shape)
    rec["useful_ratio"] = (rec["model_flops"] / terms.flops
                           if terms.flops else 0.0)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=[s.name for s in SHAPES], default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-compile", action="store_true")
    args = ap.parse_args()

    archs = sorted(ARCHS) if args.all or not args.arch else [args.arch]
    shapes = [s.name for s in SHAPES] if args.all or not args.shape \
        else [args.shape]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'multipod' if mp else 'pod'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[skip-cached] {tag}")
                    continue
                try:
                    rec = lower_cell(arch, shape, mp,
                                     compile_=not args.no_compile)
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multipod" if mp else "pod",
                           "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec.get("error") or rec.get("skip") or \
                    (f"ok compile={rec.get('compile_s')}s "
                     f"flops={rec.get('flops', 0):.3g}")
                print(f"[{tag}] {status}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")
    print("dry-run complete")


if __name__ == "__main__":
    main()
