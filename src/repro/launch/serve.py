"""Serving launcher: spins up the continuous-batching engine on a tiny
config and runs a synthetic request workload from several client threads.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
      --requests 16 --clients 4
"""
from __future__ import annotations

import argparse
import threading
import time

import jax
import numpy as np

from repro.configs import ARCHS, tiny_config
from repro.models.registry import get_model
from repro.serve.engine import Request, ServeEngine


def serve(arch: str, num_requests: int, clients: int, slots: int = 4,
          max_new: int = 8) -> dict:
    cfg = tiny_config(arch)
    if cfg.is_encoder_decoder:
        raise SystemExit("serve launcher targets decoder-only archs")
    model = get_model(cfg)
    params = model.init_params(jax.random.key(0))
    eng = ServeEngine(model, params, batch_slots=slots, max_len=64,
                      num_clients=clients)
    rng = np.random.RandomState(0)
    reqs = [Request(prompt=rng.randint(1, 100, rng.randint(2, 10)).tolist(),
                    max_new_tokens=max_new) for _ in range(num_requests)]

    def client(cid: int) -> None:
        for i, r in enumerate(reqs):
            if i % clients == cid:
                eng.submit(r, client_id=cid)
                time.sleep(0.001)

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(clients)]
    t0 = time.time()
    for t in threads:
        t.start()
    # engine thread = the DDAST manager draining client queues
    while len(eng.completed) < num_requests:
        eng.step()
        if time.time() - t0 > 120:
            raise RuntimeError("serve timeout")
    for t in threads:
        t.join()
    wall = time.time() - t0
    toks = sum(len(r.output) for r in eng.completed)
    return {"wall_s": wall, "requests": len(eng.completed),
            "tokens": toks, "engine_steps": eng.steps,
            "tok_per_s": toks / wall, "stats": eng.stats}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()
    out = serve(args.arch, args.requests, args.clients, args.slots)
    print(f"[serve] {out['requests']} requests, {out['tokens']} tokens in "
          f"{out['wall_s']:.1f}s ({out['tok_per_s']:.1f} tok/s, "
          f"{out['engine_steps']} engine steps)")
    print(f"[serve] scheduler stats: {out['stats']}")


if __name__ == "__main__":
    main()
