"""Activation sharding constraints — the canonical GSPMD steering every
production framework inserts.

Without constraints, GSPMD is free to contract an FSDP-sharded weight by
psumming ACTIVATION-sized partials (dry-run analysis measured 8 GB/device/
layer on qwen3-moe) instead of all-gathering the much smaller weight
shard. `constrain(x, "dp", None, "model")` pins activations to the
canonical layout (batch on the data axes, features on model), which makes
ZeRO-3 lower to weight all-gathers + local matmuls, and keeps dispatch
bookkeeping (one-hot cumsums, sorts) device-local.

All helpers no-op when no mesh is in scope (single-device tests) and skip
any dim whose size doesn't divide the axis — so the same model code runs
everywhere (this is what keeps all 40 dry-run cells lowering).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

DimSpec = Union[None, str]   # None | "dp" | "model" | axis name

# strategy knobs: when TP is disabled (pure-DP small-model mode) the
# "model" logical dim must resolve to None or constraints would force
# pointless resharding of replicated params' activations. moe_mode picks
# the MoE dataflow: "ep" = tokens all-to-all to expert shards;
# "gather" = weights gathered to the tokens (optimal when per-layer
# expert weights < k x tokens x d — napkin math in EXPERIMENTS.md §Perf).
_tp_enabled: contextvars.ContextVar = contextvars.ContextVar(
    "repro_tp_enabled", default=True)
_moe_mode: contextvars.ContextVar = contextvars.ContextVar(
    "repro_moe_mode", default="ep")


def moe_mode() -> str:
    return _moe_mode.get()


@contextlib.contextmanager
def strategy(tp: bool = True, moe: str = "ep"):
    tok = _tp_enabled.set(tp)
    tok2 = _moe_mode.set(moe)
    try:
        yield
    finally:
        _tp_enabled.reset(tok)
        _moe_mode.reset(tok2)


def _mesh_axes() -> dict:
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:  # noqa: BLE001
        return {}
    if mesh is None or not mesh.shape:
        return {}
    return dict(mesh.shape)


def constrain(x: jax.Array, *dims: DimSpec) -> jax.Array:
    """with_sharding_constraint with logical dim names + divisibility
    fallback. dims: one entry per axis of x — None, "dp" (pod+data) or
    "model"."""
    axes = _mesh_axes()
    if not axes or len(dims) != x.ndim:
        return x
    spec = []
    for i, d in enumerate(dims):
        if d is None:
            spec.append(None)
            continue
        if d == "dp":
            names = tuple(a for a in ("pod", "data") if a in axes)
            if not _tp_enabled.get() and "model" in axes:
                names = names + ("model",)     # model axis joins DP
        elif d == "model" and not _tp_enabled.get():
            names = ()
        else:
            names = (d,) if d in axes else ()
        size = int(np.prod([axes[a] for a in names])) if names else 0
        if names and size > 0 and x.shape[i] % size == 0 \
                and x.shape[i] >= size:
            spec.append(names if len(names) > 1 else names[0])
        else:
            spec.append(None)
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:  # no mesh context at trace time  # noqa: BLE001
        return x
