"""Sharding rules engine.

GSPMD needs valid NamedShardings only for jit inputs/outputs (params,
optimizer state, batch, caches); intermediates are the compiler's job.
This engine assigns shardings per leaf from its tree path + shape with
divisibility fallback, which is what lets EVERY pool architecture lower on
ANY mesh (14-head attention, 60-expert MoE, batch-1 long-context, ...):

  * TP/EP  — the "model" axis goes to the preferred parallel dim of each
    leaf (experts for MoE weights, heads/ffn for projections, vocab for
    embeddings) if divisible, else to the largest divisible dim, else the
    leaf stays unsharded on that axis.
  * FSDP   — the "data" axis additionally shards the largest remaining
    divisible dim of big leaves (ZeRO-3: params + optimizer state).
    Kept intra-pod so FSDP all-gathers never cross the pod axis; the pod
    axis carries pure DP (gradient all-reduce only).
  * batch  — ("pod","data") on the batch dim when divisible; batch-1
    long-context falls back to sequence sharding (SP) on "data".
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# leaf-name -> preferred dim index for the model axis, counted from the
# END of the shape (negative) so stacked [repeats, ...] leaves need no
# special casing. None entries mean "replicate on model".
_MODEL_PREF: Dict[str, int] = {
    # attention / generic projections: shard the output features
    "wq": -1, "wk": -1, "wv": -1, "w_gate": -1, "w_up": -1, "w_x": -1,
    "in_proj": -1, "x_proj": -1, "w_i": -1, "w_f": -1, "router": -1,
    # row-parallel: shard the input features
    "wo": -2, "w_down": -2, "out_proj": -2, "dt_proj": -2,
    # embeddings: vocab dim
    "embedding": -2, "unembed": -1,
    # mamba extras
    "conv_w": -1, "conv_b": -1, "dt_bias": -1, "a_log": -2, "d": -1,
    # slstm recurrent block-diagonal [4,H,hd,hd]: heads
    "w_r": -3,
}

# MoE expert-stacked weights [E, d, f] (possibly [R, E, d, f]): expert dim
_EXPERT_LEAVES = ("w_gate", "w_up", "w_down")


@dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    model_axis: str = "model"
    fsdp_axis: str = "data"
    dp_axes: Tuple[str, ...] = ("data",)      # ("pod","data") multi-pod
    fsdp_min_size: int = 2 ** 16              # don't FSDP tiny leaves
    # strategy knobs (the §Perf hillclimb levers):
    fsdp: bool = True      # False: params replicated on data (inference /
    #                        small-model: kills per-step weight gathers)
    tp: bool = True        # False: model axis joins the batch axes (pure
    #                        DP for small models — no TP resharding thrash)

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis]

    @property
    def fsdp_size(self) -> int:
        return self.mesh.shape[self.fsdp_axis]

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        return self.dp_axes + ((self.model_axis,) if not self.tp else ())


def make_rules(mesh: Mesh, *, fsdp: bool = True, tp: bool = True
               ) -> ShardingRules:
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return ShardingRules(mesh=mesh, dp_axes=dp, fsdp=fsdp, tp=tp)


def _stack_depth(path: str) -> int:
    """Leading stacked-layer dims to skip (never shard the scan axis)."""
    return 1 if re.search(r"\b(layers|encoder|decoder)\b", path) else 0


def _leaf_name(path: str) -> str:
    return path.rstrip("]'\"").split("/")[-1].split("[")[-1].strip("'\" ")


def param_sharding(path: str, shape: Sequence[int],
                   rules: ShardingRules) -> NamedSharding:
    rank = len(shape)
    spec: list = [None] * rank
    lo = _stack_depth(path)                   # protected leading dims
    name = _leaf_name(path)
    msz, fsz = rules.model_size, rules.fsdp_size

    def assignable(i: int, size: int) -> bool:
        return i >= lo and spec[i] is None and shape[i] % size == 0 \
            and shape[i] >= size

    # ---- model axis ----------------------------------------------------
    midx: Optional[int] = None
    if not rules.tp:
        # pure-DP strategy: no tensor parallelism; FSDP may still apply
        if rules.fsdp and int(np.prod(shape)) >= rules.fsdp_min_size:
            order = sorted(range(lo, rank), key=lambda i: -shape[i])
            for i in order:
                if assignable(i, fsz):
                    spec[i] = rules.fsdp_axis
                    break
        return NamedSharding(rules.mesh, P(*spec))
    is_expert = name in _EXPERT_LEAVES and rank - lo == 3
    if is_expert:
        cand = lo                              # expert dim -> EP
        if assignable(cand, msz):
            midx = cand
    if midx is None and name in _MODEL_PREF:
        cand = rank + _MODEL_PREF[name]
        if lo <= cand < rank and assignable(cand, msz):
            midx = cand
    if midx is None:                           # fallback: largest divisible
        order = sorted(range(lo, rank), key=lambda i: -shape[i])
        for i in order:
            if assignable(i, msz):
                midx = i
                break
    if midx is not None:
        spec[midx] = rules.model_axis

    # ---- FSDP on the data axis ------------------------------------------
    if rules.fsdp and int(np.prod(shape)) >= rules.fsdp_min_size:
        order = sorted(range(lo, rank), key=lambda i: -shape[i])
        for i in order:
            if i != midx and assignable(i, fsz):
                spec[i] = rules.fsdp_axis
                break

    return NamedSharding(rules.mesh, P(*spec))


def shard_tree(tree_specs: Any, rules: ShardingRules) -> Any:
    """Map a pytree of ShapeDtypeStructs to a pytree of NamedShardings."""
    paths = jax.tree_util.tree_flatten_with_path(tree_specs)[0]

    def one(kp, leaf):
        path = jax.tree_util.keystr(kp)
        return param_sharding(path, leaf.shape, rules)

    return jax.tree_util.tree_map_with_path(one, tree_specs)


# ------------------------------------------------------------------ batch
def batch_specs(batch_tree: Any, rules: ShardingRules) -> Any:
    """Shardings for train/prefill inputs: batch over dp axes; SP fallback
    on the sequence dim when the batch doesn't divide (long-context)."""
    dp = rules.batch_axes
    dp_size = int(np.prod([rules.mesh.shape[a] for a in dp]))

    def one(kp, leaf):
        shape = leaf.shape
        spec: list = [None] * len(shape)
        if len(shape) >= 1 and shape[0] % dp_size == 0 and shape[0] > 1:
            spec[0] = dp
        elif len(shape) >= 2 and shape[1] % rules.fsdp_size == 0:
            spec[1] = rules.fsdp_axis          # sequence parallelism
        return NamedSharding(rules.mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, batch_tree)


def cache_sharding(path: str, shape: Sequence[int],
                   rules: ShardingRules) -> NamedSharding:
    """KV caches [R,B,L,nkv,hd] and recurrent states [R,B,...]: batch over
    dp axes when divisible (else SP on the cache length), then kv-heads /
    head_dim / feature dims on "model" when divisible."""
    rank = len(shape)
    spec: list = [None] * rank
    # decode caches are always stacked [repeats/layers, batch, ...]:
    # dim0 is the scan axis — never shard it.
    lo = 1 if rank >= 3 else 0
    _ = path
    dp = rules.batch_axes
    dp_size = int(np.prod([rules.mesh.shape[a] for a in dp]))
    msz = rules.model_size
    b_idx = lo if rank > lo else None
    if b_idx is not None and shape[b_idx] % dp_size == 0 and shape[b_idx] > 1:
        spec[b_idx] = dp
        sp_used = False
    else:
        sp_used = True
    if rules.tp:
        # KV caches [R,B,L,nkv,hd]: put the model axis on the cache LENGTH
        # (context-parallel decode). Sharding heads/hd misaligns with GQA
        # head counts (< axis size) and SPMD then all-gathers the whole
        # cache every step (dry-run measured); L-sharding turns the
        # per-step attention into tiny psums instead.
        cand_order = ([2] + list(range(rank - 1, lo, -1))) if rank >= 5 \
            else list(range(rank - 1, lo, -1))
        for i in cand_order:
            if spec[i] is None and shape[i] % msz == 0 and shape[i] >= msz:
                spec[i] = rules.model_axis
                break
    if sp_used:
        # SP: shard the longest remaining dim (the cache length) on data
        order = sorted((i for i in range(lo, rank) if spec[i] is None),
                       key=lambda i: -shape[i])
        for i in order:
            if shape[i] % rules.fsdp_size == 0 and shape[i] >= 4 * rules.fsdp_size:
                spec[i] = rules.fsdp_axis
                break
    return NamedSharding(rules.mesh, P(*spec))


def shard_cache_tree(cache_specs_tree: Any, rules: ShardingRules) -> Any:
    def one(kp, leaf):
        path = jax.tree_util.keystr(kp)
        return cache_sharding(path, leaf.shape, rules)

    return jax.tree_util.tree_map_with_path(one, cache_specs_tree)
