from .sharding import (batch_specs, cache_sharding, param_sharding,
                       shard_tree, ShardingRules)

__all__ = ["batch_specs", "cache_sharding", "param_sharding", "shard_tree",
           "ShardingRules"]
