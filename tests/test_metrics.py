"""Live metrics plane (core.metrics): histogram bucket/merge/quantile
properties, per-slot instruments and the metrics-off no-op contract,
sampler lifecycle across every policy on the threads, process and
simulated drivers, per-scope SLO attainment (including the expiry
path), the shm counter plane's totals + leak discipline, the
Prometheus/Perfetto exporters, the ``metricsview`` CLI and the
``traceview --counters`` merge, and the incremental detector's
agreement with the post-hoc pipeline."""
import json
import random
import threading
import time
import urllib.request

import pytest

from repro.core import RuntimeSimulator, SimTaskSpec, TaskRuntime
from repro.core.errors import ScopeExpired
from repro.core.metrics import (LogHistogram, MetricsHub, NULL_METRICS,
                                counter_track_events, prometheus_text,
                                save_metrics)
from repro.core.trace import (EV_END, EV_READY, EV_START, STARVATION,
                              IncrementalDetector, TraceEvent,
                              detect_all)

ALL_MODES = ("sync", "dast", "ddast", "sharded")


def _spin(n: int = 500) -> int:
    s = 0
    for i in range(n):
        s += i
    return s


# ------------------------------------------------- histogram properties
def test_histogram_bucket_monotonicity():
    """Bucket bounds tile the axis: contiguous, strictly increasing,
    and every recorded value lands in the bucket that contains it."""
    h = LogHistogram(1.0)
    prev_hi = 0.0
    for idx in range(256):
        lo, hi = h._bounds(idx)
        assert lo < hi
        assert lo == prev_hi          # no gap, no overlap
        prev_hi = hi
    for v in [0, 1, 3, 4, 7, 8, 100, 12345, 1 << 20]:
        lo, hi = h._bounds(h._index(v))
        assert lo <= v < hi


def test_histogram_merge_associative_commutative():
    rng = random.Random(7)
    hs = [LogHistogram(1e-3) for _ in range(3)]
    for h in hs:
        for _ in range(200):
            h.record(rng.uniform(0, 50.0))
    a, b, c = hs
    left = a.merge(b).merge(c)
    right = a.merge(b.merge(c))
    assert left.counts == right.counts
    assert left.count == right.count == 600
    assert left.total == pytest.approx(right.total)
    ab, ba = a.merge(b), b.merge(a)
    assert ab.counts == ba.counts and ab.min == ba.min and ab.max == ba.max
    with pytest.raises(ValueError):
        a.merge(LogHistogram(1.0))    # resolutions must match


def test_histogram_quantile_bounds():
    """quantile(q) is conservative: >= the exact q-quantile, and within
    the documented 25% + resolution envelope above it."""
    rng = random.Random(11)
    vals = [rng.uniform(0, 1000.0) for _ in range(500)]
    h = LogHistogram(0.01)
    for v in vals:
        h.record(v)
    svals = sorted(vals)
    for q in (0.1, 0.5, 0.9, 0.99, 1.0):
        exact = svals[min(int(q * len(svals) + 0.999999), len(svals)) - 1]
        got = h.quantile(q)
        assert got >= exact - 1e-9
        assert got <= exact * 1.25 + h.resolution + 1e-9
    assert LogHistogram(1.0).quantile(0.5) == 0.0


def test_histogram_snapshot_roundtrip_sums():
    h = LogHistogram(1.0)
    for v in (1, 5, 5, 300):
        h.record(v)
    snap = h.snapshot()
    assert snap["count"] == 4 and snap["sum"] == 311
    assert sum(n for _, _, n in snap["buckets"]) == 4
    for lo, hi, _ in snap["buckets"]:
        assert lo < hi


# --------------------------------------------- instruments + off path
def test_metrics_hub_slots_and_overflow():
    hub = MetricsHub(2, clock=time.perf_counter)
    hub.task_start(0)
    hub.task_end(0, 0.5)
    hub.task_start(99)                # out of range -> overflow slot
    hub.task_end(-3, 0.25)
    snap = hub.snapshot()
    assert snap["counters"]["tasks_started"]["per_slot"] == [1, 0, 1]
    assert snap["counters"]["tasks_finished"]["total"] == 2
    assert snap["task_latency"]["count"] == 2


def test_metrics_disabled_is_the_null_singleton():
    """metrics=False must leave the hot path with exactly one shared
    no-op object: no sampler registered, no per-runtime instrument
    state, empty stats.metrics — the structural no-op-cost guarantee
    (one ``.enabled`` check, zero writes)."""
    with TaskRuntime(num_workers=2, mode="ddast") as rt:
        rt.task(_spin)
        rt.taskwait()
        assert rt.instruments is NULL_METRICS
        assert not rt.instruments.enabled
        assert rt.sampler is None
        names = [c.name for c in rt.dispatcher._callbacks]
        assert "metrics-sampler" not in names
    assert rt.stats.metrics == {}
    assert NULL_METRICS.snapshot() == {}
    NULL_METRICS.task_start(0)        # no-ops, no state
    NULL_METRICS.task_end(0, 1.0)
    assert NULL_METRICS.snapshot() == {}


# -------------------------------------------- threads driver lifecycle
@pytest.mark.parametrize("mode", ALL_MODES)
def test_threads_metrics_lifecycle(mode):
    """Every policy: counters track tasks exactly, the sampler runs,
    and a second burst after a taskwait keeps counting (no freeze at
    quiescence)."""
    with TaskRuntime(num_workers=2, mode=mode, metrics=True,
                     metrics_interval_s=1e-4) as rt:
        for i in range(20):
            rt.task(_spin, label=f"a{i}")
        rt.taskwait()
        mid = rt.metrics()
        assert mid["counters"]["tasks_finished"]["total"] == 20
        for i in range(10):
            rt.task(_spin, label=f"b{i}")
        rt.taskwait()
    m = rt.stats.metrics
    assert m["counters"]["tasks_started"]["total"] == 30
    assert m["counters"]["tasks_finished"]["total"] == 30
    assert m["task_latency"]["count"] == 30
    assert m["sampler"]["samples"] >= 2   # quiescence ticks at minimum
    assert "ready" in m["sampler"]["series"]


def test_threads_metrics_concurrent_reader():
    """rt.metrics() is safe to hammer from another thread while the
    run is in flight (lock-free reads of single-writer state)."""
    stop = threading.Event()
    seen = []

    with TaskRuntime(num_workers=2, mode="sharded", metrics=True,
                     metrics_interval_s=1e-4) as rt:
        def reader():
            while not stop.is_set():
                seen.append(rt.metrics()["counters"]
                            ["tasks_finished"]["total"])

        t = threading.Thread(target=reader)
        t.start()
        try:
            for i in range(200):
                rt.task(time.sleep, 1e-5, label=f"t{i}")
            rt.taskwait()
        finally:
            stop.set()
            t.join()
    assert seen and seen == sorted(seen)  # monotonic counter reads
    assert rt.stats.metrics["counters"]["tasks_finished"]["total"] == 200


# ------------------------------------------------------- SLO attainment
def test_scope_slo_attainment_met():
    with TaskRuntime(num_workers=2, mode="ddast", num_clients=1,
                     metrics=True) as rt:
        sc = rt.open_scope("tenantA", deadline=30.0)
        for i in range(12):
            sc.task(_spin, label=f"t{i}")
        rt.taskwait()
        live = rt.metrics()["scopes"]["tenantA"]["slo"]
        assert live["met"] == 12 and live["missed"] == 0
        assert live["attainment"] == 1.0
        assert live["slack"]["count"] == 12
    rolled = rt.stats.scopes["tenantA"]["slo"]
    assert rolled["met"] == 12 and rolled["attainment"] == 1.0


def test_scope_slo_expiry_counts_misses():
    """A scope that blows its deadline: queued tasks drain cancelled
    (missed, no slack sample), taskwait raises ScopeExpired, and the
    rollup still reports the attainment split."""
    rt = TaskRuntime(num_workers=1, mode="ddast", num_clients=1,
                     metrics=True)
    rt.start()
    sc = rt.open_scope("tenantB", deadline=0.08)
    for i in range(30):
        sc.task(time.sleep, 0.02, label=f"slow{i}")
    with pytest.raises(ScopeExpired, match="deadline"):
        sc.taskwait()
    slo = sc.slo_snapshot()
    assert slo["missed"] > 0
    assert slo["attainment"] is None or slo["attainment"] < 1.0
    # cancelled tasks contribute no slack sample
    assert slo["slack"]["count"] <= slo["met"] + slo["missed"]
    rt.shutdown()
    entry = rt.stats.scopes["tenantB"]
    assert entry["slo"]["missed"] > 0


# ----------------------------------------------------- process backend
def test_procs_metrics_plane_totals_and_no_leak():
    with TaskRuntime(4, backend="processes", metrics=True,
                     metrics_interval_s=1e-3) as rt:
        for i in range(48):
            rt.task(_spin, 2000, label=f"t{i}")
        rt.taskwait()
        live = rt.metrics()
        assert live["workers"]["totals"]["tasks_finished"] == 48.0
        assert len(live["workers"]["per_worker"]) == 4
        assert live["sampler"]["samples"] >= 1
    m = rt.stats.metrics
    assert m["workers"]["totals"]["tasks_started"] == 48.0
    assert m["workers"]["totals"]["exec_time_s"] > 0.0
    assert m["gauges"]["ipc_done_msgs"] > 0
    assert rt.leaked_shm == []        # plane unlinked with the rings


@pytest.mark.parametrize("mode", ("sync", "sharded"))
def test_procs_metrics_lifecycle_modes(mode):
    with TaskRuntime(2, backend="processes", mode=mode,
                     metrics=True) as rt:
        for i in range(16):
            rt.task(_spin, 1000, label=f"t{i}")
        rt.taskwait()
    totals = rt.stats.metrics["workers"]["totals"]
    assert totals["tasks_finished"] == 16.0
    assert rt.leaked_shm == []


# ----------------------------------------------------------- simulator
def test_sim_metrics_counters_and_priced_overhead():
    specs = [SimTaskSpec(dur=100.0, label=f"t{i}") for i in range(64)]
    base = RuntimeSimulator(num_cores=4, mode="ddast").run(specs)
    r = RuntimeSimulator(num_cores=4, mode="ddast", metrics=True,
                         metrics_interval_us=50.0).run(specs)
    assert r.metrics["counters"]["tasks_finished"]["total"] == 64
    assert r.metrics["task_latency"]["count"] == 64
    samp = r.metrics["sampler"]
    assert samp["samples"] >= 2
    assert any(k.startswith("ready_depth.") for k in samp["series"])
    # every instrument write and sampler tick is priced in virtual time
    assert r.makespan_us > base.makespan_us
    assert base.metrics == {}


def test_sim_metrics_scopes_admission_series():
    specs = [SimTaskSpec(dur=50.0, label=f"t{i}") for i in range(32)]
    r = RuntimeSimulator(num_cores=2, mode="ddast", metrics=True,
                         metrics_interval_us=25.0).run_scopes(
        [specs, specs], weights=[2.0, 1.0])
    series = r.metrics["sampler"]["series"]
    assert "admission_backlog" in series
    assert "admission_waits" in series


# ------------------------------------------------------------ exporters
def _threads_snapshot():
    with TaskRuntime(num_workers=2, mode="ddast", num_clients=1,
                     metrics=True, metrics_interval_s=1e-4,
                     trace=True) as rt:
        sc = rt.open_scope("tenantA", deadline=30.0)
        for i in range(16):
            sc.task(_spin, label=f"t{i}")
        rt.taskwait()
    return rt


def test_prometheus_text_exposition():
    rt = _threads_snapshot()
    txt = prometheus_text(rt.stats.metrics)
    assert '# TYPE repro_tasks_finished_total counter' in txt
    assert 'repro_tasks_finished_total{slot="0"}' in txt
    assert '# TYPE repro_task_latency_seconds histogram' in txt
    assert 'repro_task_latency_seconds_count 16' in txt
    assert 'repro_scope_slo_attainment{scope="tenantA"} 1' in txt
    assert 'repro_scope_slack_seconds_bucket{scope="tenantA",le=' in txt
    assert 'repro_sampled{series=' in txt
    # cumulative le-buckets are monotone nondecreasing
    cums = [int(line.rsplit(" ", 1)[1]) for line in txt.splitlines()
            if line.startswith("repro_task_latency_seconds_bucket")]
    assert cums == sorted(cums) and cums[-1] == 16


def test_counter_track_events_shape():
    rt = _threads_snapshot()
    series = rt.stats.metrics["sampler"]["series"]
    evs = counter_track_events(series, "s")
    assert evs[0]["ph"] == "M"        # process_name meta leads
    counters = [e for e in evs if e["ph"] == "C"]
    assert counters
    for e in counters:
        assert set(e) >= {"name", "pid", "tid", "ts", "args"}
        assert "value" in e["args"]
    # seconds scale to Chrome-trace microseconds
    t, v = next(iter(series.values()))[0]
    assert any(abs(e["ts"] - t * 1e6) < 1e-3 for e in counters)


def test_metricsview_cli_and_traceview_counters(tmp_path):
    from repro.analysis.metricsview import main as metricsview
    from repro.analysis.traceview import main as traceview
    rt = _threads_snapshot()
    mpath = tmp_path / "run.metrics.json"
    tpath = tmp_path / "run.trace"
    save_metrics(str(mpath), rt.stats.metrics)
    rt.tracer.save(str(tpath))

    prom = tmp_path / "prom.txt"
    assert metricsview([str(mpath), "-o", str(prom)]) == 0
    assert "repro_scope_slo_attainment" in prom.read_text()

    perf = tmp_path / "ctr.json"
    assert metricsview([str(mpath), "--perfetto", "-o", str(perf)]) == 0
    doc = json.loads(perf.read_text())
    assert any(e["ph"] == "C" for e in doc["traceEvents"])

    merged = tmp_path / "merged.json"
    assert traceview([str(tpath), "-o", str(merged),
                      "--counters", str(mpath)]) == 0
    doc = json.loads(merged.read_text())
    slices = [e for e in doc["traceEvents"]
              if e.get("ph") == "X" and e.get("cat") == "task"]
    counters = [e for e in doc["traceEvents"] if e.get("ph") == "C"
                and e["name"] in rt.stats.metrics["sampler"]["series"]]
    assert slices and counters        # both layers in one document


# --------------------------------------------------- incremental detect
def _mk(t, ev, wd_id=-1, slot=-1, label="", scope=None, data=None):
    return TraceEvent(t, ev, wd_id, slot, label, scope, data)


def _starvation_events():
    evs = [_mk(0.0, EV_START, 900, 0, "warm"),
           _mk(0.1, EV_END, 900, 0, "warm"),
           _mk(0.0, EV_START, 901, 1, "warm"),
           _mk(0.1, EV_END, 901, 1, "warm")]
    for i in range(5):
        evs.append(_mk(1.0 + i * 0.01, EV_READY, i, 1, f"t{i}"))
    evs.append(_mk(100.0, EV_END, 901, 1))
    return evs


def test_incremental_detector_agrees_with_posthoc():
    evs = _starvation_events()
    posthoc = detect_all(evs)
    assert any(f.kind == STARVATION for f in posthoc)
    det = IncrementalDetector()
    live = []
    for cut in range(1, len(evs) + 1):
        live.extend(det.sweep(evs[:cut]))
    key = lambda f: (f.kind, round(f.t0, 9), f.slot)  # noqa: E731
    assert {key(f) for f in live} == {key(f) for f in posthoc}
    assert len(live) == len({key(f) for f in live})   # deduplicated
    assert det.sweep(evs) == []       # nothing fresh on a re-sweep
    assert [key(f) for f in det.findings] == [key(f) for f in live]


def test_sampler_sweeps_feed_live_findings():
    """A traced metrics runtime accumulates live findings through its
    sampler without waiting for the post-hoc pipeline."""
    with TaskRuntime(num_workers=2, mode="ddast", metrics=True,
                     metrics_interval_s=1e-4, trace=True) as rt:
        assert rt.sampler.detector is not None
        for i in range(40):
            rt.task(_spin, label=f"t{i}")
        rt.taskwait()
        swept = rt.sampler._trace_seen
    assert swept > 0                  # the live window was examined
    # live findings are deduplicated (the incremental detector never
    # re-reports a verdict it already surfaced) and every one rides the
    # read-side snapshot. Exact live-vs-posthoc agreement is pinned on
    # a deterministic timeline in
    # test_incremental_detector_agrees_with_posthoc — a real wall-clock
    # run's mid-span sweeps may legitimately flag transient spans the
    # full-span pass dilutes away.
    key = lambda f: (f.kind, round(f.t0, 9), f.slot)  # noqa: E731
    live = rt.sampler.live_findings
    assert len({key(f) for f in live}) == len(live)
    assert len(rt.sampler.snapshot()["live_findings"]) == len(live)


# ------------------------------------------------------------ serving
def test_serve_engine_metrics_and_scrape():
    from test_scopes import _StubModel
    from repro.serve.engine import Request, ServeEngine
    with TaskRuntime(num_workers=2, mode="ddast", num_clients=2) as rt:
        eng = ServeEngine(_StubModel(), None, batch_slots=2, max_len=8,
                          num_clients=2, runtime=rt,
                          client_deadlines=[30.0, None])
        for c in range(2):
            for _ in range(3):
                eng.submit(Request(prompt=[1, 2], max_new_tokens=2),
                           client_id=c)
        eng.run_until_drained()
        snap = eng.metrics_snapshot()
        c0 = snap["clients"]["client0"]
        assert c0["latency_steps"]["count"] == 3
        assert c0["slo"]["met"] == 3 and c0["slo"]["attainment"] == 1.0
        assert "slo" not in snap["clients"]["client1"]
        txt = eng.metrics_text()
        assert ('repro_request_latency_steps_count{client="client0"} 3'
                in txt)
        assert 'repro_client_slo_attainment{client="client0"} 1' in txt
        srv, port = eng.serve_metrics()
        try:
            body = urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics", timeout=10
            ).read().decode()
        finally:
            srv.shutdown()
        assert 'repro_request_latency_steps' in body
