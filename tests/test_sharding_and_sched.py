"""Sharding rules engine + DDAST static scheduler tests (and the
input-spec machinery the dry-run builds on)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import ARCHS, get_config, tiny_config
from repro.core.static_sched import DagNode, ddast_schedule, \
    overlap_collectives
from repro.models.config import SHAPES, get_shape
from repro.models.registry import get_model, input_specs, param_specs
from repro.parallel.sharding import (batch_specs, cache_sharding,
                                     make_rules, param_sharding, shard_tree)


def _mesh(shape=(2, 2), axes=("data", "model")):
    # AbstractMesh: the rules engine only needs axis names/sizes, and
    # NamedSharding over an abstract mesh is valid for spec construction —
    # tests then run regardless of how many real devices exist.
    return jax.sharding.AbstractMesh(shape, axes)


# --------------------------------------------------------------- sharding
def test_param_sharding_prefers_expert_dim():
    rules = make_rules(_mesh())
    s = param_sharding("['layers'][0]['ffn']['w_gate']", (8, 16, 64, 32),
                       rules)
    assert s.spec[1] == "model"        # expert dim (after stacked dim0)


def test_param_sharding_divisibility_fallback():
    rules = make_rules(_mesh((2, 16), ("data", "model")))
    # 14 heads * 16 hd = 224; 224 % 16 = 0 -> shards; but a dim of 30 won't
    s = param_sharding("['x']['wq']", (60, 224), rules)
    assert s.spec[1] == "model"
    s2 = param_sharding("['x']['wq']", (61, 30), rules)
    assert s2.spec == P(None, None)    # nothing divisible -> replicated


def test_param_sharding_never_shards_stacked_dim():
    rules = make_rules(_mesh())
    s = param_sharding("['layers'][0]['mixer']['wq']", (2, 64, 64), rules)
    assert s.spec[0] is None


def test_batch_specs_sp_fallback_for_batch1():
    rules = make_rules(_mesh((4, 2), ("data", "model")))
    tree = {"tokens": jax.ShapeDtypeStruct((1, 64), jnp.int32)}
    sh = batch_specs(tree, rules)
    assert sh["tokens"].spec[1] == "data"     # sequence parallelism


def test_cache_sharding_protects_layer_dim():
    rules = make_rules(_mesh((2, 2), ("data", "model")))
    s = cache_sharding("[0]['k']", (4, 8, 128, 4, 64), rules)
    assert s.spec[0] is None
    assert s.spec[1] in ("data", ("data",))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_all_params_get_valid_shardings(arch):
    """Every leaf of every full-size arch must produce a sharding whose
    sharded dims divide — on the production-like axis sizes."""
    cfg = get_config(arch)
    pspecs = param_specs(cfg)
    mesh = _mesh((2, 2), ("data", "model"))
    rules = make_rules(mesh)
    # simulate production divisibility (16-way axes) without 256 devices:
    from repro.parallel.sharding import ShardingRules
    shardings = shard_tree(pspecs, rules)
    leaves = jax.tree_util.tree_leaves_with_path(pspecs)
    shard_leaves = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
    assert len(leaves) == len(shard_leaves)
    for (path, spec), sh in zip(leaves, shard_leaves):
        for dim, name in enumerate(sh.spec):
            if name is None:
                continue
            axes = name if isinstance(name, tuple) else (name,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert spec.shape[dim] % size == 0, (path, spec.shape, sh.spec)


def test_input_specs_cover_all_cells():
    for arch in sorted(ARCHS):
        cfg = get_config(arch)
        for shape in SHAPES:
            specs = input_specs(cfg, shape)
            if shape.kind in ("train", "prefill"):
                assert specs["tokens"].shape == (shape.global_batch,
                                                 shape.seq_len)
            else:
                assert specs["tokens"].shape == (shape.global_batch,)
                assert "cache" in specs


# ---------------------------------------------------------- static sched
def test_ddast_schedule_topological():
    nodes = [DagNode("a"), DagNode("b", deps=["a"]),
             DagNode("c", deps=["a"]), DagNode("d", deps=["b", "c"])]
    order = ddast_schedule(nodes)
    assert order.index("a") < order.index("b") < order.index("d")
    assert order.index("a") < order.index("c") < order.index("d")


@given(st.integers(2, 30), st.integers(1, 4), st.randoms(use_true_random=False))
@settings(max_examples=30, deadline=None)
def test_ddast_schedule_property_random_dags(n, units, rng):
    nodes = []
    for i in range(n):
        deps = [str(j) for j in range(i) if rng.random() < 0.3]
        nodes.append(DagNode(str(i), cost=rng.random() + 0.1, deps=deps))
    order = ddast_schedule(nodes, num_units=units)
    pos = {nm: i for i, nm in enumerate(order)}
    for nd in nodes:
        for d in nd.deps:
            assert pos[d] < pos[nd.name]


def test_overlap_collectives_hoists_safely():
    nodes = [DagNode("c0"), DagNode("c1", deps=["c0"]),
             DagNode("rs0", deps=["c0"], kind="collective"),
             DagNode("c2", deps=["c1"])]
    order = ["c0", "c1", "c2", "rs0"]
    out = overlap_collectives(nodes, order)
    assert out.index("rs0") == 1      # right after its dep, before c1/c2
    pos = {nm: i for i, nm in enumerate(out)}
    assert pos["c0"] < pos["rs0"]


def test_microbatch_schedule_is_permutation():
    from repro.train.train_step import microbatch_schedule
    for n in (2, 4, 8):
        order = microbatch_schedule(n)
        assert sorted(order) == list(range(n))
